"""On-device adaptation subsystem tests: activation-memory ledger arithmetic,
exact calibration capture, budget-respecting planner output, per-site rank
materialization in the ASI state, the train-while-serve DeviceSession, the
engine retirement hook, and the launch CLI."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.ondevice.ledger import (BYTES_PER_ELEM, build_ledger,
                                   ledgers_for_registry,
                                   measured_site_residual_bytes)
from repro.ondevice.planner import build_plan, capture_calibration
from repro.ondevice.session import DeviceSession, ReplayBuffer, SessionCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.serve_loop import Engine, Request, SequentialEngine, ServeCfg
from repro.runtime.train_loop import make_train_step

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _setup(arch="tinyllama-1.1b"):
    cfg = get_config(arch).reduced().replace(compress="asi",
                                             kernel_backend="reference")
    api = build_model(cfg)
    params = api.init(KEY)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=S,
                                global_batch=B, seed=0, branching=2))
    return cfg, api, params, data


@pytest.fixture(scope="module")
def tiny():
    return _setup()


@pytest.fixture(scope="module")
def tiny_plan(tiny):
    cfg, api, params, data = tiny
    batches = [data.batch(s) for s in range(2)]
    return build_plan(api, cfg, params, 0.05, batches, batch_size=B,
                      seq_len=S)


# --------------------------------------------------------------------------
# ledger
# --------------------------------------------------------------------------

def test_ledger_every_registry_family():
    """The ledger builds for every registered architecture (all families)
    and compressed storage always undercuts vanilla."""
    for arch, led in ledgers_for_registry(B, S).items():
        assert led.rows, arch
        assert led.asi_total_bytes < led.vanilla_total_bytes, arch
        assert led.min_bytes() <= led.asi_total_bytes, arch


def test_ledger_matches_asi_state_sites(tiny):
    """One ledger row per warm-start factor in the actual ASI state."""
    cfg, api, _, _ = tiny
    led = build_ledger(cfg, B, S)
    n_leaves = len(jax.tree.leaves(api.init_asi(KEY)))
    assert len(led.rows) == n_leaves


def test_ledger_arithmetic(tiny):
    """vanilla = M*K bytes, compressed = (M+K)*r bytes, per site."""
    cfg, _, _, _ = tiny
    led = build_ledger(cfg, B, S)
    row = led.rows[0]
    m = B * S
    assert row.vanilla_bytes == m * row.site.k * BYTES_PER_ELEM
    assert row.compressed_bytes == (m + row.site.k) * row.rank * BYTES_PER_ELEM
    # HOSVD pays the per-step SVD; ASI pays one warm-started iteration
    assert row.hosvd_overhead_flops > row.asi_overhead_flops


def test_ledger_measured_matches_analytical():
    """Eager residual weighing agrees byte-for-byte with the formulas."""
    m, k, r = 192, 96, 10
    assert measured_site_residual_bytes(m, k, r, compressed=True) \
        == (m + k) * r * BYTES_PER_ELEM
    assert measured_site_residual_bytes(m, k, r, compressed=False) \
        == m * k * BYTES_PER_ELEM


# --------------------------------------------------------------------------
# calibration capture
# --------------------------------------------------------------------------

def test_capture_is_exact(tiny):
    """x^T g from the captured pairs equals the dense model's true weight
    gradient — the capture taps sit outside the custom_vjp boundary and ASI
    keeps activation gradients exact, so calibration sees the real thing."""
    cfg, api, params, data = tiny
    batch = data.batch(0)
    asi_state = api.init_asi(KEY)
    layers = capture_calibration(api, cfg, params, asi_state, [batch])
    led = build_ledger(cfg, B, S)
    assert len(layers) == len(led.rows)

    dense_api = build_model(cfg.replace(compress="none"))
    gfull = jax.grad(lambda p: dense_api.loss(p, batch, None)[0])(params)
    # check one attention site and one ffn site in the last period
    np_idx = max(int(r.site.name.split("/")[0].split("_")[1])
                 for r in led.rows)
    checks = {f"period_{np_idx}/sub0/mixer/wq": ("mixer", "wq"),
              f"period_{np_idx}/sub0/ffn/down": ("ffn", "down")}
    for i, row in enumerate(led.rows):
        if row.site.name not in checks:
            continue
        grp, wname = checks[row.site.name]
        ref = np.asarray(gfull["stack"]["sub0"][grp][wname][np_idx])
        got = layers[i].activation.T @ layers[i].grad_out
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)


def test_capture_requires_compressed_model(tiny):
    cfg, api, params, data = tiny
    with pytest.raises(ValueError):
        capture_calibration(api, cfg.replace(compress="none"), params, {},
                            [data.batch(0)])


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------

def test_plan_respects_ledger_budget(tiny, tiny_plan):
    cfg, _, _, _ = tiny
    plan = tiny_plan
    led = build_ledger(cfg, B, S)
    assert plan.within_budget
    assert led.bytes_for(plan.rank_plan) == plan.planned_bytes
    assert plan.planned_bytes <= plan.budget_bytes
    assert set(plan.rank_plan) == {r.site.name for r in led.rows}


def test_tighter_budget_spends_less(tiny, tiny_plan):
    cfg, api, params, data = tiny
    batches = [data.batch(s) for s in range(2)]
    tight = build_plan(api, cfg, params, 0.04, batches, batch_size=B,
                       seq_len=S)
    assert tight.planned_bytes <= 0.04 * 2 ** 20
    assert tight.planned_bytes <= tiny_plan.planned_bytes


def test_infeasible_budget_raises(tiny):
    cfg, api, params, data = tiny
    # zero budget: caught by the ledger's rank-1 floor, before calibration
    with pytest.raises(ValueError, match="ledger floor"):
        build_plan(api, cfg, params, 0.0, [data.batch(0)], batch_size=B,
                   seq_len=S)
    # above the rank-1 floor but below the ε grid's smallest candidates
    with pytest.raises(ValueError, match="grid"):
        build_plan(api, cfg, params, 0.01, [data.batch(0)], batch_size=B,
                   seq_len=S)


def test_backtracking_method(tiny):
    cfg, api, params, data = tiny
    plan = build_plan(api, cfg, params, 0.05, [data.batch(0)], batch_size=B,
                      seq_len=S, method="backtracking")
    assert plan.within_budget


def test_rank_plan_materializes_in_state(tiny, tiny_plan):
    """The planner's per-site ranks become the warm-start factor shapes —
    which is exactly what sets asi_linear's compute/storage rank."""
    cfg, api, _, _ = tiny
    state = api.init_asi(KEY, rank_plan=tiny_plan.rank_plan)
    led = build_ledger(cfg, B, S, rank_plan=tiny_plan.rank_plan)
    assert led.asi_total_bytes == tiny_plan.planned_bytes
    for row in led.rows:
        node = state
        for part in row.site.name.split("/"):
            node = node[part]
        assert node.q.shape[-1] == tiny_plan.rank_plan[row.site.name]
    ccfgs = tiny_plan.compression_cfgs()
    assert all(ccfgs[n].rank == tiny_plan.rank_plan[n] for n in ccfgs)


def test_planned_training_step_learns(tiny, tiny_plan):
    """make_train_step consumes the plan (via the state shapes) and the
    adaptation loss decreases on the deterministic stream."""
    cfg, api, params, data = tiny
    state = api.init_asi(KEY, rank_plan=tiny_plan.rank_plan)
    opt = make_optimizer("adamw", warmup_cosine(1e-2, 2, 12), clip_norm=2.0)
    step = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                           trainable_mask=api.trainable_mask(params),
                           donate=False, kernel_backend=cfg.kernel_backend)
    opt_state = opt.init(params)
    losses = []
    for i in range(12):
        params, opt_state, state, metrics = step(params, opt_state, state,
                                                 data.batch(i % 3),
                                                 jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_plan_ranks_clamped_to_adaptation_shape():
    """Calibration concatenates batches along tokens, so its candidate
    ranks can exceed the adaptation shape's M = B*S — the plan must clamp
    them (an (M, r) factor with r > M collapses under orthonormalization,
    breaking the custom-vjp state shapes)."""
    cfg, api, params, data = _setup("mamba2-130m")
    batches = [data.batch(s) for s in range(2)]   # calib M = 2*B*S > B*S
    plan = build_plan(api, cfg, params, 0.2, batches, batch_size=B, seq_len=S)
    m = B * S
    for site in plan.sites:
        assert plan.rank_plan[site.name] <= min(m, site.k), site.name


def test_plan_grouped_moe_sites():
    """MoE tail: grouped sites capture (E, T, K) activations and the plan's
    shared per-site rank lands in the GroupedASIState stack."""
    cfg, api, params, data = _setup("granite-moe-3b-a800m")
    plan = build_plan(api, cfg, params, 0.2, [data.batch(0)], batch_size=B,
                      seq_len=S)
    grouped = [s for s in plan.sites if s.kind == "grouped"]
    assert grouped, "moe tail should have grouped ffn sites"
    state = api.init_asi(KEY, rank_plan=plan.rank_plan)
    for site in grouped:
        node = state
        for part in site.name.split("/"):
            node = node[part]
        assert node.q.shape == (site.groups, site.k,
                                plan.rank_plan[site.name])
    assert plan.within_budget


# --------------------------------------------------------------------------
# engine retirement hook
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [Engine, SequentialEngine])
def test_retirement_hook_streams_completions(engine_cls):
    cfg = get_config("tinyllama-1.1b").reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    reqs = [Request(uid=i, prompt=[1 + i, 2 + i, 3], max_new_tokens=4)
            for i in range(5)]
    reqs.append(Request(uid=99, prompt=[7], max_new_tokens=0))  # zero-budget
    seen = []
    done = engine_cls(api, params, ServeCfg(max_batch=2, max_len=32)).run(
        reqs, on_retire=lambda r: seen.append(r.uid))
    assert [r.uid for r in done] == seen          # streamed, completion order
    assert sorted(seen) == [0, 1, 2, 3, 4, 99]
    assert all(r.done for r in done)


# --------------------------------------------------------------------------
# replay buffer + session
# --------------------------------------------------------------------------

def test_replay_buffer_fixed_shapes():
    buf = ReplayBuffer(capacity=4, seq_len=8)
    buf.add([1])                                  # too short: dropped
    assert len(buf) == 0
    buf.add([1, 2, 3])
    for i in range(6):
        buf.add(list(range(2 + i, 12 + i)))
    assert len(buf) == 4                          # ring capacity
    batch = buf.sample_batch(3)
    assert batch["tokens"].shape == (3, 8)
    assert batch["targets"].shape == (3, 8)
    # targets are tokens shifted by one (tiled stream)
    np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                  np.asarray(batch["targets"][:, :-1]))


def test_device_session_trains_while_serving(tiny, tiny_plan):
    cfg, api, params, data = tiny
    state = api.init_asi(KEY, rank_plan=tiny_plan.rank_plan)
    opt = make_optimizer("adamw", warmup_cosine(1e-2, 2, 10), clip_norm=2.0)
    step = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                           trainable_mask=api.trainable_mask(params),
                           donate=False, kernel_backend=cfg.kernel_backend)
    sess = DeviceSession(api, params, step, opt.init(params), state,
                         ServeCfg(max_batch=2, max_len=32),
                         SessionCfg(adapt_every=2, burst_steps=2,
                                    total_steps=10, batch_size=B, seq_len=S),
                         probe_batch=data.batch(999))
    reqs = [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(5)],
                    max_new_tokens=6) for i in range(6)]
    report = sess.run(reqs)
    assert report.retired == 6
    assert report.steps == 10                     # budget honored + drained
    assert report.serve_stats.generated_tokens == 36
    assert report.adapt_losses[-1] < report.adapt_losses[0]
    # forgetting counter: probe measured before adaptation and per burst
    assert len(report.probe_losses) == report.bursts + 1
    assert report.probe_drift is not None
    # the adapted weights are live in the engine (same object)
    assert sess.engine.params is sess.params
    assert sess.params is not params              # weights actually moved


# --------------------------------------------------------------------------
# launch CLI
# --------------------------------------------------------------------------

def test_adapt_cli_end_to_end(tmp_path, capsys):
    from repro.launch import adapt as adapt_cli
    report = adapt_cli.main([
        "--config", "tinyllama_1_1b", "--reduced", "--mem-budget-mb", "0.05",
        "--steps", "4", "--adapt-every", "2", "--requests", "4",
        "--max-new", "4", "--seq-len", "16", "--kernel-backend", "reference",
        "--ckpt-dir", str(tmp_path / "ckpt")])
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    by_key = {k: l for l in lines for k in l}
    assert by_key["plan"]["plan_respects_ledger_budget"]
    assert by_key["plan"]["plan"]["within_budget"]
    assert by_key["adaptation"]["adaptation"]["adapt_steps"] == 4
    assert report.adapt_losses[-1] < report.adapt_losses[0] * 1.05
    assert checkpointer.latest_step(str(tmp_path / "ckpt")) == 4


def test_adapt_cli_rejects_unknown_arch():
    from repro.launch import adapt as adapt_cli
    with pytest.raises(SystemExit):
        adapt_cli.main(["--arch", "nonexistent", "--mem-budget-mb", "1"])
