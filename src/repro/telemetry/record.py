"""Telemetry primitives: spans, counters, gauges, histograms, and the
bounded host-side ``Recorder`` (DESIGN.md §13).

Two planes, one object:

* **Aggregates** (counters / gauges / histograms) are *always* updated,
  even with ``enabled=False``.  They are the single source of truth for
  derived surfaces such as ``Engine.last_stats`` — a few dict lookups and
  float adds per hot-loop iteration, cheap enough to leave on
  unconditionally.
* **Events** (span begin/end, instants, gauge samples) land in a bounded
  ring buffer only when ``enabled=True``.  Overflow evicts the oldest
  event and increments ``dropped`` — never silently.

Everything records *host* values only.  The recorder owns no device
arrays and issues no device syncs; callers hand it Python scalars that
already crossed the host boundary (the ``telemetry-contract`` lint rule
enforces this).  Time comes from an injectable monotonic clock so tests
get deterministic span trees (see :class:`ManualClock`).
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Callable, Optional


class ManualClock:
    """Deterministic clock for tests: starts at ``start`` and advances by
    ``tick`` after every read (``tick=0`` freezes it; use :meth:`advance`)."""

    def __init__(self, start: float = 0.0, tick: float = 1.0):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        now = self.t
        self.t += self.tick
        return now

    def advance(self, dt: float) -> None:
        self.t += dt


class Counter:
    """Monotonic accumulator (no events — timeline via spans/instants)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def add(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-value sample with a high-water mark (resettable per run)."""

    __slots__ = ("value", "peak")

    def __init__(self):
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v

    def reset_peak(self, floor: float = 0.0) -> None:
        self.peak = floor


class Histogram:
    """Raw-valued histogram: keeps every observation so percentile math
    matches what ``np.percentile`` would say over the same samples."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: list[float] = []

    def record(self, v: float) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)


class _Span:
    """Context manager emitting paired B/E events and (when a profiler
    bridge is attached) a named ``jax.profiler.TraceAnnotation`` scope."""

    __slots__ = ("rec", "name", "attrs", "sid", "_ann")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self.rec = rec
        self.name = name
        self.attrs = attrs
        self.sid = 0
        self._ann = None

    def __enter__(self) -> "_Span":
        rec = self.rec
        rec._span_seq += 1
        self.sid = rec._span_seq
        ev = {"ts": rec.now(), "kind": "B", "name": self.name,
              "id": self.sid,
              "parent": rec._stack[-1] if rec._stack else 0}
        if self.attrs:
            ev["attrs"] = self.attrs
        rec._stack.append(self.sid)
        rec._emit(ev)
        if rec.profiler is not None:
            self._ann = rec.profiler.annotation(self.name)
            self._ann.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        rec = self.rec
        if self._ann is not None:
            self._ann.__exit__(*exc)
        if rec._stack and rec._stack[-1] == self.sid:
            rec._stack.pop()
        rec._emit({"ts": rec.now(), "kind": "E", "name": self.name,
                   "id": self.sid})


class _NullSpan:
    """Shared no-op span for disabled recorders (aggregates still flow)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class Recorder:
    """Bounded host-side telemetry sink.

    Parameters
    ----------
    clock:
        Zero-arg callable returning monotonic seconds.  Defaults to
        ``time.perf_counter``; inject :class:`ManualClock` for
        deterministic tests.
    capacity:
        Ring-buffer bound on the event plane.  Oldest events are evicted
        on overflow and counted in :attr:`dropped`.
    enabled:
        When ``False``, the event plane is off (spans become no-ops,
        instants are skipped) but aggregates keep updating — this is the
        telemetry-off arm of the overhead benchmark and the default for
        engines constructed without an explicit recorder.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 capacity: int = 4096, enabled: bool = True):
        self.clock = clock if clock is not None else time.perf_counter
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.events: deque = deque()
        self.dropped = 0
        self.profiler = None  # attached JaxProfileBridge, if any
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}
        self._stack: list[int] = []
        self._span_seq = 0

    # -- clock / events ------------------------------------------------
    def now(self) -> float:
        return self.clock()

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.capacity:
            self.events.popleft()
            self.dropped += 1
        self.events.append(ev)

    def span(self, name: str, **attrs):
        """Open a named span (``with rec.span("serve.decode_step"): ...``)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Point-in-time event (request lifecycle marks, restarts, ...)."""
        if not self.enabled:
            return
        ev = {"ts": self.now(), "kind": "I", "name": name}
        if attrs:
            ev["attrs"] = attrs
        self._emit(ev)

    # -- aggregates (always on) ----------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def hist(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    def count(self, name: str, n: float = 1) -> None:
        self.counter(name).add(n)

    def observe(self, name: str, v: float) -> None:
        self.hist(name).record(v)

    def set_gauge(self, name: str, v: float, sample: bool = True) -> None:
        """Update a gauge; with ``sample=True`` also emit a ``G`` event so
        exporters can plot the value over time (skipped when disabled)."""
        self.gauge(name).set(v)
        if sample and self.enabled:
            self._emit({"ts": self.now(), "kind": "G", "name": name,
                        "value": v})

    # -- snapshots -----------------------------------------------------
    def metrics(self) -> dict:
        """Flat aggregate snapshot (exported as the JSONL footer line)."""
        out: dict = {}
        for k, c in sorted(self._counters.items()):
            out[k] = c.value
        for k, g in sorted(self._gauges.items()):
            out[k] = g.value
            out[f"{k}.peak"] = g.peak
        for k, h in sorted(self._hists.items()):
            out[f"{k}.count"] = h.count
        return out

    # -- jax.profiler bridge -------------------------------------------
    def attach_profiler(self, trace_dir: Optional[str] = None):
        """Attach a :class:`~repro.telemetry.jaxprof.JaxProfileBridge`:
        spans gain ``TraceAnnotation`` scopes and engines emit
        compile-vs-run splits / live-buffer gauges."""
        from repro.telemetry.jaxprof import JaxProfileBridge
        self.profiler = JaxProfileBridge(self, trace_dir=trace_dir)
        return self.profiler

    def profile(self):
        """Context manager covering a whole run: starts/stops the
        ``jax.profiler`` device trace when a bridge with a trace dir is
        attached, else a no-op."""
        if self.profiler is not None:
            return self.profiler.trace()
        return contextlib.nullcontext()
