"""telemetry-contract: recorder calls stay host-side and out of traced code.

The telemetry layer (DESIGN.md §13) is a pure host-side observer: spans,
counters, gauges and histograms record Python floats/ints that already
crossed the device boundary through the hot path's one explicit
``jax.device_get`` per step.  Two ways to break that contract, both flagged
under the ``telemetry-contract`` rule name:

1. **recorder calls in traced code** — a ``rec.span()`` / ``rec.count()``
   inside a jitted function (or anything reachable from one) either bakes
   the trace-time value into the compiled program or crashes on a tracer;
   either way the event stream lies.

2. **device values recorded in loop-hot code** — in ``runtime/``,
   ``ondevice/`` and ``scenarios/`` modules, passing a device-array value
   to a recorder method inside a ``for``/``while`` body smuggles a deferred
   transfer (and a live buffer reference) into the event ring.  Record the
   host copies the step's ``jax.device_get`` already produced.

Recorder-rooted calls are recognized syntactically: the final attribute is
one of ``span/instant/count/observe/set_gauge`` and the access chain goes
through a name that reads as a recorder (``tele``, ``telemetry``,
``recorder``, ``rec``) — ``self.tele.count(...)``, ``rec.span(...)``.
Suppress intentional exceptions with ``# repro-lint:
disable=telemetry-contract``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, FileContext, call_name, rule
from repro.analysis.jit_purity import (SYNC_SCOPES, _all_functions,
                                       _functions, _is_host_call,
                                       _jitted_names, _own_walk, _reachable,
                                       _traced_roots)

RECORDER_METHODS = ("span", "instant", "count", "observe", "set_gauge")
_RECORDER_ROOTS = ("tele", "telemetry", "recorder", "rec")


def _recorder_method(node: ast.Call) -> str | None:
    """``"count"`` for ``self.tele.count(...)``-shaped calls, else None."""
    func = node.func
    if not (isinstance(func, ast.Attribute)
            and func.attr in RECORDER_METHODS):
        return None
    chain = []
    cur = func.value
    while isinstance(cur, ast.Attribute):
        chain.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        chain.append(cur.id)
    if any(seg in _RECORDER_ROOTS for seg in chain):
        return func.attr
    return None


def _is_device_call(name: str | None, jitted: set[str]) -> bool:
    if name is None or _is_host_call(name):
        return False
    return (name.startswith(("jnp.", "lax.", "jax.numpy.", "jax.lax."))
            or (name.startswith("jax.")
                and not name.startswith("jax.device_get"))
            or name in jitted or name.split(".")[-1] in jitted)


def _device_names(fn: ast.FunctionDef, jitted: set[str]) -> set[str]:
    """Names assigned (anywhere in ``fn``) from device-valued calls and not
    later re-bound to a host-safe call."""
    device: set[str] = set()
    host: set[str] = set()
    for node in _own_walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = call_name(node.value)
            targets = [n.id for t in node.targets
                       for n in ast.walk(t) if isinstance(n, ast.Name)]
            if _is_host_call(name):
                host.update(targets)
            elif _is_device_call(name, jitted):
                device.update(targets)
    return device - host


def _check_traced(ctx: FileContext, fn: ast.FunctionDef):
    for node in _own_walk(fn):
        if not isinstance(node, ast.Call):
            continue
        meth = _recorder_method(node)
        if meth is not None:
            yield Finding(
                "telemetry-contract", ctx.rel, node.lineno,
                f"{fn.name}: recorder .{meth}() inside traced code — "
                "telemetry is host-side only; record outside the jitted "
                "body (after the step's jax.device_get)")


def _check_loops(ctx: FileContext, fn: ast.FunctionDef, jitted: set[str]):
    device = _device_names(fn, jitted)
    seen_lines: set[int] = set()
    for loop in _own_walk(fn):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        for node in ast.walk(loop):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not isinstance(node, ast.Call):
                continue
            meth = _recorder_method(node)
            if meth is None or node.lineno in seen_lines:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                offending = (
                    (isinstance(arg, ast.Name) and arg.id in device)
                    or (isinstance(arg, ast.Call)
                        and _is_device_call(call_name(arg), jitted)))
                if offending:
                    seen_lines.add(node.lineno)
                    yield Finding(
                        "telemetry-contract", ctx.rel, node.lineno,
                        f"{fn.name}: recorder .{meth}() records a device "
                        "value inside a loop body — a deferred per-"
                        "iteration transfer; record the host copy from "
                        "the step's jax.device_get instead")
                    break


@rule("telemetry-contract",
      doc="recorder calls must stay out of traced code and must not "
          "record device values in runtime loop bodies")
def check_telemetry_contract(ctx: FileContext):
    if ctx.rel.startswith("src/repro/telemetry/"):
        return                       # the recorder's own internals are exempt
    fns = _functions(ctx.tree)
    roots = _traced_roots(ctx.tree, fns)
    for name in sorted(_reachable(fns, roots)):
        yield from _check_traced(ctx, fns[name])

    if any(ctx.rel.startswith(s) for s in SYNC_SCOPES):
        jitted = _jitted_names(ctx.tree)
        for fn in _all_functions(ctx.tree):
            yield from _check_loops(ctx, fn, jitted)
