"""Gradient filtering baseline (Yang et al., CVPR 2023).

The paper benchmarks against this: approximate activations and output
gradients by average-pooling over RxR spatial patches before computing the
weight gradient.  Memory drops by R² for the stored activation; the gradient
is approximated (unlike ASI, the error also propagates to ∂L/∂A in the
original method — we reproduce the stored-activation variant used by the
paper's comparison, i.e. pooled A and pooled g for ∂L/∂W).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def patch_pool(x: Array, r: int) -> Array:
    """Average-pool an NCHW tensor over non-overlapping r×r patches.

    Pads H/W up to multiples of r (edge replication not needed for the cost
    model; zero-pad + renormalize keeps the mean exact on full patches).
    """
    b, c, h, w = x.shape
    ph, pw = (-h) % r, (-w) % r
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, ph), (0, pw)))
    hh, ww = (h + ph) // r, (w + pw) // r
    x = x.reshape(b, c, hh, r, ww, r)
    return x.mean(axis=(3, 5))


def pooled_storage_elems(shape: tuple[int, int, int, int], r: int) -> int:
    b, c, h, w = shape
    return b * c * ((h + r - 1) // r) * ((w + r - 1) // r)
