"""Replay policies over the ``ReplayBuffer`` fixed-shape contract.

Three ways to decide which retired token streams survive at capacity and
which get sampled into adaptation batches:

* **fifo** — the ``ondevice.session.ReplayBuffer`` baseline: strict
  add-order eviction, uniform sampling.  Recency-biased: after a domain
  shift the buffer flushes to the new distribution within ``capacity``
  retirements (fast recovery, fast forgetting).
* **reservoir** — classic reservoir sampling: every stream ever added has
  equal survival probability, so the buffer stays an unbiased sample of the
  whole session (slow forgetting, slower recovery).
* **stratified** — per-phase FIFO sub-rings with the capacity split across
  *seen* phases; sampling round-robins phases.  The replay-based middle
  ground the continual-learning literature calls phase-balanced rehearsal.

All three share ``ReplayBuffer``'s invariants, property-tested in
``tests/test_scenarios.py``: stored streams never exceed ``capacity``,
``sample_batch`` has a fixed shape regardless of fill level, and sampling
is deterministic under a fixed seed.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.ondevice.session import ReplayBuffer

__all__ = ["ReplayBuffer", "ReservoirReplay", "StratifiedReplay",
           "REPLAY_POLICIES", "make_replay"]


class ReservoirReplay(ReplayBuffer):
    """Uniform-over-history reservoir: stream #n replaces a random slot
    with probability capacity/n once the buffer is full."""

    policy = "reservoir"

    def __init__(self, capacity: int, seq_len: int, seed: int = 0):
        super().__init__(capacity, seq_len, seed=seed)
        self._buf: list = []                    # plain list: indexed eviction
        self._seen = 0

    def _store(self, toks, phase):
        self._seen += 1
        if len(self._buf) < self.capacity:
            self._buf.append(toks)
            return
        j = int(self._rng.integers(0, self._seen))
        if j < self.capacity:
            self._buf[j] = toks


class StratifiedReplay(ReplayBuffer):
    """Phase-stratified rehearsal: one FIFO sub-ring per seen phase, global
    capacity split evenly, sampling round-robined across phases."""

    policy = "stratified"

    def __init__(self, capacity: int, seq_len: int, seed: int = 0):
        super().__init__(capacity, seq_len, seed=seed)
        self._by_phase: dict[int, collections.deque] = {}

    def _store(self, toks, phase):
        self._by_phase.setdefault(phase, collections.deque()).append(toks)
        self._rebalance()

    def _rebalance(self):
        """Evict oldest-first from the fullest phase until within capacity —
        which converges on an even capacity split across seen phases."""
        while sum(len(d) for d in self._by_phase.values()) > self.capacity:
            over = max(self._by_phase, key=lambda p: len(self._by_phase[p]))
            self._by_phase[over].popleft()
            if not self._by_phase[over]:
                del self._by_phase[over]

    def _rows(self):
        return [t for p in sorted(self._by_phase)
                for t in self._by_phase[p]]

    def _select_indices(self, batch_size: int) -> np.ndarray:
        phases = sorted(p for p in self._by_phase if self._by_phase[p])
        offsets, off = {}, 0
        for p in sorted(self._by_phase):
            offsets[p] = off
            off += len(self._by_phase[p])
        idx = np.empty((batch_size,), np.int64)
        for r in range(batch_size):
            p = phases[r % len(phases)]          # round-robin the phases
            idx[r] = offsets[p] + int(
                self._rng.integers(0, len(self._by_phase[p])))
        return idx


REPLAY_POLICIES = {"fifo": ReplayBuffer, "reservoir": ReservoirReplay,
                   "stratified": StratifiedReplay}


def make_replay(policy: str, capacity: int, seq_len: int,
                seed: int = 0) -> ReplayBuffer:
    if policy not in REPLAY_POLICIES:
        raise ValueError(f"unknown replay policy {policy!r}; choose from "
                         f"{sorted(REPLAY_POLICIES)}")
    return REPLAY_POLICIES[policy](capacity, seq_len, seed=seed)
