"""Mesh-sharded training walkthrough: dp parity, FSDP memory, elastic resume.

Runs entirely on CPU by forcing 8 host-platform devices (set before jax
imports — the same trick the sharded tests and CI use), so you can watch
every moving part of the `--layout` machinery without an accelerator:

1. build a (data=2, model=4) mesh and a ``MeshPlan`` for the ``tp`` layout;
2. train a reduced TinyLlama with ASI compression + gradient accumulation;
3. checkpoint, then resume the SAME checkpoint on a differently-shaped
   (data=8, model=1) ``dp`` mesh — checkpoints are layout-free.

The CLI equivalent of step 2 is:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.train --arch tinyllama-1.1b --reduced \\
      --steps 12 --compress asi --layout tp --mesh 2,4 --grad-accum 2

Run:  PYTHONPATH=src python examples/train_sharded.py
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

from repro.configs.registry import get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.launch.mesh import make_layout_mesh
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import (TrainLoopCfg, make_mesh_plan,
                                      make_train_step, run)


def train_leg(layout, mesh_shape, ckpt_dir, total_steps, grad_accum=1):
    cfg = get_config("tinyllama-1.1b").reduced().replace(compress="asi")
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    asi = api.init_asi(key)
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 2, total_steps),
                         clip_norm=2.0)
    opt_state = opt.init(params)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=8, seed=0, branching=2))

    mesh = make_layout_mesh(layout, mesh_shape)
    plan = make_mesh_plan(cfg, mesh, layout, params, opt_state, asi,
                          data.batch(0))
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=api.trainable_mask(params),
                              kernel_backend=cfg.kernel_backend,
                              plan=plan, grad_accum=grad_accum)
    print(f"[{layout}] mesh={dict(mesh.shape)} grad_accum={grad_accum}")
    res = run(step_fn, params, opt_state, asi, data,
              TrainLoopCfg(total_steps=total_steps, ckpt_dir=ckpt_dir,
                           ckpt_every=4, log_every=4),
              hooks={"on_log": lambda s, m:
                     print(f"  step {s:3d}  loss {m['loss']:.4f}")},
              plan=plan)
    return res


def main():
    assert len(jax.devices()) == 8, "XLA_FLAGS must be set before jax import"
    with tempfile.TemporaryDirectory() as ckpt_dir:
        # Leg 1: tensor-parallel 2x4 mesh, 2 microbatches per step.
        res = train_leg("tp", (2, 4), ckpt_dir, total_steps=8, grad_accum=2)
        print(f"leg 1 done at step {res.step} "
              f"(checkpoint saved on the 2x4 mesh)")
        # Leg 2: resume that checkpoint on a pure-dp 8x1 mesh.
        res = train_leg("dp", (8, 1), ckpt_dir, total_steps=16)
        print(f"leg 2 resumed and finished at step {res.step}")
        final = res.history[-1]["loss"]
        print(f"final loss {final:.4f}")
        assert res.step == 16 and final < 5.0
        print("OK: layout-free checkpoint resumed across mesh shapes")


if __name__ == "__main__":
    main()
