"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (required: smoke tests must see 1 device; only dryrun.py
sets the 512-placeholder-device XLA flag before importing jax).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi-pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Generic helper for reduced meshes in tests (e.g. (2,2) on 4 host
    devices)."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
