"""Scenario launcher — a thin argparse shim over ``repro.scenarios``.

Streamed continual-learning evaluation (serve→adapt→swap with forgetting
curves) as one command:

  PYTHONPATH=src python -m repro.launch.scenarios --scenario domain-shift \
      --arch tinyllama-1.1b --reduced --mem-budget-mb 0.05 --seed 0 \
      --out /tmp/curves.json

Output is JSON lines (config echo, then the summary); ``--out`` writes the
full deterministic curve series.  All wiring lives in
``repro.scenarios.run_scenario``; embed that, not ``main()``.
"""
from __future__ import annotations

import argparse
import json

from repro import api
from repro.scenarios import REPLAY_POLICIES, SCENARIOS, run_scenario


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        epilog="Full flag matrix: README.md; subsystem design: DESIGN.md §10")
    api.add_arch_argument(ap)
    ap.add_argument("--scenario", default="domain-shift", choices=SCENARIOS)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-sized config (--no-reduced = full arch)")
    ap.add_argument("--phases", type=int, default=2,
                    help="task phases (domain-shift/bursty override this)")
    ap.add_argument("--waves-per-phase", type=int, default=2,
                    help="request-injection steps per phase")
    ap.add_argument("--rate", type=float, default=3.0,
                    help="Poisson mean arrivals per wave")
    ap.add_argument("--mem-budget-mb", type=float, default=0.05)
    ap.add_argument("--budget-schedule", type=float, nargs="+", default=None,
                    help="per-phase budgets (elastic: triggers replanning)")
    ap.add_argument("--drift-threshold", type=float, default=0.2,
                    help="measured-vs-analytic ledger drift replan trigger")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--adapt-every", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--replay-policy", default="fifo",
                    choices=sorted(REPLAY_POLICIES))
    ap.add_argument("--replay-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write report.curves() JSON here")
    api.add_telemetry_arguments(ap)
    return ap


# launcher-only flags that are not ScenarioCfg fields
_NON_CFG = ("out", "telemetry", "profile_trace")


def main(argv=None):
    api.warn_programmatic_use(__name__, argv)
    args = build_parser().parse_args(argv)
    kw = {k: v for k, v in vars(args).items()
          if k not in _NON_CFG and v is not None}
    kw["budget_schedule"] = (tuple(args.budget_schedule)
                             if args.budget_schedule else None)
    print(json.dumps({"config": kw | {"budget_schedule":
                                      args.budget_schedule}}))
    with api.telemetry_recorder(args) as rec:
        report = run_scenario(telemetry=rec, **kw)
        print(json.dumps({"summary": report.summary()}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.curves(), f, indent=1)
        print(json.dumps({"out": args.out}))
    return report


if __name__ == "__main__":
    main()
