"""Parity + gradient-semantics tests for the fused ASI kernel pipeline.

Three layers of guarantees:

1. Kernel parity — ``matmul_sketch`` (fwd) and ``matmul_grad_sketch`` (bwd)
   in interpret mode match the pure-jnp oracles across shapes that are and
   are not multiples of the 128-lane blocking, in fp32 and bf16.
2. Dispatch policy — the backend flag resolves as documented on this host
   and rejects typos at call time.
3. Gradient semantics — ``asi_linear`` routed through dispatch produces
   bit-identical g_x to ``jax.grad`` of the dense layer (reference backend)
   and the paper's Q·(P̂ᵀg) weight gradient on every backend, so the
   custom_vjp rewiring cannot silently change training math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asi import MatrixASIState, orthonormalize
from repro.core.compressed_linear import (GroupedASIState,
                                          LinearCompressionCfg, asi_linear,
                                          dense_linear, grouped_asi_linear)
from repro.kernels import dispatch, ops, ref

KEY = jax.random.PRNGKey(11)

# shapes that exercise both the aligned fast path and the zero-padding
# wrappers (M/K/N multiples of 128 and deliberately ragged ones)
SHAPES = [
    (128, 128, 128, 8),      # exact single block
    (256, 128, 256, 16),     # multi-block, aligned
    (100, 70, 50, 8),        # everything ragged
    (130, 300, 136, 20),     # ragged + multi-block reduction
    (64, 256, 40, 4),        # tall-K, narrow-N
]
TOLS = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2}


def _rand(ks, m, k, n, r, dtype):
    x = jax.random.normal(ks[0], (m, k), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (k, n)) * 0.1).astype(dtype)
    v = jax.random.normal(ks[2], (k, r), jnp.float32).astype(dtype)
    return x, w, v


# ---------------------------------------------------------------------------
# 1. kernel parity (interpret mode == the TPU program, run on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,r", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_sketch_parity(m, k, n, r, dtype):
    x, w, v = _rand(jax.random.split(KEY, 3), m, k, n, r, dtype)
    y, p = ops.matmul_sketch(x, w, v)
    y0, p0 = ref.matmul_sketch_ref(x, w, v)
    tol = TOLS[dtype]
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               atol=tol * k, rtol=tol)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p0),
                               atol=tol * k, rtol=tol)


@pytest.mark.parametrize("m,k,n,r", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_backward_grad_sketch_parity(m, k, n, r, dtype):
    ks = jax.random.split(KEY, 3)
    g = jax.random.normal(ks[0], (m, n), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (k, n)) * 0.1).astype(dtype)
    p_hat = jax.random.normal(ks[2], (m, r), jnp.float32).astype(dtype)
    gx, rmat = ops.matmul_grad_sketch(g, w, p_hat)
    gx0, rmat0 = ref.matmul_grad_sketch_ref(g, w, p_hat)
    tol = TOLS[dtype]
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(gx0, np.float32),
                               atol=tol * n, rtol=tol)
    np.testing.assert_allclose(np.asarray(rmat), np.asarray(rmat0),
                               atol=tol * m, rtol=tol)


def test_backward_kernel_zero_padding_exact():
    """Padding rows/cols must contribute exact zeros: the kernel on ragged
    inputs must agree BITWISE with the kernel on manually zero-padded aligned
    inputs (both run the same fp32-accumulating program)."""
    m, k, n, r = 100, 70, 50, 8
    ks = jax.random.split(KEY, 3)
    g = jax.random.normal(ks[0], (m, n))
    w = jax.random.normal(ks[1], (k, n))
    p_hat = jax.random.normal(ks[2], (m, r))
    gx, rmat = ops.matmul_grad_sketch(g, w, p_hat)
    assert gx.shape == (m, k) and rmat.shape == (r, n)
    gp = jnp.pad(g, ((0, 128 - m), (0, 128 - n)))
    wp = jnp.pad(w, ((0, 128 - k), (0, 128 - n)))
    pp = jnp.pad(p_hat, ((0, 128 - m), (0, 0)))
    gx_pad, rmat_pad = ops.matmul_grad_sketch(gp, wp, pp)
    np.testing.assert_array_equal(np.asarray(gx),
                                  np.asarray(gx_pad[:m, :k]))
    np.testing.assert_array_equal(np.asarray(rmat),
                                  np.asarray(rmat_pad[:, :n]))


# ---------------------------------------------------------------------------
# 2. dispatch policy
# ---------------------------------------------------------------------------

def test_dispatch_resolution():
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve("reference") == "reference"
    assert dispatch.resolve("pallas") == ("pallas" if on_tpu else "interpret")
    assert dispatch.resolve("auto") == ("pallas" if on_tpu else "reference")
    with pytest.raises(ValueError, match="kernel_backend"):
        dispatch.resolve("cuda")


def test_grad_sketch_large_n_falls_back_to_reference():
    """Past the VMEM R-strip cap, kernel modes must fall back to the
    reference contraction at trace time instead of failing to fit."""
    n = dispatch.GRAD_SKETCH_MAX_N + 128
    ks = jax.random.split(KEY, 3)
    g = jax.random.normal(ks[0], (8, n))
    w = jax.random.normal(ks[1], (16, n)) * 0.1
    p_hat = jax.random.normal(ks[2], (8, 4))
    gx, r = dispatch.matmul_grad_sketch(g, w, p_hat, backend="pallas")
    gx0, r0 = ref.matmul_grad_sketch_ref(g, w, p_hat)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                               atol=1e-4 * n, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r0),
                               atol=1e-4 * n, rtol=1e-4)


def test_dispatch_backends_agree():
    x, w, v = _rand(jax.random.split(KEY, 3), 96, 80, 72, 8, jnp.float32)
    y_r, p_r = dispatch.matmul_sketch(x, w, v, backend="reference")
    y_p, p_p = dispatch.matmul_sketch(x, w, v, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_p),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(p_r), np.asarray(p_p),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# 3. gradient semantics through asi_linear / grouped_asi_linear
# ---------------------------------------------------------------------------

def _asi_grads(backend, x, w, b, state):
    cfg = LinearCompressionCfg(rank=state.q.shape[-1], backend=backend)

    def loss(x, w, b):
        y, _ = asi_linear(cfg, x, w, b, state)
        return jnp.sum(y * y)

    return jax.grad(loss, argnums=(0, 1, 2))(x, w, b)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_asi_linear_gx_matches_dense_grad(backend):
    """g_x is exact (eq. 2): identical contraction to the dense layer's
    jax.grad — bitwise on the reference backend, fp32-tolerance through the
    interpret kernel."""
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (4, 33, 72))         # ragged seq on purpose
    w = jax.random.normal(ks[1], (72, 56)) * 0.05
    b = jax.random.normal(ks[2], (56,)) * 0.01
    state = MatrixASIState.init(ks[3], 72, 8)

    def dense_loss(x, w, b):
        return jnp.sum(dense_linear(x, w, b) ** 2)

    gx_d, _, gb_d = jax.grad(dense_loss, argnums=(0, 1, 2))(x, w, b)
    gx, _, gb = _asi_grads(backend, x, w, b, state)
    if backend == "reference":
        np.testing.assert_array_equal(np.asarray(gx), np.asarray(gx_d))
    else:
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                                   atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_d), atol=1e-4)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_asi_linear_gw_is_low_rank_estimate(backend):
    """g_w equals the paper's Q·(P̂ᵀg) with (P̂, Q) from Algorithm 2."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (6, 16, 48))
    w = jax.random.normal(ks[1], (48, 40)) * 0.05
    state = MatrixASIState.init(ks[2], 48, 8)
    cfg = LinearCompressionCfg(rank=8, backend=backend)

    def loss(w):
        y, _ = asi_linear(cfg, x, w, None, state)
        return jnp.sum(y * y)

    gw = jax.grad(loss)(w)
    # hand-rolled Algorithm 2 + low-rank contraction, straight-line jnp
    x2d = x.reshape(-1, 48)
    p_hat = orthonormalize(
        jnp.dot(x2d, state.q, preferred_element_type=jnp.float32))
    q = x2d.T @ p_hat
    g = 2.0 * (x2d @ w)
    gw0 = q @ (p_hat.T @ g)
    tol = 1e-4 if backend == "reference" else 1e-3
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw0),
                               atol=tol * x2d.shape[0], rtol=tol)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_grouped_asi_linear_backends_consistent(backend):
    """Per-expert (MoE) path: fused grouped kernels keep the same gradients
    as the einsum reference formulation."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (3, 24, 32))
    w = jax.random.normal(ks[1], (3, 32, 28)) * 0.1
    state = GroupedASIState.init(ks[2], 3, 32, 4)
    cfg = LinearCompressionCfg(rank=4, backend=backend)
    ref_cfg = LinearCompressionCfg(rank=4, backend="reference")

    def loss(cfg_, x, w):
        y, _ = grouped_asi_linear(cfg_, x, w, state)
        return jnp.sum(y * y)

    gx0, gw0 = jax.grad(lambda x, w: loss(ref_cfg, x, w),
                        argnums=(0, 1))(x, w)
    gx, gw = jax.grad(lambda x, w: loss(cfg, x, w), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw0),
                               atol=1e-3, rtol=1e-3)


def test_asi_linear_state_threading_unchanged():
    """The rewiring must not alter the warm-start contract: new_state.q is
    Xᵀ·orth(X·Q_prev), ready to seed the next step."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (64, 32))
    w = jax.random.normal(ks[1], (32, 24)) * 0.1
    state = MatrixASIState.init(ks[2], 32, 4)
    cfg = LinearCompressionCfg(rank=4, backend="reference")
    _, new_state = asi_linear(cfg, x, w, None, state)
    p_hat = orthonormalize(
        jnp.dot(x, state.q, preferred_element_type=jnp.float32))
    np.testing.assert_allclose(np.asarray(new_state.q),
                               np.asarray(x.T @ p_hat),
                               atol=1e-5, rtol=1e-5)
