"""Fused forward-matmul + ASI-sketch Pallas TPU kernels (fwd and bwd).

ASI's per-step cost on TPU is not FLOPs (the sketch is a tall-skinny matmul,
cheap on the MXU) but HBM traffic: unfused, X (M, K) is streamed from HBM once
for Y = X·W and again for P = X·V.  ``matmul_sketch`` computes both in ONE
pass: each (bm, bk) VMEM tile of X feeds the Y-accumulator and, on the n == 0
grid column, the P-accumulator.  Arithmetic intensity of the sketch becomes
infinite (zero extra HBM reads), which is the TPU-native formulation of the
paper's Algorithm 2 (see DESIGN.md §3).

``matmul_grad_sketch`` is the backward-pass twin: unfused, the output
cotangent g (M, N) is streamed once for the exact input gradient
g_x = g·Wᵀ and again for the rank-r reduction R = P̂ᵀ·g that feeds the
paper's low-rank weight gradient g_w = Q·R.  Fused, each g tile feeds both
accumulators, so g crosses the HBM boundary exactly once (DESIGN.md §3).

Blocking: (bm, bn, bk) multiples of 128 keep the 128x128 MXU systolic array
full; the r (rank) dimension is zero-padded to the lane width by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(x_ref, w_ref, v_ref, y_ref, p_ref, acc_ref, pacc_ref, *, nk: int):
    k = pl.program_id(2)
    n = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(n == 0)
    def _sketch():
        @pl.when(k == 0)
        def _pinit():
            pacc_ref[...] = jnp.zeros_like(pacc_ref)
        pacc_ref[...] += jnp.dot(x, v_ref[...],
                                 preferred_element_type=jnp.float32)
        @pl.when(k == nk - 1)
        def _pout():
            p_ref[...] = pacc_ref[...]

    @pl.when(k == nk - 1)
    def _out():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_sketch(x: Array, w: Array, v: Array, *, bm: int = 128,
                  bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """Returns (Y = X·W in x.dtype, P = X·V in fp32).

    x (M, K), w (K, N), v (K, r).  Dims are zero-padded to block multiples;
    padding contributes exact zeros so results are unaffected.
    """
    m, k = x.shape
    _, n = w.shape
    r = v.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    pr = (-r) % 128 if r % 128 else 0
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk or pr:
        v = jnp.pad(v, ((0, pk), (0, pr)))
    mm, nn, kk = x.shape[0], w.shape[1], x.shape[1]
    rr = v.shape[1]
    nk = kk // bk
    grid = (mm // bm, nn // bn, nk)

    y, p = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk_: (i, kk_)),
            pl.BlockSpec((bk, bn), lambda i, j, kk_: (kk_, j)),
            pl.BlockSpec((bk, rr), lambda i, j, kk_: (kk_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk_: (i, j)),
            pl.BlockSpec((bm, rr), lambda i, j, kk_: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), x.dtype),
            jax.ShapeDtypeStruct((mm, rr), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, rr), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, v)
    return y[:m, :n], p[:m, :r]


def _grad_kernel(g_ref, w_ref, p_ref, gx_ref, r_ref, acc_ref, *,
                 nl: int, bn: int):
    """Dual-accumulator backward: the two products reduce over DIFFERENT dims
    (g_x over N, R over M), so g_x uses a per-(i, j) tile accumulator reset on
    the innermost (l over N) axis, while R accumulates directly into its
    output block — mapped to the SAME (r, N_pad) block on every grid step, so
    it lives in VMEM for the whole grid and is flushed to HBM exactly once."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...]
    # g_x tile:  g (bm, bn) · wᵀ (bn, bk)  — contract the shared N dim.
    acc_ref[...] += jax.lax.dot_general(
        g, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _sketch():
        # R strip column l:  P̂ᵀ (r, bm) · g (bm, bn), accumulated over i.
        contrib = jax.lax.dot_general(
            p_ref[...], g, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        col = pl.dslice(l * bn, bn)

        @pl.when(i == 0)
        def _rinit():
            r_ref[:, col] = contrib

        @pl.when(i > 0)
        def _racc():
            r_ref[:, col] += contrib

    @pl.when(l == nl - 1)
    def _out():
        gx_ref[...] = acc_ref[...].astype(gx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_grad_sketch(g: Array, w: Array, p_hat: Array, *, bm: int = 128,
                       bk: int = 128, bn: int = 128,
                       interpret: bool = False):
    """Returns (g_x = g·Wᵀ in g.dtype, R = P̂ᵀ·g in fp32) in one pass over g.

    g (M, N), w (K, N) — note: same layout as the forward weight —
    p_hat (M, r).  Dims are zero-padded to block multiples; padding
    contributes exact zeros.  The R accumulator holds a full (r_pad, N_pad)
    fp32 strip in VMEM (r_pad = 128), so N is bounded per call — callers go
    through ``dispatch.matmul_grad_sketch``, which falls back to the
    reference contraction when the strip would not fit (e.g. jamba's
    d_ff = 24576 down-projection).
    """
    m, n = g.shape
    k = w.shape[0]
    r = p_hat.shape[1]
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    pr = (-r) % 128 if r % 128 else 0
    if pm or pn:
        g = jnp.pad(g, ((0, pm), (0, pn)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pm or pr:
        p_hat = jnp.pad(p_hat, ((0, pm), (0, pr)))
    mm, nn, kk = g.shape[0], g.shape[1], w.shape[0]
    rr = p_hat.shape[1]
    nm, nl = mm // bm, nn // bn
    grid = (nm, kk // bk, nl)

    gx, rmat = pl.pallas_call(
        functools.partial(_grad_kernel, nl=nl, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (j, l)),
            pl.BlockSpec((bm, rr), lambda i, j, l: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, j)),
            pl.BlockSpec((rr, nn), lambda i, j, l: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, kk), g.dtype),
            jax.ShapeDtypeStruct((rr, nn), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bk), jnp.float32),
        ],
        interpret=interpret,
    )(g, w, p_hat)
    return gx[:m, :k], rmat[:r, :n]
