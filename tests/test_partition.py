"""Partition-rule tests (no multi-device needed: specs are pure data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.models import build_model
from repro.parallel import partition
from repro.parallel.sharding import safe_spec


class FakeMesh:
    """Shape-only stand-in (partition rules read mesh.shape/axis_names)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_safe_spec_drops_nondivisible():
    m = FakeMesh({"data": 4, "model": 16})
    assert safe_spec((8, 30), P("data", "model"), m) == P("data", None)
    assert safe_spec((7, 32), P("data", "model"), m) == P(None, "model")
    assert safe_spec((2,), P(("data", "model")), m) == P(None)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_every_leaf_and_divide(arch):
    cfg = get_config(arch)
    api = build_model(cfg)
    struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = partition.param_specs(cfg, struct, MESH)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(struct)
    assert len(flat_s) == len(flat_l)
    for leaf, spec in zip(flat_l, flat_s):
        assert isinstance(spec, P)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                size = MESH.shape[ax] if isinstance(ax, str) else \
                    int(np.prod([MESH.shape[a] for a in ax]))
                assert dim % size == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "moonshot-v1-16b-a3b"])
def test_big_matmul_weights_are_model_sharded(arch):
    """The TP axis must actually shard the big weights — replicated 6B+
    params would blow HBM; this guards against silent safe_spec fallbacks."""
    cfg = get_config(arch)
    api = build_model(cfg)
    struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = partition.param_specs(cfg, struct, MESH)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    sharded = {"/".join(str(getattr(p, 'key', p)) for p in path): spec
               for path, spec in flat}
    n_model_sharded = sum(1 for s in sharded.values() if "model" in tuple(s))
    assert n_model_sharded >= 5
    assert "model" in tuple(sharded["embed"])          # vocab sharded
    assert "model" in tuple(sharded["unembed"])


def test_opt_specs_mirror_params_adafactor():
    from repro.optim.optimizers import adafactor
    cfg = get_config("jamba-1.5-large-398b")
    api = build_model(cfg)
    struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    opt = adafactor(lambda s: 1e-3)
    ostruct = jax.eval_shape(opt.init, struct)
    ospecs = partition.opt_specs(cfg, ostruct, MESH_MP)
    for leaf, spec in zip(jax.tree_util.tree_leaves(ostruct),
                          jax.tree_util.tree_leaves(
                              ospecs, is_leaf=lambda x: isinstance(x, P))):
        assert len(tuple(spec)) == len(leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                size = MESH_MP.shape[ax] if isinstance(ax, str) else \
                    int(np.prod([MESH_MP.shape[a] for a in ax]))
                assert dim % size == 0


def test_cache_specs_kv_or_seq_sharded():
    cfg = get_config("internlm2-20b")     # kv=8 < model=16 -> seq sharding
    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(128, 1024))
    specs = partition.cache_specs(cfg, cache, MESH)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    for path, spec in flat:
        assert "model" in tuple(spec), path      # seq dim took the TP axis

    cfg2 = get_config("phi3-mini-3.8b")   # kv=32 divisible -> kv sharding
    api2 = build_model(cfg2)
    cache2 = jax.eval_shape(lambda: api2.init_cache(128, 1024))
    specs2 = partition.cache_specs(cfg2, cache2, MESH)
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs2, is_leaf=lambda x: isinstance(x, P))[0]:
        assert tuple(spec)[3] == "model", path   # kv-head dim sharded


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "jamba-1.5-large-398b"])
def test_param_specs_dp_layout_replicates_weights(arch):
    """Under --layout dp every parameter is replicated (no model/data axis
    in any spec) while the batch still shards over data — including for
    configs that set cfg.fsdp=True (jamba), which dp must override: it is
    the parity oracle."""
    cfg = get_config(arch)
    api = build_model(cfg)
    struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    partition.set_layout("dp")
    try:
        specs = partition.param_specs(cfg, struct, MESH)
        for spec in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)):
            assert all(ax is None for ax in tuple(spec)), spec
        assert partition.batch_axes(MESH) == "data"
        batch = {"tokens": jax.ShapeDtypeStruct((32, 64), jnp.int32)}
        bspecs = partition.batch_specs(cfg, batch, MESH)
        assert tuple(bspecs["tokens"])[0] == "data"
    finally:
        partition.set_layout("tp")


def test_fsdp_layout_shards_batch_over_all_axes():
    partition.set_layout("fsdp")
    try:
        assert partition.batch_axes(MESH) == ("data", "model")
        assert partition.batch_axes(MESH_MP) == ("pod", "data", "model")
    finally:
        partition.set_layout("tp")


def test_batch_specs_handle_batch_one():
    cfg = get_config("mamba2-130m")
    batch = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    specs = partition.batch_specs(cfg, batch, MESH)
    assert tuple(specs["tokens"])[0] is None     # b=1: replicate, don't crash
