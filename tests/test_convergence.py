"""E8: end-to-end convergence — ASI fine-tuning tracks vanilla fine-tuning
(the paper's accuracy-parity claim) on a learnable synthetic LM task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

STEPS = 40


def _train(compress: str, steps=STEPS, seed=0):
    cfg = get_config("tinyllama-1.1b").reduced().replace(
        n_layers=2, compress=compress, asi_rank=16, asi_last_k=1)
    api = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = api.init(key)
    st = api.init_asi(key) if compress != "none" else {}
    mask = api.trainable_mask(params) if compress != "none" else None
    opt = make_optimizer("adamw", lambda s: 2e-3, clip_norm=2.0)
    ostate = opt.init(params)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8, branching=2, seed=seed))

    @jax.jit
    def step(params, ostate, st, batch, i):
        def lossf(p):
            loss, (m, ns) = api.loss(p, batch, st if st else None)
            return loss, ns
        (loss, ns), g = jax.value_and_grad(lossf, has_aux=True)(params)
        params, ostate = opt.update(g, ostate, params, i, mask)
        return params, ostate, (ns if ns is not None else st), loss

    losses = []
    for i in range(steps):
        params, ostate, st, loss = step(params, ostate, st, data.batch(i),
                                        jnp.int32(i))
        losses.append(float(loss))
    return losses


def test_asi_finetune_tracks_vanilla_finetune():
    """Same tail fine-tuned: vanilla-stored activations vs ASI-compressed.
    ASI's approximate dW must not derail optimization (paper Fig. 4)."""
    # vanilla fine-tune of the same tail = compress-mode layout with exact
    # storage: emulate by hosvd at (near-)full rank
    vanilla = _train("none")
    asi = _train("asi")
    assert vanilla[-1] < vanilla[0]
    assert asi[-1] < asi[0]
    # parity within tolerance (ASI only fine-tunes the tail, vanilla trains
    # everything — tail-only training converges more slowly; require
    # meaningful progress, >30% of vanilla's improvement)
    gain_v = vanilla[0] - np.mean(vanilla[-5:])
    gain_a = asi[0] - np.mean(asi[-5:])
    assert gain_a > 0.3 * gain_v, (gain_a, gain_v)


def test_hosvd_and_asi_reach_similar_loss():
    asi = _train("asi")
    hosvd = _train("hosvd")
    assert abs(np.mean(asi[-5:]) - np.mean(hosvd[-5:])) < 0.35
