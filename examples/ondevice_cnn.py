"""On-device CNN fine-tuning (the paper's own setting): pretrain an
MCUNet-class model, then fine-tune the last-k convs on a NEW downstream task
(fresh class prototypes) under three regimes — exact stored activations
(vanilla fine-tune), ASI-compressed, HOSVD-compressed — and report accuracy +
stored-activation memory.  This is the paper's Fig. 4 protocol end-to-end.

  PYTHONPATH=src python examples/ondevice_cnn.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asi import tucker_storage_elems
from repro.data.synthetic import ImageStream, ImageStreamCfg
from repro.models import convnets
from repro.optim.optimizers import make_optimizer

PRETRAIN_STEPS = 70
FINETUNE_STEPS = 60
BATCH = 32
RANKS = (4, 4, 4, 4)


def _run(cfg, params, data, st, steps, lr=3e-3):
    opt = make_optimizer("adamw", lambda s: lr)
    ostate = opt.init(params)

    @jax.jit
    def step(params, ostate, st, batch):
        def lossf(p):
            loss, (m, ns) = convnets.loss_fn(p, batch, cfg, st)
            return loss, (m, ns)
        (loss, (m, ns)), g = jax.value_and_grad(lossf, has_aux=True)(params)
        params, ostate = opt.update(g, ostate, params, jnp.int32(0))
        return params, ostate, (ns if ns is not None else st), m["acc"]

    accs = []
    for i in range(steps):
        params, ostate, st, acc = step(params, ostate, st, data.batch(i))
        accs.append(float(acc))
    return params, float(np.mean(accs[-10:]))


def main():
    key = jax.random.PRNGKey(0)
    # 1) "ImageNet" pretraining (vanilla, all layers)
    base_cfg = convnets.mcunet_mini(num_classes=4)
    params = convnets.init_params(key, base_cfg)
    pretrain = ImageStream(ImageStreamCfg(num_classes=4, hw=32,
                                          global_batch=BATCH, noise=0.25,
                                          seed=0))
    params, acc0 = _run(base_cfg, params, pretrain, None, PRETRAIN_STEPS)
    print(f"pretrained backbone accuracy: {acc0:.3f}")

    # 2) downstream task: new prototypes (seed 7) — fine-tune last-2 convs
    downstream = ImageStream(ImageStreamCfg(num_classes=4, hw=32,
                                            global_batch=BATCH, noise=0.25,
                                            seed=7))
    act_shapes = convnets.activation_shapes(base_cfg, BATCH)
    rows = {}
    for mode, label in (("hosvd_full", "vanilla-ft"), ("asi", "asi-ft"),
                        ("hosvd", "hosvd-ft")):
        if mode == "hosvd_full":
            # full-rank HOSVD == exact stored activations == vanilla fine-tune
            comp, ranks = "hosvd", (BATCH, 1024, 64, 64)
        else:
            comp, ranks = mode, RANKS
        cfg = convnets.mcunet_mini(num_classes=4, compress=comp, last_k=2,
                                   ranks=ranks)
        st = (convnets.init_asi_state(key, cfg, batch=BATCH)
              if comp == "asi" else None)
        _, acc = _run(cfg, params, downstream, st, FINETUNE_STEPS)
        comp_idx = sorted(convnets._compressed_indices(cfg))
        stored = sum(
            min(tucker_storage_elems(act_shapes[i], ranks),
                int(np.prod(act_shapes[i])))
            for i in comp_idx) * 4 / 1024
        rows[label] = {"acc": acc, "act_kb": stored}
        print(f"{label:10s} acc={acc:.3f} stored-activations={stored:,.1f} KB")

    assert rows["vanilla-ft"]["acc"] > 0.5            # transfer works
    assert rows["asi-ft"]["acc"] > rows["vanilla-ft"]["acc"] - 0.15
    assert rows["asi-ft"]["act_kb"] < 0.1 * rows["vanilla-ft"]["act_kb"]
    print("ASI fine-tuning matches vanilla fine-tuning accuracy at a "
          "fraction of the activation memory — the paper's Fig. 4 effect.")


if __name__ == "__main__":
    main()
