"""Exporters for :class:`~repro.telemetry.record.Recorder` streams.

Two on-disk formats (DESIGN.md §13):

* **JSONL** — one event per line, schema-versioned (``"v": 1``), with a
  header line (``kind: "H"``) and a closing metrics footer (``kind:
  "M"``) carrying the aggregate snapshot and the ring-buffer drop count.
  ``python -m repro.telemetry out.jsonl`` validates a file against this
  schema (the CI e2e uses exactly that).
* **Chrome trace** — a ``{"traceEvents": [...]}`` JSON loadable in
  ``chrome://tracing`` / Perfetto.  Span begin/end pairs become complete
  (``ph: "X"``) slices, instants become ``ph: "i"`` marks, gauge samples
  become ``ph: "C"`` counter tracks.  Timestamps are microseconds.
"""
from __future__ import annotations

import json
from typing import Iterable, Optional

SCHEMA_VERSION = 1
KINDS = ("H", "B", "E", "I", "G", "M")
#: required fields per event kind (beyond "v" and "kind")
_REQUIRED = {
    "H": ("schema",),
    "B": ("ts", "name", "id", "parent"),
    "E": ("ts", "name", "id"),
    "I": ("ts", "name"),
    "G": ("ts", "name", "value"),
    "M": ("metrics", "dropped"),
}


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def jsonl_lines(rec) -> Iterable[str]:
    """Serialize a recorder as schema-v1 JSONL lines (header, events,
    metrics footer)."""
    yield json.dumps({"v": SCHEMA_VERSION, "kind": "H",
                      "schema": "repro.telemetry", "capacity": rec.capacity})
    for ev in rec.events:
        yield json.dumps({"v": SCHEMA_VERSION, **ev})
    yield json.dumps({"v": SCHEMA_VERSION, "kind": "M",
                      "metrics": rec.metrics(), "dropped": rec.dropped})


def export_jsonl(rec, path_or_file) -> None:
    if hasattr(path_or_file, "write"):
        for line in jsonl_lines(rec):
            path_or_file.write(line + "\n")
        return
    with open(path_or_file, "w") as f:
        for line in jsonl_lines(rec):
            f.write(line + "\n")


def validate_event(ev: dict, where: str = "") -> list[str]:
    """Schema check for one decoded JSONL line; returns error strings."""
    errs = []
    pre = f"{where}: " if where else ""
    if ev.get("v") != SCHEMA_VERSION:
        errs.append(f"{pre}bad schema version {ev.get('v')!r}")
    kind = ev.get("kind")
    if kind not in KINDS:
        errs.append(f"{pre}unknown kind {kind!r}")
        return errs
    for field in _REQUIRED[kind]:
        if field not in ev:
            errs.append(f"{pre}kind {kind} missing field {field!r}")
    if "ts" in ev and not isinstance(ev["ts"], (int, float)):
        errs.append(f"{pre}ts must be numeric")
    if kind == "M" and not isinstance(ev.get("metrics"), dict):
        errs.append(f"{pre}metrics must be an object")
    return errs


def read_jsonl(path_or_file) -> tuple[list[dict], dict, int]:
    """Parse + validate a JSONL export.  Returns ``(events, metrics,
    dropped)`` where events excludes the header/footer.  Raises
    ``ValueError`` on schema violations."""
    if hasattr(path_or_file, "read"):
        lines = path_or_file.read().splitlines()
    else:
        with open(path_or_file) as f:
            lines = f.read().splitlines()
    events: list[dict] = []
    metrics: dict = {}
    dropped = 0
    errs: list[str] = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"line {i + 1}: not JSON ({e})")
            continue
        errs.extend(validate_event(ev, where=f"line {i + 1}"))
        kind = ev.get("kind")
        if kind == "M":
            metrics = ev.get("metrics", {})
            dropped = ev.get("dropped", 0)
        elif kind in ("B", "E", "I", "G"):
            events.append(ev)
    if not lines:
        errs.append("empty stream")
    if errs:
        raise ValueError("; ".join(errs))
    return events, metrics, dropped


def validate_jsonl_file(path: str) -> tuple[list[str], dict]:
    """Non-raising wrapper used by ``python -m repro.telemetry``: returns
    ``(errors, summary)`` with per-kind event counts."""
    try:
        events, metrics, dropped = read_jsonl(path)
    except (ValueError, OSError) as e:
        return [str(e)], {}
    counts: dict = {}
    for ev in events:
        counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
    open_spans = sum(1 for e in events if e["kind"] == "B") \
        - sum(1 for e in events if e["kind"] == "E")
    return [], {"events": len(events), "by_kind": counts,
                "metrics": len(metrics), "dropped": dropped,
                "unclosed_spans": open_spans}


# ---------------------------------------------------------------------------
# Chrome trace
# ---------------------------------------------------------------------------

def _us(ts: float) -> float:
    return round(ts * 1e6, 3)


def chrome_trace(rec, process_name: str = "repro") -> dict:
    """Render the event ring as a Chrome/Perfetto trace object."""
    out = [{"ph": "M", "pid": 1, "name": "process_name",
            "args": {"name": process_name}}]
    open_by_id: dict[int, dict] = {}
    for ev in rec.events:
        kind = ev["kind"]
        if kind == "B":
            open_by_id[ev["id"]] = ev
        elif kind == "E":
            begin = open_by_id.pop(ev["id"], None)
            if begin is None:
                continue
            slice_ev = {"ph": "X", "pid": 1, "tid": 1,
                        "name": begin["name"], "ts": _us(begin["ts"]),
                        "dur": _us(ev["ts"] - begin["ts"])}
            if begin.get("attrs"):
                slice_ev["args"] = begin["attrs"]
            out.append(slice_ev)
        elif kind == "I":
            inst = {"ph": "i", "pid": 1, "tid": 1, "s": "t",
                    "name": ev["name"], "ts": _us(ev["ts"])}
            if ev.get("attrs"):
                inst["args"] = ev["attrs"]
            out.append(inst)
        elif kind == "G":
            out.append({"ph": "C", "pid": 1, "name": ev["name"],
                        "ts": _us(ev["ts"]),
                        "args": {"value": ev["value"]}})
    # spans still open when exported render as zero-length slices at
    # their begin timestamp rather than vanishing
    for begin in open_by_id.values():
        out.append({"ph": "X", "pid": 1, "tid": 1, "name": begin["name"],
                    "ts": _us(begin["ts"]), "dur": 0})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(rec, path_or_file: Optional[str] = None,
                        process_name: str = "repro") -> dict:
    trace = chrome_trace(rec, process_name=process_name)
    if path_or_file is None:
        return trace
    if hasattr(path_or_file, "write"):
        json.dump(trace, path_or_file)
    else:
        with open(path_or_file, "w") as f:
            json.dump(trace, f)
    return trace
