"""ASI-compressed 2-D convolution via ``jax.custom_vjp`` (paper §3, conv case).

Forward: exact ``lax.conv_general_dilated`` (NCHW / OIHW).  Residuals stored
for backward: the 4-mode Tucker factors of the input activation from one
warm-started subspace iteration (Algorithm 1) — core S (r1,r2,r3,r4) and
factors U1..U4 — instead of the full (B,C,H,W) tensor.

Backward ∂L/∂W follows the paper's eq. 15 contraction order so the FLOPs stay
low-rank (U2, the channel factor, is contracted LAST):

    G1 = Σ_b U1[b,r1]·g[b,·,·,·]                      r1·B·C'H'W'
    T  = S ×₃ U₃ ×₄ U₄                                 r1r2r3r4·H + r1r2r4·H·W
    dW_low[c',r2,kh,kw] = corr(T, G1)  (conv-as-vjp)   r1r2·C'H'W'·D²
    dW = dW_low ×_{r2} U₂                              r2·C'C·D²

∂L/∂x is exact (needs only W, paper eq. 2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.asi import TuckerASIState, tucker_asi_step, _mode_dot

Array = jax.Array
DIMS = ("NCHW", "OIHW", "NCHW")


@dataclasses.dataclass(frozen=True)
class ConvCompressionCfg:
    ranks: tuple[int, int, int, int]     # (r_B, r_C, r_H, r_W)
    stride: tuple[int, int] = (1, 1)
    padding: str = "SAME"


def conv2d(x: Array, w: Array, *, stride=(1, 1), padding="SAME") -> Array:
    return lax.conv_general_dilated(x, w, window_strides=stride,
                                    padding=padding, dimension_numbers=DIMS)


def _conv_input_grad(g: Array, w: Array, x_shape, stride, padding) -> Array:
    f = lambda x: conv2d(x, w, stride=stride, padding=padding)
    _, vjp = jax.vjp(f, jnp.zeros(x_shape, g.dtype))
    return vjp(g)[0]


def _conv_weight_grad(a: Array, g: Array, w_shape, stride, padding) -> Array:
    f = lambda w: conv2d(a, w, stride=stride, padding=padding)
    _, vjp = jax.vjp(f, jnp.zeros(w_shape, g.dtype))
    return vjp(g)[0]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def asi_conv2d(cfg: ConvCompressionCfg, x: Array, w: Array,
               state: TuckerASIState):
    y = conv2d(x, w, stride=cfg.stride, padding=cfg.padding)
    _, _, new_state = tucker_asi_step(x, state)
    return y, new_state


def _asi_conv_fwd(cfg, x, w, state):
    core, factors, new_state = tucker_asi_step(x, state)
    y = conv2d(x, w, stride=cfg.stride, padding=cfg.padding)
    res = (core, factors, w, x.shape)
    return (y, new_state), res


def _asi_conv_bwd(cfg, res, cts):
    g_y, _ = cts
    core, factors, w, x_shape = res
    u1, u2, u3, u4 = factors
    # exact input gradient
    g_x = _conv_input_grad(g_y, w, x_shape, cfg.stride, cfg.padding)
    # eq.-15 low-rank weight gradient
    g1 = jnp.einsum("br,bohw->rohw", u1.astype(g_y.dtype), g_y)        # (r1,C',H',W')
    t = _mode_dot(_mode_dot(core, u3, 2), u4, 3)                        # (r1,r2,H,W)
    t = t.astype(g_y.dtype)
    c_out = w.shape[0]
    dw_low_shape = (c_out, t.shape[1]) + w.shape[2:]                    # (C', r2, D, D)
    dw_low = _conv_weight_grad(t, g1, dw_low_shape, cfg.stride, cfg.padding)
    g_w = jnp.einsum("orhw,cr->ochw", dw_low, u2.astype(dw_low.dtype))
    g_state = jax.tree.map(jnp.zeros_like, TuckerASIState(factors=factors))
    return g_x, g_w.astype(w.dtype), g_state


asi_conv2d.defvjp(_asi_conv_fwd, _asi_conv_bwd)


# ---------------------------------------------------------------------------
# HOSVD fixed-rank conv (baseline) — same storage/backward, SVD every step.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def hosvd_conv2d(cfg: ConvCompressionCfg, x: Array, w: Array):
    return conv2d(x, w, stride=cfg.stride, padding=cfg.padding)


def _unfold(a, m):
    perm = (m,) + tuple(i for i in range(a.ndim) if i != m)
    return jnp.transpose(a, perm).reshape(a.shape[m], -1)


def _hosvd_conv_fwd(cfg, x, w):
    factors = []
    for m in range(4):
        u, _, _ = jnp.linalg.svd(_unfold(x, m).astype(jnp.float32),
                                 full_matrices=False)
        r = min(cfg.ranks[m], u.shape[1])
        factors.append(u[:, :r].astype(x.dtype))
    core = x
    for m, u in enumerate(factors):
        core = _mode_dot(core, u.T, m)
    y = conv2d(x, w, stride=cfg.stride, padding=cfg.padding)
    return y, (core, tuple(factors), w, x.shape)


def _hosvd_conv_bwd(cfg, res, g_y):
    core, factors, w, x_shape = res
    u1, u2, u3, u4 = factors
    g_x = _conv_input_grad(g_y, w, x_shape, cfg.stride, cfg.padding)
    g1 = jnp.einsum("br,bohw->rohw", u1.astype(g_y.dtype), g_y)
    t = _mode_dot(_mode_dot(core, u3, 2), u4, 3).astype(g_y.dtype)
    c_out = w.shape[0]
    dw_low = _conv_weight_grad(t, g1, (c_out, t.shape[1]) + w.shape[2:],
                               cfg.stride, cfg.padding)
    g_w = jnp.einsum("orhw,cr->ochw", dw_low, u2.astype(dw_low.dtype))
    return g_x, g_w.astype(w.dtype)


hosvd_conv2d.defvjp(_hosvd_conv_fwd, _hosvd_conv_bwd)
