"""Logical-axis sharding: models annotate tensors with logical names; a
rules context maps names to mesh axes (t5x/MaxText style), so the same model
code runs on a laptop (no rules -> no-op) and on a 512-chip multi-pod mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, Optional[str | tuple[str, ...]]]):
    """Activate a (mesh, logical->mesh-axis) mapping for model tracing."""
    prev = _current()
    _STATE.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.ctx = prev


def resolve(*names: Optional[str]) -> P:
    ctx = _current()
    if ctx is None:
        return P(*[None] * len(names))
    _, rules = ctx
    return P(*[rules.get(n) if n else None for n in names])


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def safe_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop spec entries that do not evenly divide the dim (keeps GSPMD happy
    and makes rules robust across the 40 arch x shape cells)."""
    out = []
    for i, axis in enumerate(spec):
        if axis is None:
            out.append(None)
            continue
        size = _mesh_axis_size(mesh, axis)
        out.append(axis if (i < len(shape) and shape[i] % size == 0) else None)
    return P(*out)


def logical_shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without active rules."""
    ctx = _current()
    if ctx is None or not hasattr(x, "shape"):
        return x
    mesh, _ = ctx
    spec = safe_spec(x.shape, resolve(*names), mesh)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Canonical rule sets ---------------------------------------------------------

def single_pod_rules() -> dict:
    return {
        "batch": "data", "fsdp": "data", "seq": None, "long_seq": "data",
        "model": "model", "heads": "model", "kv": "model", "mlp": "model",
        "vocab": "model", "experts": "model", "embed": None, "cache_seq": "model",
        "seq_tp": None,
    }


def multi_pod_rules() -> dict:
    return {
        "batch": ("pod", "data"), "fsdp": ("pod", "data"), "seq": None,
        "long_seq": "data", "model": "model", "heads": "model", "kv": "model",
        "mlp": "model", "vocab": "model", "experts": "model", "embed": None,
        "cache_seq": "model", "seq_tp": None,
    }


def fsdp_rules(multi_pod: bool) -> dict:
    """ZeRO-3 layout: every mesh axis shards batch/weights; no TP."""
    ba = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {"batch": ba, "fsdp": ba, "seq": None, "long_seq": "data",
            "model": None, "heads": None, "kv": None, "mlp": None,
            "vocab": None, "experts": None, "embed": None,
            "cache_seq": None, "seq_tp": None}


def rules_for(mesh: Mesh, layout: str = "tp") -> dict:
    if layout == "fsdp":
        return fsdp_rules("pod" in mesh.axis_names)
    return multi_pod_rules() if "pod" in mesh.axis_names else single_pod_rules()
