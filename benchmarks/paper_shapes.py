"""Layer-shape tables for the paper's own models (Tables 1/2, Figs. 4/5).

Shapes are the final standard convolutions of each architecture at ImageNet
resolution (B=64, the paper's mini-batch), counted from the model's end the
way the paper counts "#Layers".  These drive the closed-form cost model
(repro.core.flops) to reproduce the paper's Mem/GFLOPs columns.
"""
from repro.core.flops import ConvDims

B = 64

# (c_in, h, w, c_out, ksize, stride) — last 4 standard convs, end-first.
PAPER_MODELS = {
    "mobilenetv2": [
        ConvDims(B, 320, 7, 7, 1280, 1),       # final 1x1 expand
        ConvDims(B, 160, 7, 7, 960, 1),        # last inverted-residual pw
        ConvDims(B, 960, 7, 7, 160, 1),
        ConvDims(B, 160, 7, 7, 960, 1),
    ],
    "resnet18": [
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 256, 14, 14, 512, 3, stride=2),
    ],
    "resnet34": [
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 512, 7, 7, 512, 3),
        ConvDims(B, 512, 7, 7, 512, 3),
    ],
    "mcunet": [
        ConvDims(B, 160, 7, 7, 320, 1),        # final pointwise
        ConvDims(B, 160, 7, 7, 960, 1),
        ConvDims(B, 960, 7, 7, 160, 1),
        ConvDims(B, 96, 14, 14, 576, 1),
    ],
}

# the paper's ε=0.8 regime keeps very few components; rank-selection on real
# activations lands at single-digit ranks (Nguyen et al. 2024 Fig. energy).
ASI_RANKS = (4, 4, 4, 4)
RANK1 = (1, 1, 1, 1)
