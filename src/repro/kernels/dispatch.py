"""Backend dispatch for the fused ASI kernels.

One flag — ``ModelConfig.kernel_backend`` / ``LinearCompressionCfg.backend``
(``auto`` | ``pallas`` | ``reference``) — picks the execution mode for every
fused forward/backward sketch contraction:

* ``auto``       — compiled Pallas on TPU, pure-jnp reference elsewhere (XLA
                   fuses the jnp formulation well enough on CPU/GPU, and the
                   interpreter would be orders of magnitude slower).
* ``pallas``     — force the kernel code path: compiled on TPU,
                   ``interpret=True`` elsewhere (bit-for-bit the TPU program,
                   executed by the Pallas interpreter — this is what CI runs).
* ``reference``  — force the pure-jnp oracles from ``ref.py`` everywhere.

The reference backward uses exactly the same contraction XLA derives for the
dense layer's ``jax.grad``, so ``asi_linear`` under ``reference`` produces
bit-identical g_x to an uncompressed layer (tested in
tests/test_fused_asi_kernels.py).

Kernel modes cast the small side operands (sketch factor V, subspace P̂) to
the streamed operand's dtype: Mosaic requires matched MXU operand dtypes, and
the fp32 accumulators make the cast harmless at sketch ranks.  Grouped (MoE
per-expert) variants ``vmap`` the same kernels — Pallas lifts the expert dim
into an extra grid dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.asi_sketch import matmul_grad_sketch as _grad_kernel
from repro.kernels.asi_sketch import matmul_sketch as _fwd_kernel

Array = jax.Array

BACKENDS = ("auto", "pallas", "reference")

# The backward kernel keeps a grid-persistent (128, N_pad) fp32 R strip in
# VMEM; past this many output features the strip (plus double-buffered input
# blocks) would not fit the ~16 MB budget, so kernel modes fall back to the
# reference contraction for that call.  Shapes are static, so the choice is
# made at trace time, per linear.
GRAD_SKETCH_MAX_N = 16384


def resolve(backend: str = "auto") -> str:
    """Map the user flag to an execution mode: pallas | interpret | reference.

    Raises early on unknown flags so a config typo fails at trace time, not
    by silently training on a different code path.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"kernel_backend={backend!r}; expected one of {BACKENDS}")
    on_tpu = jax.default_backend() == "tpu"
    if backend == "reference":
        return "reference"
    if backend == "pallas":
        return "pallas" if on_tpu else "interpret"
    return "pallas" if on_tpu else "reference"


def matmul_sketch(x: Array, w: Array, v: Array, *, backend: str = "auto",
                  **kw):
    """Fused forward:  (Y = X·W in x.dtype, P = X·V in fp32), one pass over X."""
    mode = resolve(backend)
    if mode == "reference":
        # no downcast: x @ v promotes (bf16 x, fp32 v -> fp32 sketch), exactly
        # the pre-dispatch matrix_asi_step numerics.
        return ref.matmul_sketch_ref(x, w, v)
    kw.setdefault("interpret", mode == "interpret")
    return _fwd_kernel(x, w.astype(x.dtype), v.astype(x.dtype), **kw)


def matmul_grad_sketch(g: Array, w: Array, p_hat: Array, *,
                       backend: str = "auto", **kw):
    """Fused backward:  (g_x = g·Wᵀ in g.dtype, R = P̂ᵀ·g in fp32), one pass
    over g.  ``w`` is the forward-layout (K, N) weight."""
    mode = resolve(backend)
    w = w.astype(g.dtype)
    if mode == "reference" or g.shape[-1] > GRAD_SKETCH_MAX_N:
        # Same contraction (and dtype) jax.grad emits for the dense layer:
        # bit-identical g_x, plus the fp32 rank-r reduction.
        g_x = g @ w.T
        r = jnp.dot(p_hat.astype(g.dtype).T, g,
                    preferred_element_type=jnp.float32)
        return g_x, r
    kw.setdefault("interpret", mode == "interpret")
    return _grad_kernel(g, w, p_hat.astype(g.dtype), **kw)


def grouped_matmul_sketch(x: Array, w: Array, v: Array, *,
                          backend: str = "auto", **kw):
    """Per-expert fused forward: x (E, T, K), w (E, K, N), v (E, K, r)."""
    mode = resolve(backend)
    if mode == "reference":
        y = jnp.einsum("etk,ekn->etn", x, w.astype(x.dtype))
        p = jnp.einsum("etk,ekr->etr", x, v,
                       preferred_element_type=jnp.float32)
        return y, p
    kw.setdefault("interpret", mode == "interpret")
    return jax.vmap(lambda xe, we, ve: _fwd_kernel(xe, we, ve, **kw))(
        x, w.astype(x.dtype), v.astype(x.dtype))


def grouped_matmul_grad_sketch(g: Array, w: Array, p_hat: Array, *,
                               backend: str = "auto", **kw):
    """Per-expert fused backward: g (E, T, N), w (E, K, N), p_hat (E, T, r)."""
    mode = resolve(backend)
    w = w.astype(g.dtype)
    if mode == "reference":
        g_x = jnp.einsum("etn,ekn->etk", g, w)
        r = jnp.einsum("etr,etn->ern", p_hat.astype(g.dtype), g,
                       preferred_element_type=jnp.float32)
        return g_x, r
    if g.shape[-1] > GRAD_SKETCH_MAX_N:
        return grouped_matmul_grad_sketch(g, w, p_hat, backend="reference")
    kw.setdefault("interpret", mode == "interpret")
    return jax.vmap(lambda ge, we, pe: _grad_kernel(ge, we, pe, **kw))(
        g, w, p_hat.astype(g.dtype))
