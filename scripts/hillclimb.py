"""§Perf hillclimb driver: the three chosen cells, one variant per subprocess,
appending to results/hillclimb.jsonl.  Run after the baseline sweep.

Cells (chosen per the spec from the baseline table):
  A. tinyllama-1.1b x train_4k   — most representative of the paper (ASI
     fine-tuning is literally the paper's Table-4 workload).
  B. internlm2-20b  x train_4k   — most collective-bound baseline
     (584 GB/device of TP all-reduces).
  C. moonshot-v1-16b-a3b x decode_32k — worst roofline fraction
     (MoE decode reads every expert's weights for 128 tokens).
"""
import json
import os
import subprocess
import sys
import time

OUT = "results/hillclimb.jsonl"

VARIANTS = [
    # (label, arch, shape, extra dryrun args, hypothesis)
    ("A1_asi", "tinyllama-1.1b", "train_4k", ["--compress", "asi"],
     "ASI tail fine-tune: frozen prefix stores nothing, tail stores rank-20 "
     "factors -> memory term down ~10x, compute term down ~2.5x vs full "
     "training (fwd + tail-only bwd)"),
    ("A2_asi_noremat", "tinyllama-1.1b", "train_4k",
     ["--compress", "asi", "--remat", "none"],
     "with a frozen prefix there is nothing to rematerialize: dropping "
     "remat removes the recompute fwd pass -> compute term -25%"),
    ("A3_asi_bf16", "tinyllama-1.1b", "train_4k",
     ["--compress", "asi", "--remat", "none", "--param-dtype", "bfloat16"],
     "bf16 params halve weight-pass HBM traffic -> memory term down ~2x"),
    ("B1_fsdp", "internlm2-20b", "train_4k", ["--layout", "fsdp"],
     "replace TP activation all-reduces (~584 GB/dev) with FSDP weight "
     "all-gathers (~3 passes x 80 GB = 240 GB/dev) -> collective term ~2.4x "
     "down"),
    ("B2_fsdp_dots", "internlm2-20b", "train_4k",
     ["--layout", "fsdp", "--remat", "dots"],
     "dots remat saves matmul outputs -> backward re-gathers fewer weights "
     "-> collective term down another ~25% (memory term up)"),
    ("B3_seqtp", "internlm2-20b", "train_4k", ["--seq-tp"],
     "Megatron sequence parallelism: RS+AG instead of AR halves TP bytes "
     "(REFUTED on the 2x2 probe: GSPMD added reshards; verify at 16x16)"),
    ("C1_bf16", "moonshot-v1-16b-a3b", "decode_32k",
     ["--param-dtype", "bfloat16"],
     "decode is weight-read bound: bf16 params halve the memory term -> "
     "roofline fraction ~2x up"),
    ("C2_bf16_asi", "moonshot-v1-16b-a3b", "decode_32k",
     ["--param-dtype", "bfloat16", "--compress", "asi"],
     "control: serve_step has no backward, ASI must not change decode terms"),
]


def main():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("REPRO_XLA_FLAGS", None)
    only = sys.argv[1:] or None
    for label, arch, shape, extra, hyp in VARIANTS:
        if only and not any(label.startswith(o) for o in only):
            continue
        t0 = time.time()
        args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                "--shape", shape, "--out", OUT] + extra
        p = subprocess.run(args, env=env, capture_output=True, text=True,
                           timeout=5400)
        ok = p.returncode == 0
        # annotate the last line with the label + hypothesis
        if ok and os.path.exists(OUT):
            with open(OUT) as f:
                lines = f.read().splitlines()
            d = json.loads(lines[-1])
            d["label"] = label
            d["hypothesis"] = hyp
            lines[-1] = json.dumps(d, default=str)
            with open(OUT, "w") as f:
                f.write("\n".join(lines) + "\n")
        print(f"{label:16s} {'ok' if ok else 'FAIL'} {time.time()-t0:5.0f}s",
              flush=True)
        if not ok:
            print(p.stdout[-800:], p.stderr[-500:], flush=True)


if __name__ == "__main__":
    main()
