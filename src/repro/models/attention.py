"""GQA attention: chunked online-softmax (flash-style) training/prefill path,
single-token decode path with (optionally ring-buffered SWA) KV cache, and
cross-attention for the encoder-decoder family.

The chunked path is the pure-JAX reference implementation of the Pallas
flash-attention kernel in ``repro/kernels`` — same math, same blocking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compressed_linear import (LinearCompressionCfg, asi_linear,
                                          dense_linear, hosvd_linear)
from repro.models.layers import apply_rope, initializer, rope_tables
from repro.parallel.sharding import logical_shard

Array = jax.Array
NEG_INF = -1e30


def attn_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    p = {
        "wq": initializer(k1, (d, h * hd), dtype),
        "wk": initializer(k2, (d, kv * hd), dtype),
        "wv": initializer(k3, (d, kv * hd), dtype),
        "wo": initializer(k4, (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.use_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _project(params, x, cfg, asi_state, new_state, names=("wq", "wk", "wv")):
    # output dims: wq -> heads*hd ("heads"), wk/wv -> kv*hd ("kv") — both
    # TP-sharded, so mesh-aware dispatch may size the VMEM cap per shard
    outs = []
    for n in names:
        ccfg = LinearCompressionCfg(rank=cfg.asi_rank,
                                    backend=cfg.kernel_backend,
                                    out_axis="heads" if n == "wq" else "kv")
        b = params.get("b" + n[1])
        if asi_state is not None and n in asi_state:
            if cfg.compress == "hosvd":
                y = hosvd_linear(ccfg, x, params[n], b)
                new_state[n] = asi_state[n]
            else:
                y, ns = asi_linear(ccfg, x, params[n], b, asi_state[n])
                new_state[n] = ns
        else:
            y = dense_linear(x, params[n], b)
        outs.append(y)
    return outs


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps blocking exact for any
    sequence length, e.g. VLM seq = text + image patches)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, q_chunk: int = 1024,
                      kv_chunk: int = 1024, q_offset=0) -> Array:
    """Online-softmax attention.

    q: (B, Sq, KV, G, hd);  k/v: (B, Skv, KV, hd).  Returns (B, Sq, KV, G, hd).
    ``q_offset`` is the absolute position of q[0] (for chunked prefill).
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    qb = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kv_chunk, KV, hd), 1, 0)

    def one_q_block(args):
        qi, q_blk = args                                  # q_blk (B,Cq,KV,G,hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, k_blk, v_blk = xs
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)                    # (B,Cq,KV,G,hd)

    outs = jax.lax.map(one_q_block, (jnp.arange(nq), qb))  # (nq,B,Cq,KV,G,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, hd).astype(q.dtype)


def attn_forward(params: dict, x: Array, cfg: ModelConfig,
                 positions: Array | None = None,
                 asi_state: dict | None = None,
                 enc_kv: tuple[Array, Array] | None = None,
                 causal: bool = True):
    """Full-sequence attention (training / prefill).

    Returns (y, new_asi_state, (k, v)) — k/v returned for cache priming.
    enc_kv: cross-attention keys/values (already projected & headed).
    """
    B, S, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    new_state: dict = {}
    if enc_kv is None:
        q, k, v = _project(params, x, cfg, asi_state, new_state)
        q = _split_heads(q, h, hd)
        k = _split_heads(k, kv, hd)
        v = _split_heads(v, kv, hd)
        if not cfg.learned_pos:
            if positions is None:
                positions = jnp.arange(S)[None, :]
            cos, sin = rope_tables(positions, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        (q,) = _project(params, x, cfg, asi_state, new_state, names=("wq",))
        q = _split_heads(q, h, hd)
        k, v = enc_kv
    q = q.reshape(B, S, kv, g, hd)
    q = logical_shard(q, "batch", None, "kv", None, None)
    k = logical_shard(k, "batch", None, "kv", None)
    v = logical_shard(v, "batch", None, "kv", None)
    o = chunked_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                          q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    o = o.reshape(B, S, h * hd)
    # wo's output dim is d_model — replicated under TP (out_axis=None keeps
    # the VMEM cap at the full width)
    ccfg = LinearCompressionCfg(rank=cfg.asi_rank, backend=cfg.kernel_backend,
                                out_axis=None)
    if asi_state is not None and "wo" in asi_state:
        if cfg.compress == "hosvd":
            y = hosvd_linear(ccfg, o, params["wo"], params.get("bo"))
            new_state["wo"] = asi_state["wo"]
        else:
            y, ns = asi_linear(ccfg, o, params["wo"], params.get("bo"),
                               asi_state["wo"])
            new_state["wo"] = ns
    else:
        y = dense_linear(o, params["wo"], params.get("bo"))
    return y, (new_state if asi_state is not None else None), (k, v)


def cross_kv(params: dict, enc_out: Array, cfg: ModelConfig):
    """Project encoder output once into cross-attention K/V heads."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = _split_heads(dense_linear(enc_out, params["wk"], params.get("bk")), kv, hd)
    v = _split_heads(dense_linear(enc_out, params["wv"], params.get("bv")), kv, hd)
    return k, v


def attn_decode(params: dict, x: Array, cache: dict, pos: Array,
                cfg: ModelConfig, cross: bool = False):
    """One-token decode.  x (B, 1, d); cache {'k','v'} (B, S_cache, KV, hd).

    ``pos`` is either a scalar (all rows at the same position — legacy path)
    or a (B,) vector of per-slot positions (continuous batching: each batch
    row is an independent request at its own depth).
    For SWA archs the cache is a ring buffer of ``sliding_window`` slots.
    Returns (y, new_cache).
    """
    B, _, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    if cross:
        (q,) = _project(params, x, cfg, None, {}, names=("wq",))
        q = _split_heads(q, h, hd)
        k, v = cache["k"], cache["v"]
        valid = jnp.ones((B, k.shape[1]), bool)
        new_cache = cache
    else:
        posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        rows = jnp.arange(B)
        q, k1, v1 = _project(params, x, cfg, None, {})
        q = _split_heads(q, h, hd)
        k1 = _split_heads(k1, kv, hd)
        v1 = _split_heads(v1, kv, hd)
        if not cfg.learned_pos:
            cos, sin = rope_tables(posb[:, None], hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k1 = apply_rope(k1, cos, sin)
        s_cache = cache["k"].shape[1]
        slot = posb % s_cache if cfg.sliding_window else posb
        if "k_scale" in cache:                       # int8 cache path
            k1q, k1s = _quantize_kv(k1)
            v1q, v1s = _quantize_kv(v1)
            kq = cache["k"].at[rows, slot].set(k1q[:, 0])
            vq = cache["v"].at[rows, slot].set(v1q[:, 0])
            ks = cache["k_scale"].at[rows, slot].set(k1s[:, 0])
            vs = cache["v_scale"].at[rows, slot].set(v1s[:, 0])
            new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            k = (kq.astype(jnp.float32) * ks).astype(x.dtype)
            v = (vq.astype(jnp.float32) * vs).astype(x.dtype)
        else:
            k = cache["k"].at[rows, slot].set(k1[:, 0])
            v = cache["v"].at[rows, slot].set(v1[:, 0])
            new_cache = {"k": k, "v": v}
        idx = jnp.arange(s_cache)
        if cfg.sliding_window:
            age = (slot[:, None] - idx[None, :]) % s_cache   # steps since written
            valid = (age < jnp.minimum(posb[:, None] + 1, s_cache))
        else:
            valid = idx[None, :] <= posb[:, None]
    q = q.reshape(B, 1, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, h * hd).astype(x.dtype)
    y = dense_linear(o, params["wo"], params.get("bo"))
    return y, new_cache


def attn_decode_paged(params: dict, x: Array, pool: dict, table: Array,
                      pos: Array, cfg: ModelConfig):
    """One-token decode against a block-paged KV pool.

    x (B, 1, d); pool {'k','v'[,scales]} of shape (n_blocks, bs, KV, hd);
    table (B, L) int32 physical-block ids (trash block 0 for unallocated
    entries — see ``runtime/paged_kv.py``); pos (B,) per-slot positions with
    ``L * bs == max_len``.  The gathered view then has exactly the dense
    cache's (B, max_len, KV, hd) shape, so the reference read path below is
    bit-identical to ``attn_decode`` on a dense cache (the parity contract in
    DESIGN.md §12).  Compiled/interpreted Pallas modes route the read through
    ``kernels/paged_attention`` instead (fp16/32 pools only — int8 pools
    dequantize on the gather path).  Returns (y, new_pool).
    """
    from repro.kernels import dispatch
    from repro.kernels.paged_attention import paged_attention

    B, _, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    rows = jnp.arange(B)
    q, k1, v1 = _project(params, x, cfg, None, {})
    q = _split_heads(q, h, hd)
    k1 = _split_heads(k1, kv, hd)
    v1 = _split_heads(v1, kv, hd)
    if not cfg.learned_pos:
        cos, sin = rope_tables(posb[:, None], hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k1 = apply_rope(k1, cos, sin)
    bs = pool["k"].shape[1]
    L = table.shape[1]
    phys = table[rows, posb // bs]          # physical block per slot
    off = posb % bs                         # position within the block
    if "k_scale" in pool:                   # int8 pool path
        k1q, k1s = _quantize_kv(k1)
        v1q, v1s = _quantize_kv(v1)
        new_pool = {"k": pool["k"].at[phys, off].set(k1q[:, 0]),
                    "v": pool["v"].at[phys, off].set(v1q[:, 0]),
                    "k_scale": pool["k_scale"].at[phys, off].set(k1s[:, 0]),
                    "v_scale": pool["v_scale"].at[phys, off].set(v1s[:, 0])}
        k = (new_pool["k"][table].astype(jnp.float32)
             * new_pool["k_scale"][table]).astype(x.dtype)
        v = (new_pool["v"][table].astype(jnp.float32)
             * new_pool["v_scale"][table]).astype(x.dtype)
        use_kernel = False
    else:
        new_pool = {"k": pool["k"].at[phys, off].set(k1[:, 0]),
                    "v": pool["v"].at[phys, off].set(v1[:, 0])}
        mode = dispatch.resolve(cfg.kernel_backend)
        use_kernel = mode != "reference"
    if use_kernel:
        q4 = q[:, 0].reshape(B, kv, g, hd)
        o = paged_attention(q4, new_pool["k"], new_pool["v"], table, posb,
                            interpret=(mode == "interpret"))
        o = o.reshape(B, 1, h * hd).astype(x.dtype)
    else:
        if "k_scale" not in pool:
            k, v = new_pool["k"][table], new_pool["v"][table]
        k = k.reshape(B, L * bs, kv, hd)
        v = v.reshape(B, L * bs, kv, hd)
        valid = jnp.arange(L * bs)[None, :] <= posb[:, None]
        q = q.reshape(B, 1, kv, g, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o.reshape(B, 1, h * hd).astype(x.dtype)
    y = dense_linear(o, params["wo"], params.get("bo"))
    return y, new_pool


def init_paged_kv_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                        dtype) -> dict:
    """Shared physical block pool for one attention layer.  Block 0 is the
    trash block every unallocated table entry points at."""
    shape = (n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:3] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    n = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, n, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        # per-(token, kv-head) scales: 1/hd memory overhead, 2x cache shrink
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:3] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x: Array):
    """x (B, S, KV, hd) -> (int8 values, per-(B,S,KV) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / jnp.maximum(scale, 1e-9)), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_cache(cache: dict) -> dict:
    """Convert a full-precision prefilled KV cache to the int8 layout."""
    k, ks = _quantize_kv(cache["k"])
    v, vs = _quantize_kv(cache["v"])
    return {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
