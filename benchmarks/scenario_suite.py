"""Continual-learning scenario suite: the domain-shift benchmark.

Runs the streamed serve→adapt→swap scenario (``repro.scenarios``) on the
reduced tinyllama config: phase 0 traffic from one Markov chain, a
transition-table swap, phase 1 traffic from the shifted chain, adaptation
bursts riding request retirement throughout.  Reports the quality-over-time
and per-phase forgetting-curve series (the figure the harness exists to
produce) plus the gates:

* **recovery** — the phase-1 probe loss falls while phase-1 traffic is live
  (the model actually adapts to the shifted domain);
* **forgetting bound** — the phase-0 probe ends within a loose bound of its
  best (replay keeps the old domain from collapsing);
* **determinism** — curves are pure in the seed (asserted run-to-run by
  tests/test_scenarios.py; the suite records the seed so any run is
  re-checkable).

Run:  PYTHONPATH=src python -m benchmarks.scenario_suite
"""
from __future__ import annotations

import json

from repro.scenarios import run_scenario

CONFIG = dict(scenario="domain-shift", arch="tinyllama_1_1b", reduced=True,
              seed=0, mem_budget_mb=0.05, waves_per_phase=3, rate=4.0,
              steps=32, adapt_every=2, burst_steps=2, batch=2, seq_len=16,
              prompt_lens=[10, 14], max_new=4, lr=0.01,
              replay_policy="fifo", replay_size=32)

FORGETTING_BOUND = 3.0       # loose: phase-0 probe may drift, not collapse


def run(verbose: bool = True) -> dict:
    report = run_scenario(**CONFIG)
    recovery = report.recovery(1)
    forgetting = report.forgetting(0)
    out = {
        "config": dict(CONFIG),
        "summary": report.summary(),
        "quality": [q["loss"] for q in report.quality],
        "burst_phase": report.burst_phase,
        "probe_curves": report.probe_curves,
        "recovery_phase1": recovery,
        "forgetting_phase0": forgetting,
        "recovered": recovery is not None and recovery > 0,
        "forgetting_bounded": (forgetting is not None
                               and forgetting < FORGETTING_BOUND),
    }
    if verbose:
        print(json.dumps({"summary": out["summary"]}))
        print(json.dumps({"forgetting_curves": out["probe_curves"],
                          "quality_over_time": out["quality"],
                          "burst_phase": out["burst_phase"]}))
        print(f"recovery(phase1)={recovery}  forgetting(phase0)={forgetting}"
              f"  recovered={out['recovered']}"
              f"  bounded={out['forgetting_bounded']}")
    return out


if __name__ == "__main__":
    out = run()
    assert out["recovered"], "quality did not recover after the domain shift"
    assert out["forgetting_bounded"], (
        f"phase-0 forgetting {out['forgetting_phase0']} exceeds "
        f"{FORGETTING_BOUND}")
