"""Analytic FLOPs model for every arch x shape cell.

Why this exists: XLA's HloCostAnalysis visits each computation once — a
while-loop body (our layer scan, attention kv-block scans, SSD chunk scans)
is counted ONCE regardless of trip count, so ``compiled.cost_analysis()``
under-reports FLOPs by ~n_layers x.  The dry-run unrolls the outer layer scan
(recovering per-layer collectives and most FLOPs), but inner chunk loops stay
rolled; this model counts exactly what the lowered code computes, matmul by
matmul (2·m·k·n convention), and is cross-checked against cost_analysis on
unrolled small configs in tests.

Counted = what the implementation executes, including its own waste:
full (mask-only) causal attention blocks in the jnp path, MoE capacity
padding, remat recompute.  "Useful" MODEL_FLOPS (6·N·D / 2·N·D) divided by
this number is exactly the §Roofline useful-compute ratio.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models.transformer import period_pattern as _tfm_period_pattern


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX releases.

    Older JAX returns one properties dict; current JAX returns a list with
    one dict per device program (entry computation first).  Either way the
    caller wants a plain dict — empty when analysis is unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def period_pattern(cfg: ModelConfig):
    if cfg.family == "encdec":
        return [("attn", "dense")]        # decoder block pattern
    return _tfm_period_pattern(cfg)


def _attn_flops(cfg: ModelConfig, b: int, sq: int, skv: int,
                cross: bool = False) -> float:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj_q = 2 * b * sq * d * h * hd
    proj_kv = 2 * b * skv * d * 2 * kv * hd
    if cross:
        proj_kv = 0.0            # cross K/V projected once; counted separately
    scores = 2 * b * h * sq * skv * hd
    pv = 2 * b * h * sq * skv * hd
    out = 2 * b * sq * h * hd * d
    return proj_q + proj_kv + scores + pv + out


def _mlp_flops(cfg: ModelConfig, tokens: float) -> float:
    n_mat = 3 if cfg.act == "silu" else 2
    return 2 * tokens * cfg.d_model * cfg.d_ff * n_mat


def _moe_flops(cfg: ModelConfig, tokens: float, rows: float = 1.0) -> float:
    """Grouped dispatch: each batch row pads to its own capacity multiple."""
    e, k = cfg.n_experts, cfg.experts_per_tok
    router = 2 * tokens * cfg.d_model * e
    per_row = tokens / max(rows, 1.0)
    cap_row = max(8.0, -(-per_row * k * cfg.capacity_factor / e // 8) * 8)
    expert = 2 * rows * e * cap_row * cfg.d_model * cfg.d_ff * 3
    return router + expert


def _mamba_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d, din = cfg.d_model, cfg.ssm_d_inner
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    nc = max(s // q, 1)
    in_proj = 2 * b * s * d * (2 * din + 2 * n + h)
    conv = 2 * b * s * cfg.ssm_conv_width * (din + 2 * n)
    att = 2 * b * nc * q * q * n                 # C·Bᵀ per chunk
    intra = 2 * b * nc * q * q * h * p           # scores x X
    inter = 2 * b * s * h * p * n                # C·h decode of carried state
    contrib = 2 * b * s * h * p * n              # state update outer products
    out_proj = 2 * b * s * din * d
    return in_proj + conv + att + intra + inter + contrib + out_proj


def _sublayer_fwd(cfg: ModelConfig, spec, b: int, s: int) -> float:
    mixer, ffn = spec
    t = b * s
    f = _attn_flops(cfg, b, s, s) if mixer == "attn" else _mamba_flops(cfg, b, s)
    if ffn == "dense":
        f += _mlp_flops(cfg, t)
    elif ffn == "moe":
        f += _moe_flops(cfg, t, rows=b)
    return f


def _lm_forward(cfg: ModelConfig, b: int, s: int) -> float:
    per_period = sum(_sublayer_fwd(cfg, spec, b, s)
                     for spec in period_pattern(cfg))
    n_p = cfg.n_layers // len(period_pattern(cfg))
    unembed = 2 * b * s * cfg.d_model * cfg.vocab_size
    return per_period * n_p + unembed


def _encdec_forward(cfg: ModelConfig, b: int, s: int) -> float:
    enc = cfg.n_enc_layers * (_attn_flops(cfg, b, cfg.enc_len, cfg.enc_len)
                              + _mlp_flops(cfg, b * cfg.enc_len))
    cross_kv_proj = cfg.n_layers * 2 * b * cfg.enc_len * cfg.d_model \
        * 2 * cfg.n_kv_heads * cfg.hd
    dec = cfg.n_layers * (_attn_flops(cfg, b, s, s)
                          + _attn_flops(cfg, b, s, cfg.enc_len, cross=True)
                          + _mlp_flops(cfg, b * s))
    unembed = 2 * b * s * cfg.d_model * cfg.vocab_size
    return enc + cross_kv_proj + dec + unembed


def forward_flops(cfg: ModelConfig, b: int, s: int) -> float:
    if cfg.family == "encdec":
        return _encdec_forward(cfg, b, s)
    return _lm_forward(cfg, b, s)


def _asi_tail_extra(cfg: ModelConfig, b: int, s: int) -> float:
    """Backward + sketch cost of the ASI fine-tuned tail (matrix variant):
    per wrapped linear (M, K)x(K, N): sketch 4MKr + dW low-rank
    2r(M+K)N + exact dX 2MKN."""
    t = float(b * s)
    r = cfg.asi_rank
    d, hd, h, kv, ff = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    linears = [(d, h * hd), (d, kv * hd), (d, kv * hd), (h * hd, d)]
    if cfg.act == "silu":
        linears += [(d, ff), (d, ff), (ff, d)]
    else:
        linears += [(d, ff), (ff, d)]
    total = 0.0
    for k_, n_ in linears:
        total += 4 * t * k_ * r + 2 * r * (t + k_) * n_ + 2 * t * k_ * n_
    # attention backward through scores/pv of the tail
    total += 2 * (2 * b * h * s * s * hd)
    n_tail = min(cfg.asi_last_k, cfg.n_layers)
    return total * n_tail * len(period_pattern(cfg))


def cell_flops(cfg: ModelConfig, shape: ShapeCfg, compress: str = "none"
               ) -> float:
    """Total executed FLOPs for one step of this cell (global, all chips)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        pass                                 # total seq already includes image
    if shape.kind == "train":
        fwd = forward_flops(cfg, b, s)
        if compress == "none":
            remat_extra = {"none": 0.0, "dots": 0.3, "full": 1.0,
                           "offload": 0.0}[cfg.remat]
            return fwd * (1.0 + remat_extra) + 2.0 * fwd
        return fwd + _asi_tail_extra(cfg, b, s)
    if shape.kind == "prefill":
        return forward_flops(cfg, b, s)
    # decode: one token against a cache of length s
    if cfg.family == "encdec":
        new_kv = 2 * b * cfg.d_model * 2 * cfg.n_kv_heads * cfg.hd
        f = cfg.n_layers * (_attn_flops(cfg, b, 1, s, cross=True) + new_kv
                            + _attn_flops(cfg, b, 1, cfg.enc_len, cross=True)
                            + _mlp_flops(cfg, b))
        return f + 2 * b * cfg.d_model * cfg.vocab_size
    total = 0.0
    skv = min(s, cfg.sliding_window) if cfg.sliding_window else s
    for spec in period_pattern(cfg):
        mixer, ffn = spec
        if mixer == "attn":
            # decode projects K/V for the NEW token only (cache holds the rest)
            total += _attn_flops(cfg, b, 1, skv, cross=True)
            total += 2 * b * cfg.d_model * 2 * cfg.n_kv_heads * cfg.hd
        else:
            total += _mamba_decode_flops(cfg, b)
        if ffn == "dense":
            total += _mlp_flops(cfg, b)
        elif ffn == "moe":
            total += _moe_flops(cfg, b, rows=b)
    n_p = cfg.n_layers // len(period_pattern(cfg))
    return total * n_p + 2 * b * cfg.d_model * cfg.vocab_size


def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeCfg, compress: str = "none"
                   ) -> float:
    """Analytic per-step HBM traffic (global bytes) under TPU-grade fusion.

    Counted: parameter reads per pass (fwd / remat-recompute / bwd / update),
    optimizer-state IO, saved-activation write+read, KV-cache/SSM-state read+
    write for decode, logits.  NOT counted: attention score matrices (flash
    blocks stay in VMEM) and intra-fusion temporaries.  The HLO
    'bytes accessed' from the CPU pipeline is reported alongside as an
    unfused upper bound.
    """
    b, s = shape.global_batch, shape.seq_len
    act = 2.0                                   # bf16 activations
    pb = 4.0 if cfg.param_dtype == "float32" else 2.0
    # parameter count (matmul params only, embed excluded from per-pass reads)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = period_pattern(cfg)
    per_layer = 0.0
    tok_act_per_layer = 0.0                     # saved/major activations/token
    for mixer, ffn in specs:
        if mixer == "attn":
            per_layer += d * (h + 2 * kv) * hd + h * hd * d
            tok_act_per_layer += d + (h + 2 * kv) * hd + d
        else:
            din, n = cfg.ssm_d_inner, cfg.ssm_state
            per_layer += d * (2 * din + 2 * n + cfg.ssm_heads) + din * d
            tok_act_per_layer += d + 2 * din + 2 * n
        if ffn == "dense":
            per_layer += 3 * d * ff if cfg.act == "silu" else 2 * d * ff
            tok_act_per_layer += d + 2 * ff
        elif ffn == "moe":
            per_layer += d * cfg.n_experts + cfg.n_experts * 3 * d * ff
            tok_act_per_layer += d + 2 * ff * cfg.experts_per_tok
    n_p = cfg.n_layers // len(specs)
    mat_params = per_layer * n_p + d * v        # + unembed
    enc_extra = 0.0
    if cfg.family == "encdec":
        enc_extra = cfg.n_enc_layers * (d * (h + 2 * kv) * hd + h * hd * d
                                        + 2 * d * ff) \
            + cfg.n_layers * (d * (h + 1 * kv * 2) * hd + h * hd * d)
        mat_params += enc_extra

    if shape.kind == "train":
        passes = {"none": 3.0, "dots": 3.3, "full": 4.0,
                  "offload": 3.0}[cfg.remat] if compress == "none" else 2.0
        param_io = mat_params * pb * passes + mat_params * pb * 2   # opt r/w
        if cfg.optimizer == "adafactor":
            param_io = mat_params * pb * passes + mat_params * pb * 0.1
        saved = b * s * cfg.d_model * act * 2 * cfg.n_layers        # w+r
        logits = b * s * v * 4 * 2
        return param_io + saved + logits
    if shape.kind == "prefill":
        cache_w = b * s * 2 * kv * hd * act * _n_attn_layers(cfg)
        return mat_params * pb + b * s * d * act * 2 * cfg.n_layers + cache_w
    # decode: weights once + cache read/write
    skv = min(s, cfg.sliding_window) if cfg.sliding_window else s
    cache_b = (1.0 + 4.0 / hd) if cfg.kv_cache_dtype == "int8" else act
    cache_r = b * skv * 2 * kv * hd * cache_b * _n_attn_layers(cfg)
    ssm_state = 0.0
    n_mamba = sum(1 for m, _ in specs if m == "mamba") * n_p
    if n_mamba:
        ssm_state = 2 * b * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state \
            * 4 * n_mamba
    if cfg.family == "encdec":
        cache_r += b * cfg.enc_len * 2 * kv * hd * act * cfg.n_layers
    logits = b * v * 4
    return mat_params * pb + cache_r + ssm_state + logits


def _n_attn_layers(cfg: ModelConfig) -> int:
    specs = period_pattern(cfg)
    n_p = cfg.n_layers // len(specs)
    return sum(1 for m, _ in specs if m == "attn") * n_p \
        + (cfg.n_enc_layers if cfg.family == "encdec" else 0)


def _mamba_decode_flops(cfg: ModelConfig, b: int) -> float:
    d, din = cfg.d_model, cfg.ssm_d_inner
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return (2 * b * d * (2 * din + 2 * n + h)        # in_proj
            + 2 * b * cfg.ssm_conv_width * (din + 2 * n)
            + 4 * b * h * p * n                      # state update + readout
            + 2 * b * din * d)                       # out_proj
