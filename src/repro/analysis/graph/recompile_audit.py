"""recompile-audit: the jit cache must stay where the design says it is.

jax keys its compile cache on the *abstract* call signature — shapes,
dtypes, weak-type bits, and the pytree structure.  A python scalar where
an ``int32`` array belongs, or a rebuilt state tree whose treedef
changed, silently doubles compiles without any numeric difference; on a
long-lived on-device session that fragmentation is a latency cliff, not
a correctness bug, so no numeric test catches it.  This rule hashes
signatures (``harness.signature_key``) across the sweeps the runtime
actually performs:

- steady-state train steps (same shapes step after step) must map to ONE
  signature, with no weak-typed leaves in the canonical state trees;
- chunked prefill must fold every prompt length onto one compile key per
  (chunk, embeds-shape) — ``Engine.prefill_compile_keys`` exposes the
  admission plan; legacy whole-prompt prefill is bounded by the engine's
  ``_PREFILL_MEMO_MAX`` eviction instead;
- grad-accum microbatching happens *inside* the step: the outer
  signature for accum=1 vs accum=4 over the same batch must agree;
- equal rank plans must produce identical ASI-state signatures (rank
  *changes* legitimately recompile; rank *equality* must not).
"""
from __future__ import annotations

import os
from functools import partial
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.analysis.core import Finding, rule
from repro.analysis.graph import harness

TRAIN_REL = "src/repro/runtime/train_loop.py"
SERVE_REL = "src/repro/runtime/serve_loop.py"
ARCH_ENV = "REPRO_GRAPH_RECOMPILE_ARCH"
DEFAULT_ARCH = "tinyllama-1.1b"


def _line(root: str, rel: str, marker: str) -> int:
    try:
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            for lineno, text in enumerate(f, start=1):
                if marker in text:
                    return lineno
    except OSError:
        pass
    return 1


def audit_family(arch: str, root: str) -> Iterator[Finding]:
    from repro.configs.registry import get_config
    from repro.data.synthetic import LMStream, LMStreamCfg
    from repro.models import build_model
    from repro.runtime.serve_loop import Engine, ServeCfg

    cfg = get_config(arch).reduced().replace(compress="asi")
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(api.init, key)
    asi = jax.eval_shape(api.init_asi, key)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=4, seed=0, branching=2))

    # steady-state train-step signatures: data batches at different steps
    # and a fresh jnp.int32 counter must hash identically
    keys = {harness.signature_key(params, asi, data.batch(t), jnp.int32(t))
            for t in range(3)}
    if len(keys) != 1:
        yield Finding(
            rule="recompile-audit", path=TRAIN_REL,
            line=_line(root, TRAIN_REL, "def make_train_step"),
            message=f"{arch}: {len(keys)} distinct train-step signatures "
                    f"across 3 steady-state steps — every step should hit "
                    f"one compile-cache entry")

    # python scalars in state trees flip the weak-type bit and fork the
    # cache; the canonical trees must carry none
    for name, tree in (("params", params), ("asi_state", asi),
                       ("batch", data.batch(0))):
        for keypath, shape in harness.weak_typed_leaves(tree):
            yield Finding(
                rule="recompile-audit", path=TRAIN_REL,
                line=_line(root, TRAIN_REL, "def make_train_step"),
                message=f"{arch}: weak-typed leaf {name}{keypath} "
                        f"shape {shape} — a python scalar leaked into a "
                        f"jitted state tree (jit-cache fragmentation)")

    # grad-accum reshapes *inside* the step: outer signature is invariant
    if harness.signature_key(params, asi, data.batch(0)) != \
            harness.signature_key(params, asi, data.batch(1)):
        yield Finding(
            rule="recompile-audit", path=TRAIN_REL,
            line=_line(root, TRAIN_REL, "grad_accum"),
            message=f"{arch}: consecutive batches from the same stream "
                    f"have different abstract signatures")

    # chunked prefill folds all prompt lengths onto one compile key
    scfg = ServeCfg(max_batch=2, max_len=32, cache="dense", prefill_chunk=8)
    eng = Engine(api, params, scfg)
    lens = range(1, scfg.max_len - 1)
    chunk_keys = eng.prefill_compile_keys(lens)
    if len(chunk_keys) != 1:
        yield Finding(
            rule="recompile-audit", path=SERVE_REL,
            line=_line(root, SERVE_REL, "def prefill_compile_keys"),
            message=f"{arch}: chunked prefill touches {len(chunk_keys)} "
                    f"compile keys over {len(list(lens))} prompt lengths — "
                    f"must be 1 per (chunk, embeds-shape)")
    legacy = Engine(api, params,
                    ServeCfg(max_batch=2, max_len=32, cache="dense"))
    legacy_keys = legacy.prefill_compile_keys(lens)
    if len(legacy_keys) > Engine._PREFILL_MEMO_MAX:
        yield Finding(
            rule="recompile-audit", path=SERVE_REL,
            line=_line(root, SERVE_REL, "_PREFILL_MEMO_MAX"),
            message=f"{arch}: legacy prefill would compile "
                    f"{len(legacy_keys)} entries, over the declared memo "
                    f"bound {Engine._PREFILL_MEMO_MAX}")

    # rank-plan determinism: equal plans => equal ASI-state signatures
    from repro.ondevice.ledger import iter_asi_sites
    sites = list(iter_asi_sites(cfg, 2, 16))
    plan = {sites[0].name: 2} if sites else None
    sig_a = harness.signature_key(jax.eval_shape(
        partial(api.init_asi, rank_plan=plan), key))
    sig_b = harness.signature_key(jax.eval_shape(
        partial(api.init_asi, rank_plan=dict(plan) if plan else None), key))
    if sig_a != sig_b:
        yield Finding(
            rule="recompile-audit", path=TRAIN_REL,
            line=_line(root, TRAIN_REL, "def make_train_step"),
            message=f"{arch}: identical rank plans produced different "
                    f"ASI-state signatures — nondeterministic init_asi "
                    f"structure would recompile every adaptation burst")


@rule("recompile-audit", scope="tree", plane="graph",
      doc="abstract call signatures stay stable across shape sweeps "
          "(prefill chunks, grad-accum, rank plans); no weak-type leaks")
def check_recompile(root, contexts) -> Iterator[Finding]:
    arch = os.environ.get(ARCH_ENV, DEFAULT_ARCH)
    yield from audit_family(arch, root)
