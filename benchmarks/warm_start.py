"""Paper Fig. 3 ablation: warm start vs cold start for ASI.

We fine-tune the reduced TinyLlama tail with ASI twice — warm-started factors
(the paper's method) vs factors re-randomized every step — on the synthetic
Markov task, and compare (a) gradient-approximation error against the exact
gradient and (b) final training loss.  Warm start must win on (a); (b) must
not be worse (the paper reports +3.87% accuracy on CIFAR-10/MCUNet).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

STEPS = 30


def _run(warm: bool, rank=4, seed=0):
    cfg = get_config("tinyllama-1.1b").reduced().replace(
        n_layers=2, compress="asi", asi_rank=rank, asi_last_k=1)
    api = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = api.init(key)
    st = api.init_asi(key)
    mask = api.trainable_mask(params)
    opt = make_optimizer("sgdm", lambda s: 0.05, momentum=0.9)
    ostate = opt.init(params)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8, branching=2, seed=seed))

    exact_cfg = cfg.replace(compress="none")
    exact_api = build_model(exact_cfg)

    @jax.jit
    def step(params, ostate, st, batch, i):
        def lossf(p):
            loss, (m, ns) = api.loss(p, batch, st)
            return loss, ns
        (loss, ns), g = jax.value_and_grad(lossf, has_aux=True)(params)
        params, ostate = opt.update(g, ostate, params, i, mask)
        return params, ostate, ns, loss, g

    @jax.jit
    def exact_grads(params, batch):
        return jax.grad(lambda p: exact_api.loss(p, batch)[0])(params)

    key2 = jax.random.PRNGKey(seed + 100)
    losses, gerrs = [], []
    for i in range(STEPS):
        batch = data.batch(i)
        if not warm:                     # ablation: re-randomize the subspace
            key2, sub = jax.random.split(key2)
            st = api.init_asi(sub)
        ge = exact_grads(params, batch)
        params, ostate, st, loss, g = step(params, ostate, st, batch,
                                           jnp.int32(i))
        # gradient error on the fine-tuned tail only
        num = den = 0.0
        for ga, gb in zip(jax.tree.leaves(g["stack"]),
                          jax.tree.leaves(ge["stack"])):
            num += float(jnp.sum((ga.astype(jnp.float32)
                                  - gb.astype(jnp.float32)) ** 2))
            den += float(jnp.sum(gb.astype(jnp.float32) ** 2))
        losses.append(float(loss))
        gerrs.append((num / max(den, 1e-12)) ** 0.5)
    return np.mean(losses[-5:]), np.mean(gerrs[5:])


def run(verbose=True):
    loss_w, err_w = _run(warm=True)
    loss_c, err_c = _run(warm=False)
    if verbose:
        print(f"warm  : final loss {loss_w:.4f}  rel grad err {err_w:.4f}")
        print(f"cold  : final loss {loss_c:.4f}  rel grad err {err_c:.4f}")
    assert err_w < err_c, "warm start must reduce gradient error (Fig. 3)"
    return {"loss_warm": loss_w, "loss_cold": loss_c,
            "gerr_warm": err_w, "gerr_cold": err_c}


if __name__ == "__main__":
    run()
