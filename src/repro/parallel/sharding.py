"""Logical-axis sharding: models annotate tensors with logical names; a
rules context maps names to mesh axes (t5x/MaxText style), so the same model
code runs on a laptop (no rules -> no-op) and on a 512-chip multi-pod mesh.

Usage (see DESIGN.md §6 and examples/train_sharded.py):

    mesh = make_mesh((2, 4), ("data", "model"))
    with axis_rules(mesh, rules_for(mesh, layout="tp")):
        logits = jit_step(params, batch)   # logical_shard calls now resolve

The logical vocabulary (``batch``, ``heads``, ``kv``, ``mlp``, ``vocab``,
``experts``, ...) is fixed; a *layout* is one mapping from that vocabulary to
mesh axes.  Three canonical layouts ship here:

* ``dp``   — pure data parallelism: only ``batch`` is sharded, weights are
             replicated.  Bit-identical losses to single-device (same
             contraction per example), so it doubles as the parity oracle.
* ``tp``   — Megatron tensor parallelism x DP (``single_pod_rules`` /
             ``multi_pod_rules``): head/ffn/vocab/expert dims on ``model``.
* ``fsdp`` — ZeRO-3: every mesh axis shards batch *and* weights, no TP.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, Optional[str | tuple[str, ...]]]):
    """Activate a (mesh, logical->mesh-axis) mapping for model tracing."""
    prev = _current()
    _STATE.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.ctx = prev


def resolve(*names: Optional[str]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    Outside any ``axis_rules`` context every name resolves to ``None``
    (replicated) — this is what lets the same model code run unsharded."""
    ctx = _current()
    if ctx is None:
        return P(*[None] * len(names))
    _, rules = ctx
    return P(*[rules.get(n) if n else None for n in names])


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def safe_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop spec entries that do not evenly divide the dim (keeps GSPMD happy
    and makes rules robust across the 40 arch x shape cells)."""
    out = []
    for i, axis in enumerate(spec):
        if axis is None:
            out.append(None)
            continue
        size = _mesh_axis_size(mesh, axis)
        out.append(axis if (i < len(shape) and shape[i] % size == 0) else None)
    return P(*out)


def logical_shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without active rules."""
    ctx = _current()
    if ctx is None or not hasattr(x, "shape"):
        return x
    mesh, _ = ctx
    spec = safe_spec(x.shape, resolve(*names), mesh)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Canonical rule sets ---------------------------------------------------------

def dp_rules(multi_pod: bool = False) -> dict:
    """Pure data parallelism: shard only the batch; replicate all weights.

    Per-example compute is identical to single-device (no contraction is
    split), so dp losses are bit-identical to the unsharded step — the
    parity oracle tests/test_sharded_train.py gates on."""
    ba = ("pod", "data") if multi_pod else "data"
    return {"batch": ba, "fsdp": None, "seq": None, "long_seq": None,
            "model": None, "heads": None, "kv": None, "mlp": None,
            "vocab": None, "experts": None, "embed": None,
            "cache_seq": None, "seq_tp": None}


def single_pod_rules() -> dict:
    """Megatron TP x DP on a (data, model) mesh: head/ffn/vocab/expert dims
    shard over ``model``; the batch over ``data``."""
    return {
        "batch": "data", "fsdp": "data", "seq": None, "long_seq": "data",
        "model": "model", "heads": "model", "kv": "model", "mlp": "model",
        "vocab": "model", "experts": "model", "embed": None, "cache_seq": "model",
        "seq_tp": None,
    }


def multi_pod_rules() -> dict:
    """``single_pod_rules`` with the batch additionally split over ``pod``."""
    return {
        "batch": ("pod", "data"), "fsdp": ("pod", "data"), "seq": None,
        "long_seq": "data", "model": "model", "heads": "model", "kv": "model",
        "mlp": "model", "vocab": "model", "experts": "model", "embed": None,
        "cache_seq": "model", "seq_tp": None,
    }


def fsdp_rules(multi_pod: bool) -> dict:
    """ZeRO-3 layout: every mesh axis shards batch/weights; no TP."""
    ba = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {"batch": ba, "fsdp": ba, "seq": None, "long_seq": "data",
            "model": None, "heads": None, "kv": None, "mlp": None,
            "vocab": None, "experts": None, "embed": None,
            "cache_seq": None, "seq_tp": None}


LAYOUTS = ("dp", "tp", "fsdp")


def rules_for(mesh: Mesh, layout: str = "tp") -> dict:
    """Select the canonical rule set for ``layout`` on ``mesh``.

    ``dp`` -> ``dp_rules``; ``fsdp`` -> ``fsdp_rules``; ``tp`` (default) ->
    ``single_pod_rules`` or ``multi_pod_rules`` depending on whether the mesh
    has a ``pod`` axis.  Unknown layouts raise (a typo must not silently
    train replicated)."""
    if layout not in LAYOUTS:
        raise ValueError(f"layout={layout!r}; expected one of {LAYOUTS}")
    multi = "pod" in mesh.axis_names
    if layout == "dp":
        return dp_rules(multi)
    if layout == "fsdp":
        return fsdp_rules(multi)
    return multi_pod_rules() if multi else single_pod_rules()
