"""Regenerate the §Dry-run/§Roofline/§Perf tables inside EXPERIMENTS.md from
results/dryrun.jsonl + results/hillclimb.jsonl."""
import io
import json
import os
import re
import sys

sys.path.insert(0, "src")
from benchmarks.roofline_report import dryrun_table, enrich, load, table  # noqa: E402

MARK = "<!-- AUTOGEN TABLES BELOW -->"


def hillclimb_table() -> str:
    if not os.path.exists("results/hillclimb.jsonl"):
        return "(hillclimb results pending)"
    out = io.StringIO()
    print("| label | cell | compute(s) | mem(s) | coll(s) | dominant | "
          "roofline | verdict |", file=out)
    print("|" + "---|" * 8, file=out)
    base = load("results/dryrun.jsonl")
    with open("results/hillclimb.jsonl") as f:
        for line in f:
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            if d.get("status") != "ok":
                continue
            e = enrich(dict(d))
            bkey = (d["arch"], d["shape"], False, "none", "full", False)
            b = base.get(bkey)
            verdict = ""
            if b and b.get("status") == "ok":
                be = enrich(dict(b))
                d_ = {"compute": be["an_compute_s"] / max(e["an_compute_s"], 1e-12),
                      "memory": be["an_mem_s"] / max(e["an_mem_s"], 1e-12),
                      "collective": be["coll_s"] / max(e["coll_s"], 1e-12)}
                verdict = " ".join(f"{k}x{v:.2f}" for k, v in d_.items()
                                   if abs(v - 1) > 0.05)
            print(f"| {d.get('label','?')} | {d['arch']}/{d['shape']} | "
                  f"{e['an_compute_s']:.2e} | {e['an_mem_s']:.2e} | "
                  f"{e['coll_s']:.2e} | {e['dominant2']} | "
                  f"{e['roofline_frac']:.3f} | {verdict} |", file=out)
    return out.getvalue()


def main():
    buf = io.StringIO()
    print(MARK, file=buf)
    print("\n### §Dry-run table (both meshes)\n", file=buf)
    dryrun_table(out=buf)
    print("\n### §Roofline — single-pod (16x16, 256 chips)\n", file=buf)
    table(multi_pod=False, out=buf)
    print("\n### §Roofline — multi-pod (2x16x16, 512 chips)\n", file=buf)
    table(multi_pod=True, out=buf)
    print("\n### §Perf — hillclimb variants (vs single-pod baseline)\n",
          file=buf)
    print(hillclimb_table(), file=buf)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    if MARK in text:
        text = text[: text.index(MARK)]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text.rstrip() + "\n\n" + buf.getvalue())
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
