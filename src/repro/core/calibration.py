"""Calibration capture for the on-device planner (paper §3.3, step 1).

The planner needs, for every ASI-compressed linear site in the fine-tuned
tail, the *exact* pair (input activation A_i, output cotangent ∂L/∂Y_i) on a
few real batches — that is what ``rank_selection.estimate_perplexity`` turns
into the gradient-perplexity table the budget search minimizes over.

Getting those pairs without instrumenting every model file exploits two
facts about the existing stack:

1. every compressed site already routes through ``asi_linear`` /
   ``grouped_asi_linear`` (core/compressed_linear.py), so a single
   thread-local context consulted there sees every site, in deterministic
   trace order (the fine-tuned tail is python-unrolled, never scanned);
2. ASI backward keeps ∂L/∂x exact (eq. 2 needs only W), so the cotangents
   arriving at *every* site are exact even while capture runs with the
   compressed model — only weight gradients are approximated, and those are
   not on the activation-gradient path.

Mechanics: inside ``capture_sites(taps)`` each site appends its input to the
record and adds ``taps[i]`` (a zeros array, a *differentiated input* of the
probe function) to its output.  The probe returns the recorded activations
as auxiliary outputs, so a single ``jax.vjp(probe, params, taps,
has_aux=True)`` yields activations (aux) and per-site cotangents (the taps'
gradients) in one backward pass.  A first ``jax.eval_shape`` discovery pass
(taps=None) provides the tap shapes.

The context is thread-local and off by default: normal training/serving
never touches it (same pattern as ``parallel.sharding.axis_rules``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

_STATE = threading.local()


@dataclasses.dataclass
class SiteCapture:
    """One compressed-linear site seen during a capture pass."""
    kind: str                 # "matrix" | "grouped"
    x: Any                    # site input as traced (matrix: (..., K);
                              #  grouped: (E, T, K))
    y_shape: tuple            # site output shape (tap shape)
    y_dtype: Any


class CaptureContext:
    def __init__(self, taps=None):
        self.sites: list[SiteCapture] = []
        self._taps = list(taps) if taps is not None else None

    def record(self, kind: str, x, y):
        """Record a site; returns ``y`` (+ its tap when taps were supplied)."""
        self.sites.append(SiteCapture(kind, x, tuple(y.shape), y.dtype))
        if self._taps is None:
            return y
        if not self._taps:
            raise ValueError(
                "calibration capture: more compressed-linear sites than taps "
                "— discovery and probe passes traced different programs")
        return y + self._taps.pop(0).astype(y.dtype)


def active() -> CaptureContext | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def capture_sites(taps=None):
    """Enable site capture for everything traced inside the block.

    ``taps``: sequence of zero arrays (one per site, discovery-pass order)
    added to the site outputs so their vjp gradients are the per-site
    cotangents; None records activations/shapes only.
    """
    if active() is not None:
        raise RuntimeError("calibration capture does not nest")
    ctx = CaptureContext(taps)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = None
