import os
import sys

if __name__ == "__main__" and "jax" not in sys.modules:
    # ``python -m repro.launch.dryrun`` executes this module as __main__
    # before jax is imported: stand up the 512 placeholder host devices.
    # Importing the shim (tests, embedders using the deprecated run_cell
    # path) never touches XLA_FLAGS — the process and its subprocesses keep
    # their own device configuration.
    os.environ["XLA_FLAGS"] = os.environ.get(
        "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, print memory/cost analysis, and emit roofline
terms.  This is a thin argparse shim over ``repro.api.analyze`` — the cell
analysis itself is importable, embeddable data (``Session.analyze()``).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  ... [--multi-pod] [--compress asi] [--remat full|dots|none] [--fsdp]
"""
import argparse
import json
import warnings

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS


def build_parser() -> argparse.ArgumentParser:
    from repro import api

    ap = argparse.ArgumentParser()
    api.add_arch_argument(ap, required=False)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=("none", "asi", "hosvd"))
    ap.add_argument("--remat", default=None, choices=("none", "full", "dots",
                                                      "offload"))
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--seq-tp", action="store_true")
    ap.add_argument("--param-dtype", default=None,
                    choices=("float32", "bfloat16"))
    ap.add_argument("--layout", default="tp", choices=("tp", "fsdp", "dp"))
    ap.add_argument("--kv-cache-dtype", default=None, choices=("int8",))
    ap.add_argument("--mem-budget-mb", type=float, default=None,
                    help="on-device activation-memory budget: train cells "
                         "report whether vanilla/ASI tail storage fits "
                         "(repro.ondevice.ledger) before any training")
    ap.add_argument("--reduced", action="store_true",
                    help="analyze the CPU-sized config on the reduced shape "
                         "(smoke tests / CI; production numbers need the "
                         "full config)")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep the layer scan rolled (fallback for compile-"
                         "time-bound cells; per-layer collectives are then "
                         "counted once — the report scales them by depth)")
    ap.add_argument("--mesh", default=None,
                    help="override, e.g. '2,2:data,model' for tests")
    ap.add_argument("--out", default=None, help="append JSONL here")
    return ap


def main(argv=None):
    from repro import api
    from repro.api import analyze as _analyze

    api.warn_programmatic_use(__name__, argv)
    args = build_parser().parse_args(argv)

    mesh_override = None
    if args.mesh:
        shp, axes = args.mesh.split(":")
        mesh_override = (tuple(int(x) for x in shp.split(",")),
                         tuple(axes.split(",")))

    if args.all:
        cells = [(arch, shape) for arch in ARCHS for shape in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                res = _analyze.run_cell(
                    arch, shape, multi_pod=mp, compress=args.compress,
                    remat=args.remat, fsdp=args.fsdp,
                    mesh_override=mesh_override, seq_shard=args.seq_shard,
                    seq_tp=args.seq_tp, param_dtype=args.param_dtype,
                    layout=args.layout, kv_cache_dtype=args.kv_cache_dtype,
                    mem_budget_mb=args.mem_budget_mb, reduced=args.reduced,
                    unroll=not args.no_unroll)
            except Exception as e:                           # noqa: BLE001
                failures += 1
                res = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "failed", "error": repr(e)[:500]}
                print(json.dumps(res))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res, default=str) + "\n")
    sys.exit(1 if failures else 0)


_MOVED = ("run_cell", "build_cell", "_param_counts", "_model_flops",
          "_ledger_report")


def __getattr__(name):
    if name in _MOVED:              # pre-api import path, kept as a shim
        warnings.warn(f"repro.launch.dryrun.{name} moved to "
                      f"repro.api.analyze.{name}", DeprecationWarning,
                      stacklevel=2)
        from repro.api import analyze as _analyze
        return getattr(_analyze, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


if __name__ == "__main__":
    main()
