"""jit-purity: host effects must stay out of traced code and hot loops.

Two sub-checks share the ``jit-purity`` rule name:

1. **traced purity** — per module, find the functions that get traced
   (decorated with / passed to ``jax.jit``, Pallas kernel bodies, custom_vjp
   primal/fwd/bwd, ``lax.scan``/``while_loop``/``cond`` bodies) and every
   local function reachable from them through the module's call graph.
   Inside those bodies flag: ``time.*`` calls, unseeded ``np.random.*``,
   ``print``, ``.item()`` / ``float()`` / ``int()`` on array-typed values,
   and Python ``if`` branching on tracer-derived values (these either break
   tracing or silently bake a host value into the compiled program).

2. **loop syncs** — in ``runtime/``, ``ondevice/`` and ``scenarios/``
   modules, flag device syncs inside loop bodies: ``.block_until_ready()``
   and implicit transfers (``float(...)`` / ``int(...)`` / ``.item()`` of a
   device value) outside a log-step guard (an enclosing ``if`` whose test
   uses ``%``).  A per-step sync stalls dispatch pipelining — the serving
   and adaptation hot paths are designed around a single explicit
   ``jax.device_get`` per step, which is exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Finding, FileContext, call_name,
                                 dotted_name, rule)

SYNC_SCOPES = ("src/repro/runtime/", "src/repro/ondevice/",
               "src/repro/scenarios/")

# call roots whose results are host values (safe to convert in a loop)
_HOST_CALL_ROOTS = ("jax.device_get", "time.", "np.", "numpy.", "len",
                    "range", "enumerate", "zip", "sorted", "min", "max",
                    "sum", "abs", "round", "list", "dict", "tuple", "set",
                    "str", "int", "float", "bool", "getattr", "isinstance")

_CONVERSIONS = {"float", "int", "bool"}


def _is_host_call(name: str | None) -> bool:
    if name is None:
        return False
    return any(name == r or name.startswith(r) for r in _HOST_CALL_ROOTS
               if not r.endswith(".")) or any(
        name.startswith(r) for r in _HOST_CALL_ROOTS if r.endswith("."))


def _functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    fns: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            fns.setdefault(node.name, node)
    return fns


def _all_functions(tree: ast.Module) -> list[ast.FunctionDef]:
    """Every FunctionDef, including same-named methods on different
    classes (the name-keyed dict above keeps only the first)."""
    return [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]


def _own_walk(fn: ast.FunctionDef):
    """Walk ``fn``'s body without descending into nested function defs —
    those are visited as functions in their own right."""
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _is_jit_like(name: str | None) -> bool:
    return name in ("jax.jit", "jit", "pjit", "jax.pjit")


def _traced_roots(tree: ast.Module, fns: dict) -> set[str]:
    """Names of local functions that are traced entry points."""
    roots: set[str] = set()
    for fn in fns.values():
        for dec in fn.decorator_list:
            dname = dotted_name(dec)
            if _is_jit_like(dname) or dname in ("jax.custom_vjp",
                                                "custom_vjp",
                                                "jax.checkpoint"):
                roots.add(fn.name)
            if isinstance(dec, ast.Call):
                cname = call_name(dec)
                if _is_jit_like(cname) or cname in ("jax.checkpoint",):
                    roots.add(fn.name)
                if cname in ("partial", "functools.partial") and dec.args:
                    inner = dotted_name(dec.args[0])
                    if _is_jit_like(inner) or inner in ("jax.custom_vjp",
                                                        "custom_vjp"):
                        roots.add(fn.name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        # X.defvjp(fwd, bwd): both halves trace
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "defvjp":
            for a in node.args:
                t = dotted_name(a)
                if t in fns:
                    roots.add(t)
            continue
        first_fn_arg = None
        if node.args:
            first_fn_arg = dotted_name(node.args[0])
            if first_fn_arg is None and isinstance(node.args[0], ast.Call):
                inner = node.args[0]
                if call_name(inner) in ("partial", "functools.partial") \
                        and inner.args:
                    first_fn_arg = dotted_name(inner.args[0])
        if name is None:
            continue
        if _is_jit_like(name) or name in (
                "pl.pallas_call", "pallas_call",
                "jax.lax.scan", "lax.scan", "jax.lax.while_loop",
                "lax.while_loop", "jax.lax.cond", "lax.cond",
                "jax.lax.fori_loop", "lax.fori_loop", "jax.checkpoint"):
            if first_fn_arg in fns:
                roots.add(first_fn_arg)
            # lax.cond branches are args 1..2
            if name.endswith("cond"):
                for a in node.args[1:3]:
                    t = dotted_name(a)
                    if t in fns:
                        roots.add(t)
    return roots


def _reachable(fns: dict, roots: set[str]) -> set[str]:
    calls: dict[str, set[str]] = {}
    for name, fn in fns.items():
        callees = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                t = call_name(node)
                if t in fns and t != name:
                    callees.add(t)
        calls[name] = callees
    seen = set()
    stack = [r for r in roots if r in fns]
    while stack:
        cur = stack.pop()
        if cur in seen:
            continue
        seen.add(cur)
        stack.extend(calls.get(cur, ()))
    return seen


def _array_typed_names(fn: ast.FunctionDef) -> set[str]:
    """Names assigned (in ``fn``) from jnp/jax/lax calls — tracer-valued
    under tracing."""
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = call_name(node.value)
            if name and (name.startswith(("jnp.", "lax.", "jax.numpy.",
                                          "jax.lax."))
                         or (name.startswith("jax.")
                             and not name.startswith("jax.device_get"))):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            out.add(n.id)
    # annotated Array params
    for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
        ann = a.annotation
        if ann is not None and dotted_name(ann) in (
                "Array", "jax.Array", "jnp.ndarray"):
            out.add(a.arg)
    return out


def _test_is_host_safe(test: ast.AST, array_names: set[str]) -> bool:
    """True when an ``if`` test cannot involve a tracer value."""
    for node in ast.walk(test):
        if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            continue
        if isinstance(node, ast.Attribute) and node.attr in (
                "shape", "ndim", "dtype", "size"):
            return True
        if isinstance(node, ast.Name) and node.id in array_names:
            return False
    return True


def _check_traced_body(ctx: FileContext, fn: ast.FunctionDef):
    array_names = _array_typed_names(fn)
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue                       # nested defs analyzed on their own
        stack.extend(ast.iter_child_nodes(node))
        if isinstance(node, ast.If) and not _test_is_host_safe(
                node.test, array_names):
            yield Finding("jit-purity", ctx.rel, node.lineno,
                          f"{fn.name}: Python `if` on a tracer-derived "
                          "value — use jnp.where / lax.cond inside traced "
                          "code")
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name is None:
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                yield Finding("jit-purity", ctx.rel, node.lineno,
                              f"{fn.name}: .item() forces a device sync "
                              "inside traced code")
            continue
        if name.startswith("time."):
            yield Finding("jit-purity", ctx.rel, node.lineno,
                          f"{fn.name}: {name}() in traced code — wall-clock "
                          "reads are baked in at trace time")
        elif name.startswith(("np.random.", "numpy.random.")) and \
                not name.endswith("default_rng"):
            yield Finding("jit-purity", ctx.rel, node.lineno,
                          f"{fn.name}: unseeded {name}() in traced code — "
                          "use jax.random with an explicit key")
        elif name == "print":
            yield Finding("jit-purity", ctx.rel, node.lineno,
                          f"{fn.name}: print() in traced code — use "
                          "jax.debug.print")
        elif name.endswith(".item"):
            yield Finding("jit-purity", ctx.rel, node.lineno,
                          f"{fn.name}: .item() forces a device sync inside "
                          "traced code")
        elif name in ("float", "int") and node.args:
            arg = node.args[0]
            aname = dotted_name(arg)
            direct = call_name(arg) if isinstance(arg, ast.Call) else None
            if (aname in array_names
                    or (direct or "").startswith(("jnp.", "jax.", "lax."))):
                yield Finding("jit-purity", ctx.rel, node.lineno,
                              f"{fn.name}: {name}() on an array value "
                              "inside traced code forces a sync (breaks "
                              "under jit)")


# ---------------------------------------------------------------------------
# loop-sync sub-check
# ---------------------------------------------------------------------------

def _jitted_names(tree: ast.Module) -> set[str]:
    """Names (locals and self attributes) bound to jax.jit(...) products."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if _is_jit_like(call_name(node.value)):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
                    elif isinstance(t, ast.Attribute):
                        out.add(t.attr)
    return out


def _root_name(node: ast.AST) -> str | None:
    while isinstance(node, (ast.Subscript, ast.Attribute, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


class _LoopSyncChecker:
    def __init__(self, ctx: FileContext, jitted: set[str]):
        self.ctx = ctx
        self.jitted = jitted

    def check_fn(self, fn: ast.FunctionDef):
        host_names: set[str] = set()       # assigned from host-safe calls
        device_names: set[str] = set()     # assigned from device-valued calls
        for node in _own_walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                name = call_name(node.value)
                targets = [n.id for t in node.targets
                           for n in ast.walk(t) if isinstance(n, ast.Name)]
                if _is_host_call(name):
                    host_names.update(targets)
                elif self._is_device_call(name):
                    device_names.update(targets)
                # unknown calls stay unknown: flagging them would drown the
                # report in numpy / dict-method false positives
        findings = []
        for loop in _own_walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            findings.extend(self._check_loop(fn, loop, host_names,
                                             device_names))
        # dedupe by line (nested loops walk the same calls twice)
        seen = set()
        for f in findings:
            if f.line not in seen:
                seen.add(f.line)
                yield f

    def _check_loop(self, fn, loop, host_names, device_names):
        # map child -> parent inside the loop for guard lookup
        parents: dict[ast.AST, ast.AST] = {}
        stack = [loop]
        while stack:
            cur = stack.pop()
            for child in ast.iter_child_nodes(cur):
                parents[child] = cur
                if not isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                    stack.append(child)
        for node, parent in list(parents.items()):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "block_until_ready":
                yield Finding("jit-purity", self.ctx.rel, node.lineno,
                              f"{fn.name}: .block_until_ready() inside a "
                              "loop body — a per-iteration device sync")
                continue
            name = call_name(node)
            is_item = (isinstance(node.func, ast.Attribute)
                       and node.func.attr == "item")
            if not (is_item or (name in _CONVERSIONS and node.args)):
                continue
            arg = node if is_item else node.args[0]
            if is_item:
                arg = node.func.value
            if not self._is_device_value(arg, host_names, device_names):
                continue
            if self._log_guarded(node, parents, loop):
                continue
            what = ".item()" if is_item else f"{name}()"
            yield Finding(
                "jit-purity", self.ctx.rel, node.lineno,
                f"{fn.name}: {what} on a device value inside a loop body — "
                "an implicit per-iteration sync; hoist it out of the loop, "
                "batch via jax.device_get, or guard it to log steps")

    def _is_device_call(self, name: str | None) -> bool:
        if name is None or _is_host_call(name):
            return False
        return (name.startswith(("jnp.", "lax.", "jax.numpy.", "jax.lax."))
                or (name.startswith("jax.")
                    and not name.startswith("jax.device_get"))
                or name in self.jitted
                or name.split(".")[-1] in self.jitted)

    def _is_device_value(self, arg, host_names, device_names) -> bool:
        if isinstance(arg, ast.Call):
            return self._is_device_call(call_name(arg))
        root = _root_name(arg)
        return root is not None and root in device_names \
            and root not in host_names

    def _log_guarded(self, node, parents, loop) -> bool:
        cur = parents.get(node)
        while cur is not None and cur is not loop:
            if isinstance(cur, ast.If):
                for t in ast.walk(cur.test):
                    if isinstance(t, ast.BinOp) and isinstance(t.op, ast.Mod):
                        return True
            cur = parents.get(cur)
        return False


@rule("jit-purity",
      doc="no host effects in traced code; no device syncs in runtime "
          "loop bodies outside log-step guards")
def check_purity(ctx: FileContext):
    fns = _functions(ctx.tree)
    roots = _traced_roots(ctx.tree, fns)
    for name in sorted(_reachable(fns, roots)):
        yield from _check_traced_body(ctx, fns[name])

    if any(ctx.rel.startswith(s) for s in SYNC_SCOPES):
        checker = _LoopSyncChecker(ctx, _jitted_names(ctx.tree))
        for fn in _all_functions(ctx.tree):
            yield from checker.check_fn(fn)
