"""Paper Table 4: TinyLlama-1.1B fine-tuning with ASI at rank 20 (B=8,
S<=512) — activation memory and TFLOPs for 1..5 fine-tuned layers.

The paper reports e.g. 1408 MB vanilla vs 0.51 MB ASI for one layer and a
~1.9x TFLOPs reduction at 5 layers; we reproduce both columns from our
(matrix-variant) formulas on the real TinyLlama projection shapes, and
cross-check the memory column against actual residual sizes of the
compressed layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.flops import (LinearDims, linear_asi_activation_elems,
                              linear_asi_backward_flops,
                              linear_asi_overhead_flops,
                              linear_forward_flops,
                              linear_vanilla_activation_elems,
                              linear_vanilla_backward_flops)

BYTES = 4
B, S, RANK = 8, 512, 20


def _block_linears(cfg):
    d, hd, h, kv, ff = (cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads,
                        cfg.d_ff)
    m = B * S
    return [LinearDims(m, d, h * hd), LinearDims(m, d, kv * hd),
            LinearDims(m, d, kv * hd), LinearDims(m, h * hd, d),
            LinearDims(m, d, ff), LinearDims(m, d, ff), LinearDims(m, ff, d)]


def _autograd_elems_per_token(cfg) -> int:
    """PyTorch-autograd stored set for one block (the paper's accounting):
    linear inputs + rope'd q/k + attention scores AND softmax probs (the
    dominant term at S=512) + silu/gating intermediates + norm saves."""
    d, hd, h, kv, ff = (cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads,
                        cfg.d_ff)
    linear_inputs = 6 * d + ff                   # q,k,v share x; o; gate/up; down
    rope = h * hd + kv * hd
    scores = 2 * h * S                           # scores + softmax output
    values = kv * hd
    silu = 2 * ff
    norms = 2 * d
    return linear_inputs + rope + scores + values + silu + norms


def table_rows():
    cfg = get_config("tinyllama-1.1b")
    lins = _block_linears(cfg)
    per_tok = _autograd_elems_per_token(cfg)
    rows = []
    for n_layers in (1, 2, 3, 4, 5):
        van_mem = asi_mem = 0
        van_fl = asi_fl = 0
        paper_van_mem = n_layers * per_tok * B * S * BYTES
        for _ in range(n_layers):
            for ld in lins:
                van_mem += linear_vanilla_activation_elems(ld) * BYTES
                asi_mem += linear_asi_activation_elems(ld, RANK) * BYTES
                van_fl += (linear_forward_flops(ld)
                           + linear_vanilla_backward_flops(ld))
                asi_fl += (linear_forward_flops(ld)
                           + linear_asi_overhead_flops(ld, RANK)
                           + linear_asi_backward_flops(ld, RANK))
        # the paper stores one rank-20 factor pair per fine-tuned layer
        paper_asi_mem = n_layers * (B * S + cfg.d_model) * RANK * BYTES
        rows.append({
            "layers": n_layers,
            "vanilla_mem_mb": van_mem / 2**20,
            "asi_mem_mb": asi_mem / 2**20,
            "mem_ratio": van_mem / asi_mem,
            "paper_vanilla_mb": paper_van_mem / 1e6,
            "paper_asi_mb": paper_asi_mem / 1e6,
            "paper_mem_ratio": paper_van_mem / paper_asi_mem,
            "vanilla_tflops": van_fl / 1e12,
            "asi_tflops": asi_fl / 1e12,
            "flops_ratio": van_fl / asi_fl,
        })
    return rows


def measured_residual_mb():
    """Ground truth: actual residual bytes saved by one ASI-wrapped block."""
    from repro.core.asi import MatrixASIState
    from repro.core.compressed_linear import LinearCompressionCfg, asi_linear
    cfg = get_config("tinyllama-1.1b")
    d = cfg.d_model
    x = jnp.zeros((B * S, d), jnp.float32)
    w = jnp.zeros((d, cfg.n_heads * cfg.hd), jnp.float32)
    st = MatrixASIState.init(jax.random.PRNGKey(0), d, RANK)
    ccfg = LinearCompressionCfg(rank=RANK)

    def f(w):
        y, _ = asi_linear(ccfg, x, w, None, st)
        return jnp.sum(y ** 2)

    _, vjp = jax.vjp(f, w)
    res = [v for v in jax.tree.leaves(vjp)
           if hasattr(v, "shape") and RANK in v.shape]
    return sum(v.size * v.dtype.itemsize for v in res) / 2**20


def run(verbose=True):
    rows = table_rows()
    if verbose:
        print(f"{'#L':>3s} {'paperVan':>9s} {'paperASI':>8s} {'pRatio':>8s} "
              f"{'fwMB':>7s} {'fwASI':>7s} {'van TF':>7s} {'ASI TF':>7s} "
              f"{'R_S':>5s}")
        for r in rows:
            print(f"{r['layers']:3d} {r['paper_vanilla_mb']:9.1f} "
                  f"{r['paper_asi_mb']:8.2f} {r['paper_mem_ratio']:8.1f} "
                  f"{r['vanilla_mem_mb']:7.1f} {r['asi_mem_mb']:7.2f} "
                  f"{r['vanilla_tflops']:7.2f} {r['asi_tflops']:7.2f} "
                  f"{r['flops_ratio']:5.2f}")
        print(f"measured per-linear residual: {measured_residual_mb():.3f} MB "
              f"(paper Table 4 reports 0.51 MB @ 1 layer)")
    # paper-claim assertions: Table 4 reports 1408 MB -> 0.51 MB at 1 layer
    # (PyTorch autograd accounting; exact saved-tensor bookkeeping differs by
    # ~20% between frameworks) and ~1.8-1.9x FLOPs reduction.
    assert abs(rows[0]["paper_vanilla_mb"] - 1408) < 350
    assert abs(rows[0]["paper_asi_mb"] - 0.51) < 0.15
    assert rows[0]["paper_mem_ratio"] > 1500       # paper: ~2500x at 5 layers
    assert rows[-1]["flops_ratio"] > 1.3           # ~1.8x in the paper
    return rows


if __name__ == "__main__":
    run()
