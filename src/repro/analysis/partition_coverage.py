"""partition-coverage: every parameter resolves to exactly one partition
rule under every layout, and every declared ``out_axis`` is real.

Two halves:

1. **AST half** — every ``LinearCompressionCfg(...)`` construction in
   ``models/`` must pass ``out_axis`` *explicitly* (``out_axis=None`` when
   the output dim is replicated): the field defaults to None, so an omitted
   keyword is indistinguishable from a deliberate "replicated" declaration —
   and an undeclared TP-sharded dim silently checks the VMEM cap against
   the global width (see ``kernels.dispatch.local_feature_dim``).  Declared
   axis names must exist in the logical vocabulary and be mapped to a mesh
   axis by the TP layout (an axis no layout shards is a dead declaration).

2. **import half** — for each config in ``configs/registry.py``, build the
   parameter struct via ``ModelAPI.init_struct()`` (``eval_shape`` — no
   device arrays), then for each layout in {dp, fsdp, tp} on an
   ``AbstractMesh`` run ``partition.param_specs`` and verify each leaf path
   matches exactly one ``_param_rule`` branch (matchers are extracted from
   the rule's AST, so this stays in lock-step with the real if-chain).
   A >=2-d leaf matching no branch falls through to replication — silent
   memory waste at scale; a leaf matching two branches is order-dependent.

Findings anchor to ``parallel/partition.py`` / the ccfg call site, so
suppressions live next to the code they bless.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.core import Finding, call_name, rule

PARTITION = "src/repro/parallel/partition.py"
MODEL_SCOPE = "src/repro/models/"
LAYOUTS = ("dp", "fsdp", "tp")

# Leaf names whose fall-through to replication is the *intended* rule.
# 1-d leaves are exempt wholesale; this list is for >=2-d leaves only —
# all of them are per-layer *vectors* stacked to (n_layers, dim) by the
# scan-over-layers parameter layout, so replicating them costs O(L * d),
# negligible next to any weight matrix.
REPLICATED_OK: frozenset = frozenset({
    "dec_pos",                          # matched explicitly, listed defensively
    "bias", "bq", "bk", "bv", "bo",     # attention / norm bias vectors
    "up_b", "down_b",                   # MLP bias vectors
    "norm", "scale",                    # RMS/LayerNorm gain vectors
})


def _out_axis_nodes(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name and name.split(".")[-1] == "LinearCompressionCfg":
                yield node


def _literal_axes(node: ast.expr):
    """String constants an out_axis value expression can *evaluate to* —
    IfExp tests and comparison operands are conditions, not axis names."""
    if isinstance(node, ast.IfExp):
        yield from _literal_axes(node.body)
        yield from _literal_axes(node.orelse)
    elif isinstance(node, (ast.Compare, ast.BoolOp)):
        return
    elif isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    else:
        for child in ast.iter_child_nodes(node):
            yield from _literal_axes(child)


def _vocabulary():
    from repro.parallel import sharding
    tp = sharding.single_pod_rules()
    return set(tp), {k for k, v in tp.items() if v is not None}


def _check_out_axes(contexts):
    try:
        vocab, tp_sharded = _vocabulary()
    except Exception as e:                                # pragma: no cover
        yield Finding("partition-coverage", PARTITION, 1,
                      f"could not import sharding rules: {e!r}")
        return
    for ctx in contexts:
        if not ctx.rel.startswith(MODEL_SCOPE):
            continue
        for node in _out_axis_nodes(ctx.tree):
            kw = next((k for k in node.keywords if k.arg == "out_axis"), None)
            if kw is None:
                yield Finding(
                    "partition-coverage", ctx.rel, node.lineno,
                    "LinearCompressionCfg without an explicit out_axis — "
                    "declare the output dim's logical axis, or out_axis="
                    "None if it is replicated (the VMEM cap is sized "
                    "against this)")
                continue
            for axis in _literal_axes(kw.value):
                if axis not in vocab:
                    yield Finding(
                        "partition-coverage", ctx.rel, kw.value.lineno,
                        f"out_axis={axis!r} is not in the logical-axis "
                        f"vocabulary {sorted(vocab)}")
                elif axis not in tp_sharded:
                    yield Finding(
                        "partition-coverage", ctx.rel, kw.value.lineno,
                        f"out_axis={axis!r} is never sharded by the TP "
                        "layout — a dead declaration (use None)")


# ---------------------------------------------------------------------------
# import half
# ---------------------------------------------------------------------------

def _rule_matchers(partition_path: str):
    """Ordered (lineno, frozenset_of_last_names) per ``_param_rule`` branch
    that dispatches on the leaf's last path component."""
    with open(partition_path, encoding="utf-8") as f:
        tree = ast.parse(f.read())
    fn = next(n for n in ast.walk(tree)
              if isinstance(n, ast.FunctionDef) and n.name == "_param_rule")
    matchers = []
    for node in ast.walk(fn):
        if not (isinstance(node, ast.If)
                and isinstance(node.test, ast.Compare)):
            continue
        test = node.test
        if not (isinstance(test.left, ast.Name) and test.left.id == "last"):
            continue
        comp = test.comparators[0]
        if isinstance(test.ops[0], ast.Eq) and isinstance(comp, ast.Constant):
            matchers.append((node.lineno, frozenset([comp.value])))
        elif isinstance(test.ops[0], ast.In) and isinstance(
                comp, (ast.Tuple, ast.List)):
            names = frozenset(e.value for e in comp.elts
                              if isinstance(e, ast.Constant))
            matchers.append((node.lineno, names))
    return matchers


def _abstract_mesh():
    import jax.sharding as js
    return js.AbstractMesh((("data", 2), ("model", 4)))


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _check_coverage(root: str):
    import jax

    from repro.configs.registry import ARCHS, get_config
    from repro.models.registry import build_model
    from repro.parallel import partition

    matchers = _rule_matchers(os.path.join(root, *PARTITION.split("/")))
    if not matchers:                                      # pragma: no cover
        yield Finding("partition-coverage", PARTITION, 1,
                      "could not extract any `last`-name matchers from "
                      "_param_rule — the coverage check is blind")
        return
    mesh = _abstract_mesh()
    rule_line = min(line for line, _ in matchers)

    uncovered: dict[tuple, set] = {}
    ambiguous: dict[tuple, set] = {}
    prev_layout = partition.LAYOUT
    try:
        for arch in ARCHS:
            cfg = get_config(arch).reduced()
            struct = build_model(cfg).init_struct()
            flat, _ = jax.tree_util.tree_flatten_with_path(struct)
            leaves = [(_leaf_name(p), len(leaf.shape), leaf.shape)
                      for p, leaf in flat]
            for layout in LAYOUTS:
                partition.set_layout(layout)
                try:
                    partition.param_specs(cfg, struct, mesh)
                except Exception as e:
                    yield Finding(
                        "partition-coverage", PARTITION, rule_line,
                        f"param_specs raised for arch={arch} "
                        f"layout={layout}: {e!r}")
                    continue
                for name, ndim, shape in leaves:
                    last = name.split("/")[-1]
                    hits = [line for line, names in matchers
                            if last in names]
                    if len(hits) > 1:
                        ambiguous.setdefault((last, tuple(hits)),
                                             set()).add(arch)
                    elif not hits and ndim >= 2 and \
                            last not in REPLICATED_OK:
                        uncovered.setdefault((last, ndim),
                                             set()).add(f"{arch}:{layout}")
    finally:
        partition.set_layout(prev_layout)

    for (last, ndim), cells in sorted(uncovered.items()):
        sample = ", ".join(sorted(cells)[:3])
        yield Finding(
            "partition-coverage", PARTITION, rule_line,
            f"param leaf {last!r} ({ndim}-d; e.g. {sample}) matches no "
            "_param_rule branch — it silently replicates; add a rule or "
            "extend the replicated-by-design set")
    for (last, hits), archs in sorted(ambiguous.items()):
        yield Finding(
            "partition-coverage", PARTITION, hits[1],
            f"param leaf {last!r} matches {len(hits)} _param_rule branches "
            f"(lines {list(hits)}) — resolution is order-dependent")


@rule("partition-coverage", scope="tree",
      doc="every param path resolves to exactly one partition rule per "
          "layout; every declared out_axis is a real, TP-sharded axis")
def check_partition(root: str, contexts):
    yield from _check_out_axes(contexts)
    yield from _check_coverage(root)
