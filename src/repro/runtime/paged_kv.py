"""Host-side block accounting for the block-paged KV cache.

The device side (``models/attention.py``, ``kernels/paged_attention.py``)
sees one shared pool of ``n_blocks`` physical KV blocks per attention layer
plus a ``(max_batch, max_len // block_size)`` block table mapping each slot's
logical block index to a physical block.  This module owns the table: which
physical blocks are free, which slot owns which, and when admission must
back-pressure because the pool is exhausted.

Conventions:

* **Physical block 0 is the trash block.**  Every unallocated table entry
  points at it, so the lock-step decode kernel can scatter/gather for
  *inactive* slots without branching — their writes land in trash and their
  reads are fully masked (fully-masked softmax columns contribute exact
  zeros, see DESIGN.md §12).  Block 0 is never handed out.
* Allocation is whole-request-atomic at admission (``admit``) and
  block-at-a-time during decode (``ensure``); both fail soft (return False)
  so the scheduler can queue or preempt instead of raising.
* Internal fragmentation is bounded by construction: a slot owns exactly
  ``ceil(used_positions / block_size)`` blocks, so it wastes at most
  ``block_size - 1`` positions (asserted in tests/test_paged_kv.py).
"""
from __future__ import annotations

import numpy as np

TRASH_BLOCK = 0


class PagedKVManager:
    """Free-list + per-slot block-table bookkeeping (pure host, no jax)."""

    def __init__(self, n_blocks: int, block_size: int, max_batch: int,
                 max_len: int):
        if block_size < 1:
            raise ValueError(f"block_size={block_size} must be >= 1")
        if max_len % block_size:
            raise ValueError(f"max_len={max_len} must divide by "
                             f"block_size={block_size} (the gathered paged "
                             "view must equal the dense cache extent)")
        if n_blocks < 2:
            raise ValueError(f"n_blocks={n_blocks} must be >= 2 "
                             "(block 0 is reserved as the trash block)")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_batch = max_batch
        self.blocks_per_slot = max_len // block_size
        # LIFO free list: a freed block is reused by the very next allocation
        # (cache-friendly, and makes reuse-after-retirement directly testable)
        self._free = list(range(1, n_blocks))
        self._owned: list[list[int]] = [[] for _ in range(max_batch)]
        self.table = np.full((max_batch, self.blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self.peak_used_blocks = 0

    # --- accounting ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def blocks_for(self, n_positions: int) -> int:
        """Physical blocks covering ``n_positions`` cache positions."""
        return -(-n_positions // self.block_size)

    def can_admit(self, n_positions: int) -> bool:
        return self.blocks_for(n_positions) <= len(self._free)

    def owned_blocks(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def internal_fragmentation(self, slot: int, used_positions: int) -> int:
        """Allocated-but-unused positions for a slot at depth
        ``used_positions`` — bounded by ``block_size - 1``."""
        return len(self._owned[slot]) * self.block_size - used_positions

    def _grab(self, slot: int) -> int:
        phys = self._free.pop()
        row = self._owned[slot]
        self.table[slot, len(row)] = phys
        row.append(phys)
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks)
        return phys

    # --- allocation ---------------------------------------------------------

    def admit(self, slot: int, n_positions: int) -> bool:
        """Atomically allocate blocks covering ``n_positions`` for a fresh
        slot.  Returns False (allocating nothing) when the pool cannot cover
        the request — admission back-pressure, not an error."""
        if self._owned[slot]:
            raise ValueError(f"slot {slot} already owns blocks; release first")
        need = self.blocks_for(n_positions)
        if need > len(self._free):
            return False
        for _ in range(need):
            self._grab(slot)
        return True

    def ensure(self, slot: int, position: int) -> bool:
        """Grow ``slot`` so cache ``position`` is backed by a real block.
        Returns False when the pool is exhausted (caller preempts/queues)."""
        need = position // self.block_size + 1
        if need > self.blocks_per_slot:
            raise ValueError(f"position {position} beyond max_len "
                             f"({self.blocks_per_slot} blocks/slot)")
        while len(self._owned[slot]) < need:
            if not self._free:
                return False
            self._grab(slot)
        return True

    def release(self, slot: int) -> list[int]:
        """Return all of ``slot``'s blocks to the pool and point its table
        row back at the trash block.  Returns the freed block ids."""
        freed = self._owned[slot]
        self._owned[slot] = []
        self.table[slot, :] = TRASH_BLOCK
        self._free.extend(freed)
        if len(self._free) > self.n_blocks - 1:
            raise AssertionError("double free: pool over-full")
        return freed
