"""Paper Fig. 5 (Raspberry Pi 5): forward/backward latency of MCUNet training
under vanilla / HOSVD_eps / ASI.

No RPi here — two complementary measurements:
  1. cost-model ratios on the paper's MCUNet shapes (the 106x HOSVD forward
     blow-up, the ~4x low-rank backward speed-up, ASI net > 1x vs vanilla);
  2. real wall-clock on THIS host for the reduced MCUNet-mini: jitted
     fwd+bwd step time of vanilla vs ASI vs HOSVD — the ordering must match
     the paper's figure (HOSVD ≫ vanilla ≥ ASI is the headline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import flops as F
from repro.models import convnets

from benchmarks.paper_shapes import PAPER_MODELS, RANK1

BATCH = 16


def cost_model_ratios():
    layers = PAPER_MODELS["mcunet"][:2]
    fwd_van = sum(F.vanilla_forward_flops(cd) for cd in layers)
    fwd_hosvd = fwd_van + sum(F.hosvd_overhead_flops(cd) for cd in layers)
    fwd_asi = fwd_van + sum(F.asi_overhead_flops(cd, RANK1) for cd in layers)
    bwd_van = sum(F.vanilla_backward_weight_flops(cd) for cd in layers)
    bwd_low = sum(F.asi_backward_weight_flops(cd, RANK1) for cd in layers)
    return {
        "fwd_hosvd_over_vanilla": fwd_hosvd / fwd_van,
        "fwd_asi_over_vanilla": fwd_asi / fwd_van,
        "bwd_speedup_lowrank": bwd_van / bwd_low,
        "asi_step_speedup": (fwd_van + bwd_van) / (fwd_asi + bwd_low),
    }


def _step_time(compress: str, steps=5) -> float:
    cfg = convnets.mcunet_mini(num_classes=10, compress=compress, last_k=2,
                               ranks=(2, 2, 2, 2))
    key = jax.random.PRNGKey(0)
    params = convnets.init_params(key, cfg)
    st = (convnets.init_asi_state(key, cfg, batch=BATCH)
          if compress == "asi" else None)
    batch = {"images": jax.random.normal(key, (BATCH, 3, 32, 32)),
             "labels": jnp.zeros((BATCH,), jnp.int32)}

    @jax.jit
    def step(params, st):
        def lossf(p):
            loss, (m, ns) = convnets.loss_fn(p, batch, cfg, st)
            return loss
        return jax.grad(lossf)(params)

    step(params, st)                     # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        jax.block_until_ready(step(params, st))
    return (time.perf_counter() - t0) / steps * 1e6     # us


def run(verbose=True):
    ratios = cost_model_ratios()
    times = {c: _step_time(c) for c in ("none", "asi", "hosvd")}
    if verbose:
        print("cost-model (paper MCUNet shapes, rank-1):")
        for k, v in ratios.items():
            print(f"  {k}: {v:.2f}x")
        print("measured on this host (reduced MCUNet-mini, us/step):")
        for k, v in times.items():
            print(f"  {k}: {v:,.0f}")
    # headline orderings from the paper's figure
    assert ratios["fwd_hosvd_over_vanilla"] > 20     # 106x on RPi
    assert ratios["bwd_speedup_lowrank"] > 2         # ~3.95x on RPi
    assert ratios["asi_step_speedup"] > 1            # ~1.56x on RPi
    # Wall-clock on x86: both compressed modes beat vanilla via the low-rank
    # backward.  The ASI-vs-HOSVD wall-time gap needs RPi-class BLAS or
    # larger maps to manifest (LAPACK gesdd is fast at these sizes); the
    # FLOP-model ratios above carry the paper's claim.  See EXPERIMENTS.md.
    assert times["asi"] < times["none"]
    assert times["hosvd"] < times["none"]
    return {"ratios": ratios, "times_us": times}


if __name__ == "__main__":
    run()
