"""donation-audit: every declared donation must be an actual alias.

``donate_argnums`` is a *request*; XLA silently drops the donations it
cannot match to an output (dtype/shape mismatch, buffer still live), and
a dropped donation on the KV cache or optimizer state is a silent 2x on
exactly the buffers the paper's memory claims count.  The lowered module
records the compiler's decision as a ``tf.aliasing_output`` attribute on
each ``@main`` parameter it will reuse, so the audit is device-free:
lower each declared donation site with abstract arguments (CPU lowering
still records aliasing even though the CPU runtime ignores donation —
the serve engine takes ``donate=True`` to force the request on) and
demand one alias per donated leaf.

Sites covered: the sharded/unsharded train step and the serve engine's
decode hot path — ``_step``, ``_write_slot`` (dense), ``_step_paged``,
``_write_paged`` (paged), and the chunked-prefill ``_chunk_runner``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Iterator

import jax
import jax.numpy as jnp

from repro.analysis.core import Finding, rule
from repro.analysis.graph import harness

TRAIN_REL = "src/repro/runtime/train_loop.py"
SERVE_REL = "src/repro/runtime/serve_loop.py"
ARCH_ENV = "REPRO_GRAPH_DONATION_ARCH"
DEFAULT_ARCH = "tinyllama-1.1b"


@dataclasses.dataclass
class DonationSite:
    """One jitted call site with declared donations, ready to lower."""
    name: str
    path: str                  # repo-relative anchor file
    marker: str                # source line locating the jit construction
    jitted: Any
    example_args: tuple
    donate_argnums: tuple


def _i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def collect_sites(arch: str = DEFAULT_ARCH) -> list[DonationSite]:
    """Build every donation site on one representative family with
    abstract example arguments (nothing here touches a device)."""
    from repro.configs.registry import get_config
    from repro.data.synthetic import LMStream, LMStreamCfg
    from repro.models import build_model
    from repro.optim.optimizers import make_optimizer
    from repro.optim.schedules import warmup_cosine
    from repro.runtime.serve_loop import Engine, ServeCfg
    from repro.runtime.train_loop import make_train_step

    cfg = get_config(arch).reduced().replace(compress="asi")
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    key_struct = jax.ShapeDtypeStruct(key.shape, key.dtype)
    params = jax.eval_shape(api.init, key)
    asi = jax.eval_shape(api.init_asi, key)
    mask = api.trainable_mask(params)
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 1, 4), clip_norm=2.0)
    opt_state = jax.eval_shape(opt.init, params)
    batch = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4, seed=0,
                                 branching=2)).batch(0)
    step = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                           trainable_mask=mask, donate=True,
                           kernel_backend=cfg.kernel_backend)
    sites = [DonationSite(
        name="train._step", path=TRAIN_REL, marker="jit_kw",
        jitted=step,
        example_args=(params, opt_state, asi, batch, _i32()),
        donate_argnums=(0, 1, 2))]

    B, max_len, bs = 2, 32, 8
    scfg = ServeCfg(max_batch=B, max_len=max_len, cache="dense",
                    prefill_chunk=bs)
    eng = Engine(api, params, scfg, donate=True)
    state = {"tok": _i32((B,)), "pos": _i32((B,)), "rem": _i32((B,)),
             "active": jax.ShapeDtypeStruct((B,), jnp.bool_)}
    cache = jax.eval_shape(lambda: api.init_cache(B, max_len))
    one = jax.eval_shape(lambda: api.init_cache(1, max_len))
    sites += [
        DonationSite(name="serve._step", path=SERVE_REL,
                     marker="self._step = jax.jit",
                     jitted=eng._step,
                     example_args=(params, cache, state, key_struct),
                     donate_argnums=(1, 2)),
        DonationSite(name="serve._write_slot", path=SERVE_REL,
                     marker="self._write_slot = jax.jit",
                     jitted=eng._write_slot,
                     example_args=(cache, one, _i32()),
                     donate_argnums=(0,)),
        DonationSite(name="serve._chunk_runner", path=SERVE_REL,
                     marker="fn = jax.jit(scan_chunk",
                     jitted=eng._chunk_runner(bs, None),
                     example_args=(params, one, _i32((bs,)), _i32(), _i32()),
                     donate_argnums=(1,)),
    ]

    pcfg = ServeCfg(max_batch=B, max_len=max_len, cache="paged",
                    page_block=bs, pool_blocks=B * (max_len // bs) + 1)
    peng = Engine(api, params, pcfg, donate=True)
    pcache = jax.eval_shape(
        lambda: api.init_paged_cache(B, peng._pool_blocks, bs))
    table = _i32((B, max_len // bs))
    sites += [
        DonationSite(name="serve._step_paged", path=SERVE_REL,
                     marker="self._step_paged = jax.jit",
                     jitted=peng._step_paged,
                     example_args=(params, pcache, state, table, key_struct),
                     donate_argnums=(1, 2)),
        DonationSite(name="serve._write_paged", path=SERVE_REL,
                     marker="self._write_paged = jax.jit",
                     jitted=peng._write_paged,
                     example_args=(pcache, one, _i32((max_len // bs,)),
                                   _i32()),
                     donate_argnums=(0,)),
    ]
    return sites


def _marker_line(root: str, rel: str, marker: str) -> int:
    try:
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            for lineno, text in enumerate(f, start=1):
                if marker in text:
                    return lineno
    except OSError:
        pass
    return 1


def site_findings(site: DonationSite, root: str) -> Iterator[Finding]:
    donated, aliased = harness.audit_donation(
        site.jitted, site.example_args, site.donate_argnums)
    if aliased < donated:
        yield Finding(
            rule="donation-audit", path=site.path,
            line=_marker_line(root, site.path, site.marker),
            message=f"{site.name}: {donated - aliased} of {donated} donated "
                    f"buffer(s) not aliased in the lowered module — dead "
                    f"donation(s); the freed-in-place memory the serve/"
                    f"train budget counts on is not actually freed")
    elif donated == 0:
        yield Finding(
            rule="donation-audit", path=site.path,
            line=_marker_line(root, site.path, site.marker),
            message=f"{site.name}: declared donation site donates nothing")


@rule("donation-audit", scope="tree", plane="graph",
      doc="declared donate_argnums in train/serve jits are actually "
          "aliased in the lowered executable (tf.aliasing_output)")
def check_donation(root, contexts) -> Iterator[Finding]:
    arch = os.environ.get(ARCH_ENV, DEFAULT_ARCH)
    for site in collect_sites(arch):
        yield from site_findings(site, root)
