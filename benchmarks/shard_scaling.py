"""Sharded-training scaling: step time and per-device memory vs device count.

For dense / MoE / SSM reduced configs, run the mesh-sharded train step
(layout=fsdp, the per-device-memory layout) on 1 / 2 / 4 / 8 forced
host-platform devices and record:

* ``step_ms``  — measured wall-clock per optimizer step (after warmup);
* ``arg_mb``   — per-device bytes of the compiled step's live arguments
                 (params + optimizer state + batch shards; this is what
                 FSDP shrinks as the mesh grows);
* ``temp_mb``  — per-device XLA temp allocation (activation workspace —
                 what ASI's activation compression shrinks).

Both memory numbers come from XLA's compiled-program
``memory_analysis`` — the same per-device program a real accelerator would
run, so the scaling trend (not the absolute CPU numbers) is the signal.

Each device count needs its own XLA_FLAGS before jax import, so every cell
runs in a subprocess; the parent aggregates CSV rows.

Run:  PYTHONPATH=src python -m benchmarks.shard_scaling
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

ARCHS = [
    ("tinyllama-1.1b", "dense"),
    ("granite-moe-3b-a800m", "moe"),
    ("mamba2-130m", "ssm"),
]
DEVICE_COUNTS = (1, 2, 4, 8)
LAYOUT = "fsdp"
STEPS = 4          # timed steps after 1 warmup/compile step
BATCH = 8
SEQ = 16


def _cell(arch: str, n_dev: int, layout: str) -> dict:
    """Runs inside the subprocess: one (arch, device-count) measurement."""
    import contextlib

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.data.synthetic import LMStream, LMStreamCfg
    from repro.launch.mesh import make_layout_mesh
    from repro.models import build_model
    from repro.optim.optimizers import make_optimizer
    from repro.optim.schedules import warmup_cosine
    from repro.runtime.train_loop import make_mesh_plan, make_train_step

    cfg = get_config(arch).reduced().replace(compress="asi")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    asi = api.init_asi(jax.random.PRNGKey(0))
    mask = api.trainable_mask(params)
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 1, 100), clip_norm=2.0)
    opt_state = opt.init(params)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                global_batch=BATCH, seed=0, branching=2))

    plan = None
    if n_dev > 1:
        plan = make_mesh_plan(cfg, make_layout_mesh(layout), layout,
                              params, opt_state, asi, data.batch(0))
        params, opt_state, asi = plan.shard_state(params, opt_state, asi)
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=mask, plan=plan)

    ctx = plan.activate() if plan else contextlib.nullcontext()
    with ctx:
        batch = data.batch(0)
        if plan:
            batch = plan.shard_batch(batch)
        mem = {}
        try:
            ma = (step_fn.lower(params, opt_state, asi, batch, jnp.int32(0))
                  .compile().memory_analysis())
            if ma is not None:
                mem = {"arg_mb": ma.argument_size_in_bytes / 2**20,
                       "temp_mb": ma.temp_size_in_bytes / 2**20}
        except Exception as e:                                # noqa: BLE001
            mem = {"error": str(e)}
        # warmup (separate jit cache entry from the AOT compile above)
        params, opt_state, asi, m = step_fn(params, opt_state, asi, batch,
                                            jnp.int32(0))
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for t in range(1, STEPS + 1):
            b = data.batch(t)
            if plan:
                b = plan.shard_batch(b)
            params, opt_state, asi, m = step_fn(params, opt_state, asi, b,
                                                jnp.int32(t))
        jax.block_until_ready(m["loss"])
        step_ms = (time.perf_counter() - t0) / STEPS * 1e3
    return {"arch": arch, "n_dev": n_dev, "layout": layout,
            "step_ms": round(step_ms, 2),
            **{k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in mem.items()}}


def run(verbose: bool = True) -> dict:
    rows = []
    for arch, family in ARCHS:
        for n_dev in DEVICE_COUNTS:
            env = dict(os.environ,
                       XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
                       JAX_PLATFORMS="cpu",
                       PYTHONPATH=os.path.join(
                           os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))), "src"))
            p = subprocess.run(
                [sys.executable, "-m", "benchmarks.shard_scaling",
                 "--cell", arch, str(n_dev), LAYOUT],
                env=env, capture_output=True, text=True, timeout=1200)
            if p.returncode != 0:
                rows.append({"arch": arch, "n_dev": n_dev, "layout": LAYOUT,
                             "error": p.stderr[-500:]})
                continue
            row = json.loads(p.stdout.strip().splitlines()[-1])
            row["family"] = family
            rows.append(row)
            if verbose:
                print(f"{arch},{family},{n_dev},{row.get('step_ms')},"
                      f"{row.get('arg_mb')},{row.get('temp_mb')}")
    ok = [r for r in rows if "error" not in r]
    # headline: how much per-device argument memory FSDP sheds going 1 -> 8
    ratios = []
    for arch, _ in ARCHS:
        one = next((r for r in ok if r["arch"] == arch and r["n_dev"] == 1), None)
        eight = next((r for r in ok if r["arch"] == arch and r["n_dev"] == 8), None)
        if one and eight and one.get("arg_mb") and eight.get("arg_mb"):
            ratios.append(one["arg_mb"] / eight["arg_mb"])
    return {"rows": rows,
            "min_arg_mem_ratio_1to8": round(min(ratios), 2) if ratios else 0.0}


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--cell":
        arch, n_dev, layout = sys.argv[2], int(sys.argv[3]), sys.argv[4]
        print(json.dumps(_cell(arch, n_dev, layout)))
        return
    print("arch,family,n_dev,step_ms,arg_mb,temp_mb")
    out = run(verbose=True)
    print(json.dumps({"min_arg_mem_ratio_1to8": out["min_arg_mem_ratio_1to8"]}))


if __name__ == "__main__":
    main()
