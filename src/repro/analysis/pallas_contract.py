"""pallas-contract: static geometry checks for every ``pallas_call``.

For each ``pl.pallas_call(kernel, grid=..., in_specs=..., out_specs=...,
out_shape=...)(*operands)`` in ``kernels/``:

* one BlockSpec per operand, one out_spec per out_shape entry;
* every BlockSpec index_map takes exactly ``len(grid)`` arguments and
  returns one coordinate per block-shape dim (a mismatch compiles on the
  interpreter but mis-tiles on Mosaic);
* block shapes and their ShapeDtypeStructs agree in rank;
* ``pl.dslice(i * step, width)`` strides must step by exactly ``width`` —
  ``step != width`` silently reads overlapping or out-of-bounds columns of
  the padded dim;
* ``input_output_aliases`` indices must name a real operand (past the
  scalar-prefetch prefix) and a real out_shape entry, and an out_shape
  built from ``<operand>.shape`` without an alias back to that operand is
  a missed in-place update — the jit-side donation-audit (graph plane)
  sees the same defect as an unaliased donated buffer;
* ``GRAD_SKETCH_MAX_N`` is dispatch.py's private VMEM cap: referencing it
  anywhere else bypasses ``local_feature_dim``'s shard-awareness, and any
  dispatch function that divides widths by a mesh-axis size must consult
  ``_shard_local()`` (per-shard accounting is only sound inside a
  ``shard_local_kernels()`` scope).
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Finding, FileContext, call_name, dotted_name,
                                 rule)

KERNEL_SCOPE = "src/repro/kernels/"
DISPATCH = "src/repro/kernels/dispatch.py"


def _enclosing_assignments(fn: ast.FunctionDef) -> dict[str, ast.expr]:
    out: dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _resolve(node: ast.expr | None, env: dict) -> ast.expr | None:
    seen = 0
    while isinstance(node, ast.Name) and node.id in env and seen < 4:
        node = env[node.id]
        seen += 1
    return node


def _as_list(node: ast.expr | None) -> list | None:
    """Spec/shape arguments may be a single entry or a [list, of, entries]."""
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _blockspec_parts(node: ast.expr):
    """(block_shape_elts | None, index_map_lambda | None) of a BlockSpec."""
    if not (isinstance(node, ast.Call)
            and (call_name(node) or "").endswith("BlockSpec")):
        return None, None
    shape = node.args[0] if node.args else None
    index_map = node.args[1] if len(node.args) > 1 else None
    for kw in node.keywords:
        if kw.arg in ("block_shape",):
            shape = kw.value
        if kw.arg in ("index_map",):
            index_map = kw.value
    shape_elts = list(shape.elts) if isinstance(shape,
                                                (ast.Tuple, ast.List)) else None
    lam = index_map if isinstance(index_map, ast.Lambda) else None
    return shape_elts, lam


def _operand_base(node: ast.expr) -> str | None:
    """The name an operand expression is rooted at: ``pool`` for both
    ``pool`` and ``pool.astype(...)`` — fluent conversions don't change
    which buffer is being passed."""
    while isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        node = node.func.value
    return dotted_name(node)


def _check_aliases(ctx: FileContext, call: ast.Call, operands: list,
                   out_shape: list | None, nsp: int, env: dict):
    """Validate ``input_output_aliases`` and flag missed aliasing."""
    kw = {k.arg: k.value for k in call.keywords}
    aliases = _resolve(kw.get("input_output_aliases"), env)
    alias_map: dict[int, int] = {}
    starred = any(isinstance(a, ast.Starred) for a in operands)
    if isinstance(aliases, ast.Dict):
        for knode, vnode in zip(aliases.keys, aliases.values):
            kv, vv = _resolve(knode, env), _resolve(vnode, env)
            if not (isinstance(kv, ast.Constant) and isinstance(kv.value, int)
                    and isinstance(vv, ast.Constant)
                    and isinstance(vv.value, int)):
                continue
            alias_map[kv.value] = vv.value
            if kv.value < nsp:
                yield Finding(
                    "pallas-contract", ctx.rel, aliases.lineno,
                    f"input_output_aliases names input {kv.value}, a "
                    f"scalar-prefetch operand (first {nsp} operands) — "
                    "scalars cannot alias an output buffer")
            elif operands and not starred and kv.value >= len(operands):
                yield Finding(
                    "pallas-contract", ctx.rel, aliases.lineno,
                    f"input_output_aliases names input {kv.value} but the "
                    f"pallas_call is applied to {len(operands)} operands")
            if out_shape is not None and vv.value >= len(out_shape):
                yield Finding(
                    "pallas-contract", ctx.rel, aliases.lineno,
                    f"input_output_aliases names output {vv.value} but "
                    f"only {len(out_shape)} out_shape entries are declared")
    if out_shape is None or not operands or starred:
        return
    bases = [_operand_base(a) for a in operands]
    for oi, entry in enumerate(out_shape):
        entry = _resolve(entry, env)
        if not (isinstance(entry, ast.Call)
                and (call_name(entry) or "").endswith("ShapeDtypeStruct")
                and entry.args):
            continue
        shape_arg = entry.args[0]
        if not (isinstance(shape_arg, ast.Attribute)
                and shape_arg.attr == "shape"):
            continue
        src = dotted_name(shape_arg.value)
        for ii, base in enumerate(bases):
            if src is not None and base == src and alias_map.get(ii) != oi:
                yield Finding(
                    "pallas-contract", ctx.rel, entry.lineno,
                    f"out_shape[{oi}] reuses {src}.shape but operand {ii} "
                    f"is not aliased to it — the kernel materializes a "
                    f"full copy of {src}; declare "
                    f"input_output_aliases={{{ii}: {oi}}} for an in-place "
                    "update")


def _check_pallas_call(ctx: FileContext, call: ast.Call, operands: list,
                       env: dict):
    kw = {k.arg: k.value for k in call.keywords}
    # PrefetchScalarGridSpec bundles the geometry and prepends
    # num_scalar_prefetch operands whose values feed every index_map: the
    # leading scalar operands have no BlockSpec, and index_maps take
    # len(grid) + num_scalar_prefetch arguments.
    nsp = 0
    gs = _resolve(kw.get("grid_spec"), env)
    if isinstance(gs, ast.Call) and \
            (call_name(gs) or "").endswith("PrefetchScalarGridSpec"):
        gkw = {k.arg: k.value for k in gs.keywords}
        kw = {**kw, **{k: gkw[k] for k in ("grid", "in_specs", "out_specs")
                       if k in gkw}}
        nsp_node = _resolve(gkw.get("num_scalar_prefetch"), env)
        if isinstance(nsp_node, ast.Constant) and \
                isinstance(nsp_node.value, int):
            nsp = nsp_node.value
    grid = _resolve(kw.get("grid"), env)
    n_grid = len(grid.elts) if isinstance(grid, (ast.Tuple, ast.List)) else None

    in_specs = _as_list(_resolve(kw.get("in_specs"), env))
    out_specs = _as_list(_resolve(kw.get("out_specs"), env))
    out_shape = _as_list(_resolve(kw.get("out_shape"), env))

    if in_specs is not None and operands and \
            not any(isinstance(a, ast.Starred) for a in operands) and \
            len(in_specs) + nsp != len(operands):
        yield Finding("pallas-contract", ctx.rel, call.lineno,
                      f"pallas_call declares {len(in_specs)} in_specs"
                      + (f" (+ {nsp} scalar-prefetch operands)" if nsp else "")
                      + f" but is applied to {len(operands)} operands")
    if out_specs is not None and out_shape is not None and \
            len(out_specs) != len(out_shape):
        yield Finding("pallas-contract", ctx.rel, call.lineno,
                      f"pallas_call declares {len(out_specs)} out_specs but "
                      f"{len(out_shape)} out_shape entries")
    yield from _check_aliases(ctx, call, operands, out_shape, nsp, env)

    def check_spec(spec_node, what: str, rank_hint: int | None):
        shape_elts, lam = _blockspec_parts(_resolve(spec_node, env))
        if shape_elts is None:
            return
        if n_grid is not None and lam is not None and \
                len(lam.args.args) != n_grid + nsp:
            yield Finding(
                "pallas-contract", ctx.rel, lam.lineno,
                f"{what}: index_map takes {len(lam.args.args)} args but the "
                f"grid has {n_grid} dims"
                + (f" plus {nsp} scalar-prefetch refs" if nsp else ""))
        if lam is not None and isinstance(lam.body, (ast.Tuple, ast.List)) \
                and len(lam.body.elts) != len(shape_elts):
            yield Finding(
                "pallas-contract", ctx.rel, lam.lineno,
                f"{what}: index_map returns {len(lam.body.elts)} block "
                f"coords for a {len(shape_elts)}-d block shape")
        if rank_hint is not None and len(shape_elts) != rank_hint:
            yield Finding(
                "pallas-contract", ctx.rel, spec_node.lineno,
                f"{what}: block shape is {len(shape_elts)}-d but its "
                f"out_shape entry is {rank_hint}-d")

    for i, spec in enumerate(in_specs or []):
        yield from check_spec(spec, f"in_specs[{i}]", None)
    for i, spec in enumerate(out_specs or []):
        rank = None
        if out_shape is not None and i < len(out_shape):
            entry = _resolve(out_shape[i], env)
            if isinstance(entry, ast.Call) and \
                    (call_name(entry) or "").endswith("ShapeDtypeStruct") \
                    and entry.args and isinstance(entry.args[0],
                                                  (ast.Tuple, ast.List)):
                rank = len(entry.args[0].elts)
        yield from check_spec(spec, f"out_specs[{i}]", rank)


def _check_dslices(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) in ("pl.dslice", "pl.ds", "dslice")
                and len(node.args) >= 2):
            continue
        start, width = node.args[0], node.args[1]
        if not (isinstance(start, ast.BinOp)
                and isinstance(start.op, ast.Mult)):
            continue
        # i * step: the non-index factor must equal the slice width
        factors = [dotted_name(start.left) or
                   (start.left.value if isinstance(start.left, ast.Constant)
                    else None),
                   dotted_name(start.right) or
                   (start.right.value if isinstance(start.right, ast.Constant)
                    else None)]
        width_key = dotted_name(width) if not isinstance(width, ast.Constant) \
            else width.value
        if width_key is not None and width_key not in factors:
            yield Finding(
                "pallas-contract", ctx.rel, node.lineno,
                f"pl.dslice steps by {factors} but slices {width_key!r} "
                "columns — a step != width over-indexes or overlaps the "
                "padded dim")


def _check_cap(ctx: FileContext):
    """GRAD_SKETCH_MAX_N / shard-local discipline."""
    if ctx.rel == DISPATCH:
        for fn in (n for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.FunctionDef)):
            loads = {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}
            attrs = {n.attr for n in ast.walk(fn)
                     if isinstance(n, ast.Attribute)}
            if ("_mesh_axis_size" in loads or "_mesh_axis_size" in attrs) \
                    and "_shard_local" not in loads:
                yield Finding(
                    "pallas-contract", ctx.rel, fn.lineno,
                    f"{fn.name} divides widths by a mesh-axis size without "
                    "consulting _shard_local() — per-shard VMEM accounting "
                    "is only sound inside shard_local_kernels()")
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Name) and node.id == "GRAD_SKETCH_MAX_N":
            yield Finding(
                "pallas-contract", ctx.rel, node.lineno,
                "GRAD_SKETCH_MAX_N referenced outside kernels/dispatch.py — "
                "go through dispatch.matmul_grad_sketch / local_feature_dim "
                "so the cap stays shard-aware")
        if isinstance(node, ast.Attribute) and \
                node.attr == "GRAD_SKETCH_MAX_N" and \
                dotted_name(node.value) not in ("dispatch",):
            yield Finding(
                "pallas-contract", ctx.rel, node.lineno,
                "GRAD_SKETCH_MAX_N referenced outside kernels/dispatch.py — "
                "go through dispatch helpers so the cap stays shard-aware")


@rule("pallas-contract",
      doc="BlockSpec/grid geometry, dslice strides, input_output_aliases "
          "validity, and the GRAD_SKETCH_MAX_N shard-local discipline")
def check_pallas(ctx: FileContext):
    if not ctx.rel.startswith("src/repro/"):
        return
    if ctx.rel.startswith(KERNEL_SCOPE):
        for fn in (n for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.FunctionDef)):
            env = _enclosing_assignments(fn)
            for node in ast.walk(fn):
                # pl.pallas_call(...)(operands); a bare pallas_call that is
                # stored and applied later has no operand list to check, so
                # only the applied form is geometry-checked.
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Call) and \
                        (call_name(node.func) or "").endswith("pallas_call"):
                    yield from _check_pallas_call(ctx, node.func, node.args,
                                                  env)
        yield from _check_dslices(ctx)
    yield from _check_cap(ctx)
