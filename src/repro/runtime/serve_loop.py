"""Batched decode serving: continuous-batching style request loop.

Requests carry a prompt; the scheduler packs up to ``max_batch`` active
sequences, primes caches via prefill, then steps all of them together with
one jitted ``decode_step``, retiring finished sequences and admitting new
ones into freed slots (slot reuse = the KV cache row is overwritten by the
next prefill).  Greedy sampling by default; temperature optional.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeCfg:
    max_batch: int = 4
    max_len: int = 128
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early


class Engine:
    """Single-host serving engine over a ModelAPI."""

    def __init__(self, model_api, params, cfg: ServeCfg, seed: int = 0):
        self.api = model_api
        self.params = params
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: model_api.decode_step(p, c, t, pos))

    def _prefill_one(self, cache, slot: int, prompt: Sequence[int]):
        """Feed a prompt token-by-token into one batch slot (slot-sliced
        decode would need gather/scatter over caches; per-token prefill keeps
        the engine simple and is exact)."""
        toks = list(prompt)
        logits = None
        for pos, t in enumerate(toks):
            tok_vec = self._slot_tokens(slot, t)
            logits, cache = self._decode(self.params, cache, tok_vec,
                                         jnp.int32(pos))
        return cache, logits, len(toks)

    def _slot_tokens(self, slot: int, tok: int) -> Array:
        v = np.zeros((self.cfg.max_batch,), np.int32)
        v[slot] = tok
        return jnp.asarray(v)

    def run(self, requests: list[Request]) -> list[Request]:
        """Sequential-slot scheduling: each request decodes in its own slot;
        a shared position counter per slot tracks cache occupancy."""
        pending = list(requests)
        results = []
        while pending:
            active = pending[: self.cfg.max_batch]
            pending = pending[len(active):]
            cache = self.api.init_cache(self.cfg.max_batch, self.cfg.max_len)
            for slot, req in enumerate(active):
                cache, logits, pos = self._prefill_one(cache, slot, req.prompt)
                for _ in range(req.max_new_tokens):
                    row = logits[slot]
                    if self.cfg.temperature > 0:
                        self.key, sub = jax.random.split(self.key)
                        tok = int(jax.random.categorical(
                            sub, row / self.cfg.temperature))
                    else:
                        tok = int(jnp.argmax(row))
                    req.out.append(tok)
                    if tok == self.cfg.eos_id or pos + 1 >= self.cfg.max_len:
                        break
                    logits, cache = self._decode(
                        self.params, cache, self._slot_tokens(slot, tok),
                        jnp.int32(pos))
                    pos += 1
                req.done = True
                results.append(req)
        return results
