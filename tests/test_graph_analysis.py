"""graph-lint: the jaxpr/HLO plane proves what the AST plane cannot see.

The centerpiece is the blindness canary: a custom_vjp whose fwd saves a
dense activation *behind an imported call*, which severs the AST taint —
the source rule stays quiet while the residual census flags the save from
the traced graph.  Around it: ledger reconciliation on the real tree,
comm-signature gating with a deliberately wrong signature, donation
aliasing on synthetic jits and the real serve/train sites, signature-key
hashing, and the aliased paged-pool write kernel against its jnp oracle.
"""
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import core
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.core import FileContext
from repro.analysis.graph import (collectives_audit, donation_audit, harness,
                                  recompile_audit, residual_audit)

REPO_ROOT = core.find_repo_root()
ARCH = "tinyllama-1.1b"


def _family():
    from repro.configs.registry import get_config
    from repro.models import build_model
    cfg = get_config(ARCH).reduced().replace(compress="asi")
    return cfg, build_model(cfg)


# ---------------------------------------------------------------------------
# plane registry
# ---------------------------------------------------------------------------

def test_graph_rules_registered_in_graph_plane():
    graph = set(core.rules_in_plane("graph"))
    assert graph == {"residual-audit", "collectives-audit",
                     "donation-audit", "recompile-audit"}
    assert not graph & set(core.rules_in_plane("ast"))


# ---------------------------------------------------------------------------
# residual-audit: the blindness canary
# ---------------------------------------------------------------------------

# The dense save rides through jax.nn.relu — an *imported* call, which the
# AST taint analysis treats as severing (imported code is assumed to
# contract/sketch).  The graph census classifies by residual shape, so the
# construct is transparent to it.
_CANARY_SRC = """\
    import jax
    import jax.numpy as jnp


    @jax.custom_vjp
    def leaky_matmul(x, w):
        return jax.nn.relu(x) @ w


    def _fwd(x, w):
        h = jax.nn.relu(x)        # imported call: AST taint severed here
        return h @ w, (h, w)      # ...but h IS the dense activation


    def _bwd(res, g):
        h, w = res
        return ((h > 0) * (g @ w.T), h.T @ g)


    leaky_matmul.defvjp(_fwd, _bwd)
"""


def test_canary_is_invisible_to_ast_taint(tmp_path):
    path = tmp_path / "src" / "repro" / "core" / "canary.py"
    path.parent.mkdir(parents=True)
    path.write_text(textwrap.dedent(_CANARY_SRC))
    ctx = FileContext.parse(str(path), str(tmp_path))
    _scope, fn, _doc = core.RULES["residual-contract"]
    found = [f for f in fn(ctx)
             if not ctx.is_suppressed(f.rule, f.line)]
    assert found == [], [f.message for f in found]


def test_canary_is_caught_by_residual_census():
    cfg, api = _family()
    ns: dict = {}
    exec(textwrap.dedent(_CANARY_SRC), ns)  # the exact source the AST saw
    leaky_matmul = ns["leaky_matmul"]
    _led, _exp, site_ks, token_extents = harness.ledger_expectation(
        cfg, harness.CENSUS_BATCH, harness.CENSUS_SEQ)
    tokens, k = max(token_extents), max(site_ks)

    def canary_loss(params, batch, asi):
        loss, aux = api.loss(params, batch, asi)
        x = jnp.zeros((tokens, k), jnp.float32) + loss
        w = jnp.zeros((k, 5), jnp.float32)
        return loss + leaky_matmul(x, w).sum(), aux

    baseline = harness.census_family(ARCH, cfg, api)
    canary = harness.census_family(ARCH, cfg, api, loss_fn=canary_loss)
    assert canary.counts.get("dense", 0) == \
        baseline.counts.get("dense", 0) + 1
    findings = list(residual_audit.census_findings([canary]))
    assert any("dense activation saved as vjp residual" in f.message
               for f in findings), [f.message for f in findings]


def test_residual_census_reconciles_against_ledger():
    cfg, api = _family()
    census = harness.census_family(ARCH, cfg, api)
    assert census.factor_match, "saved factors != ledger's predicted multiset"
    assert census.factor_bytes == census.ledger_bytes, \
        f"{census.factor_bytes} != {census.ledger_bytes} (gap must be 0%)"


def test_residual_audit_clean_at_head_one_family(monkeypatch):
    monkeypatch.setenv(harness.FAMILIES_ENV, ARCH)
    findings = core.run_lint(root=REPO_ROOT, select=["residual-audit"])
    bad = [f for f in findings if not f.suppressed]
    assert bad == [], "\n" + core.render_text(bad)
    # the blessed dense saves (norm/activation/loss tail) stay visible
    assert any(f.suppressed for f in findings)


def test_golden_drift_is_a_finding():
    cfg, api = _family()
    census = harness.census_family(ARCH, cfg, api)
    golden = residual_audit.load_golden()
    assert golden["families"][ARCH] == census.summary()
    skewed = {"families": {ARCH: {**census.summary(), "factor_bytes": 1}}}
    findings = list(residual_audit.census_findings([census], golden=skewed))
    assert any("drifted from golden" in f.message for f in findings)
    missing = list(residual_audit.census_findings([census],
                                                  golden={"families": {}}))
    assert any("no golden census entry" in f.message for f in missing)


# ---------------------------------------------------------------------------
# collectives-audit: signature gating (device-free half)
# ---------------------------------------------------------------------------

_DP_COUNTS = {"all-gather": 14, "all-reduce": 36}


def test_comm_signature_accepts_measured_counts():
    from repro.parallel.partition import COMM_SIGNATURE
    assert list(collectives_audit.signature_findings(
        "dp", _DP_COUNTS, COMM_SIGNATURE)) == []


def test_comm_signature_flags_forbidden_kind():
    sig = {"dp": {"all-gather": (0, None), "all-reduce": (1, None)}}
    counts = dict(_DP_COUNTS, **{"collective-permute": 12})
    findings = list(collectives_audit.signature_findings("dp", counts, sig))
    assert any("forbids collective-permute" in f.message
               for f in findings), [f.message for f in findings]


def test_comm_signature_flags_count_out_of_bounds():
    sig = {"dp": {"all-gather": (0, None), "all-reduce": (1, 10)}}
    findings = list(collectives_audit.signature_findings(
        "dp", _DP_COUNTS, sig))
    assert any("outside declared bounds [1, 10]" in f.message
               for f in findings)


def test_comm_signature_flags_missing_required_kind():
    # gradients no longer synchronized: the required all-reduce vanished
    sig = {"dp": {"all-gather": (0, None), "all-reduce": (1, None)}}
    findings = list(collectives_audit.signature_findings(
        "dp", {"all-gather": 14}, sig))
    assert any("required all-reduce is absent" in f.message
               for f in findings)


def test_comm_signature_flags_unknown_layout():
    findings = list(collectives_audit.signature_findings("pp", {}, {}))
    assert any("no COMM_SIGNATURE row" in f.message for f in findings)


# ---------------------------------------------------------------------------
# donation-audit
# ---------------------------------------------------------------------------

_F32 = jax.ShapeDtypeStruct((8, 8), jnp.float32)


def test_audit_donation_counts_live_aliases():
    @partial(jax.jit, donate_argnums=(0,))
    def step(x, y):
        return x + y
    donated, aliased = harness.audit_donation(step, (_F32, _F32), (0,))
    assert (donated, aliased) == (1, 1)


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_audit_donation_detects_dead_donation():
    # dtype change: XLA cannot reuse the donated f32 buffer for bf16 out
    @partial(jax.jit, donate_argnums=(0,))
    def step(x, y):
        return (x + y).astype(jnp.bfloat16)
    donated, aliased = harness.audit_donation(step, (_F32, _F32), (0,))
    assert donated == 1 and aliased == 0

    site = donation_audit.DonationSite(
        name="synthetic.step", path="src/repro/runtime/serve_loop.py",
        marker="no-such-marker", jitted=step, example_args=(_F32, _F32),
        donate_argnums=(0,))
    findings = list(donation_audit.site_findings(site, REPO_ROOT))
    assert any("dead" in f.message for f in findings)


def test_donation_audit_clean_at_head():
    findings = [f for site in donation_audit.collect_sites(ARCH)
                for f in donation_audit.site_findings(site, REPO_ROOT)]
    assert findings == [], [f.message for f in findings]


# ---------------------------------------------------------------------------
# recompile-audit
# ---------------------------------------------------------------------------

def test_signature_key_separates_weak_types():
    strong = harness.signature_key(jnp.int32(0))
    weak = harness.signature_key(0)
    assert strong != weak
    assert strong == harness.signature_key(jnp.int32(7))  # values don't key


def test_weak_typed_leaves_finds_python_scalars():
    tree = {"good": jnp.ones((2,), jnp.float32), "leak": 1.0}
    leaks = harness.weak_typed_leaves(tree)
    assert len(leaks) == 1 and "leak" in leaks[0][0]


def test_recompile_audit_clean_at_head():
    findings = list(recompile_audit.audit_family(ARCH, REPO_ROOT))
    assert findings == [], [f.message for f in findings]


def test_prefill_compile_keys_fold_under_chunking():
    from repro.runtime.serve_loop import Engine, ServeCfg
    cfg, api = _family()
    params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    chunked = Engine(api, params, ServeCfg(max_batch=2, max_len=32,
                                           cache="dense", prefill_chunk=8))
    assert len(chunked.prefill_compile_keys(range(1, 31))) == 1
    legacy = Engine(api, params, ServeCfg(max_batch=2, max_len=32,
                                          cache="dense"))
    assert len(legacy.prefill_compile_keys([3, 5, 3])) == 2


# ---------------------------------------------------------------------------
# aliased paged-pool write kernel vs jnp oracle
# ---------------------------------------------------------------------------

def test_write_kv_block_matches_ref_and_preserves_untouched_blocks():
    from repro.kernels.paged_attention import (write_kv_block,
                                               write_kv_block_ref)
    n, bs, kv, hd = 6, 4, 2, 8
    key = jax.random.PRNGKey(0)
    pool = jax.random.normal(key, (n, bs, kv, hd), jnp.float32)
    blocks = jax.random.normal(jax.random.fold_in(key, 1),
                               (3, bs, kv, hd), jnp.float32)
    row = jnp.array([4, 1, 3], jnp.int32)
    out = write_kv_block(pool, blocks, row, interpret=True)
    ref = write_kv_block_ref(pool, blocks, row)
    assert out.shape == pool.shape
    assert jnp.array_equal(out, ref)
    for untouched in (0, 2, 5):
        assert jnp.array_equal(out[untouched], pool[untouched])


def test_write_kv_block_alias_is_live():
    # the in-place contract the graph donation-audit checks on the real
    # engine sites, proven here on the kernel's own jit wrapper
    from repro.kernels.paged_attention import write_kv_block
    pool = jax.ShapeDtypeStruct((6, 4, 2, 8), jnp.float32)
    blocks = jax.ShapeDtypeStruct((3, 4, 2, 8), jnp.float32)
    row = jax.ShapeDtypeStruct((3,), jnp.int32)
    jitted = jax.jit(partial(write_kv_block, interpret=True),
                     donate_argnums=(0,))
    donated, aliased = harness.audit_donation(
        jitted, (pool, blocks, row), (0,))
    assert (donated, aliased) == (1, 1)
