"""Benchmark harness entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline number
that reproduces the table's claim).
"""
from __future__ import annotations

import time


def _timed(name, fn, derive):
    t0 = time.perf_counter()
    out = fn(verbose=False)
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(out)}")
    return out


def main() -> None:
    from benchmarks import (activation_memory, adapt_throughput, fused_asi,
                            latency_ondevice, serve_throughput, shard_scaling,
                            table1_imagenet, table4_tinyllama, warm_start)

    print("name,us_per_call,derived")
    _timed("table1_imagenet", table1_imagenet.run,
           lambda rows: f"max_mem_ratio={max(r['mem_ratio'] for r in rows):.0f}x")
    _timed("table4_tinyllama", table4_tinyllama.run,
           lambda rows: f"mem_ratio_1layer={rows[0]['mem_ratio']:.0f}x;"
                        f"flops_ratio_5layer={rows[-1]['flops_ratio']:.2f}x")
    _timed("fig5_latency", latency_ondevice.run,
           lambda o: f"hosvd_fwd_blowup={o['ratios']['fwd_hosvd_over_vanilla']:.0f}x;"
                     f"asi_step_speedup={o['ratios']['asi_step_speedup']:.2f}x")
    _timed("fig3_warmstart", warm_start.run,
           lambda o: f"gerr_warm={o['gerr_warm']:.3f};gerr_cold={o['gerr_cold']:.3f}")
    _timed("fused_asi", fused_asi.run,
           lambda o: f"backend={o['backend']};"
                     f"hbm_pass_ratio={o['hbm_pass_ratio']:.0f}x")
    _timed("serve_throughput", serve_throughput.run,
           lambda o: f"families_won={o['families_won']}/{len(o['rows'])};"
                     f"min_speedup={min(r['speedup'] for r in o['rows']):.2f}x")
    _timed("shard_scaling", shard_scaling.run,
           lambda o: f"min_arg_mem_ratio_1to8="
                     f"{o['min_arg_mem_ratio_1to8']:.1f}x")
    _timed("activation_memory", activation_memory.run,
           lambda o: f"max_site_ratio={o['max_site_ratio']:.0f}x;"
                     f"measured_gap="
                     f"{o['measured_gap']['gap_asi']*100:.0f}%")
    _timed("adapt_throughput", adapt_throughput.run,
           lambda o: f"retention={o['retention']:.2f}x;"
                     f"adapt_steps_per_s={o['adapt_steps_per_s']:.1f}")


if __name__ == "__main__":
    main()
