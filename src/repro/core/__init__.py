"""Paper core: Activation Subspace Iteration (ASI) and its baselines."""
from repro.core.asi import (
    MatrixASIState,
    TuckerASIState,
    compression_ratio,
    matrix_asi_step,
    matrix_reconstruct,
    matrix_storage_elems,
    orthonormalize,
    tucker_asi_step,
    tucker_reconstruct,
    tucker_storage_elems,
)
from repro.core.compressed_linear import (
    GroupedASIState,
    LinearCompressionCfg,
    asi_linear,
    dense_linear,
    grouped_asi_linear,
    hosvd_linear,
)
from repro.core.compressed_conv import (
    ConvCompressionCfg,
    asi_conv2d,
    conv2d,
    hosvd_conv2d,
)
from repro.core.rank_selection import (
    DEFAULT_EPS_GRID,
    LayerCalibration,
    PerplexityTable,
    apply_selection,
    estimate_perplexity,
    select_ranks_backtracking,
    select_ranks_knapsack,
)
