"""Continuous-batching serving launcher — a thin argparse shim over
``repro.api`` (reduced configs run on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 6 --max-new 12

``--engine sequential`` selects the legacy one-request-at-a-time loop
(useful for A/B sanity checks; ``benchmarks/serve_throughput.py`` does the
systematic comparison).  Embed ``repro.api.Session.server`` instead of
calling ``main()`` programmatically (which is deprecated).
"""
from __future__ import annotations

import argparse
import json

from repro import api


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    api.add_arch_argument(ap)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced (CPU-sized) config; "
                         "--no-reduced serves the full architecture")
    ap.add_argument("--engine", choices=("continuous", "sequential"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense",
                    help="KV cache layout: dense per-slot rows, or paged "
                         "block tables over a shared pool (continuous "
                         "engine only)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size; 0 = whole-prompt for dense "
                         "(paged prefill is always chunked, at --page-block)")
    ap.add_argument("--page-block", type=int, default=16,
                    help="positions per physical KV block (--cache paged)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="physical blocks in the shared pool; 0 sizes it to "
                         "dense-equivalent capacity (--cache paged)")
    ap.add_argument("--seed", type=int, default=0)
    api.add_telemetry_arguments(ap)
    return ap


def main(argv=None):
    api.warn_programmatic_use(__name__, argv)
    args = build_parser().parse_args(argv)
    with api.telemetry_recorder(args) as rec:
        sess = api.Session.from_config(args.arch, reduced=args.reduced,
                                       seed=args.seed, telemetry=rec)
        if sess.cfg.family == "encdec":
            raise SystemExit("encdec serving needs audio frames; use "
                             "examples/serve_decode.py for the full pipeline")
        server = sess.server(engine=args.engine, max_batch=args.max_batch,
                             max_len=args.max_len,
                             temperature=args.temperature,
                             cache=args.cache,
                             prefill_chunk=args.prefill_chunk,
                             page_block=args.page_block,
                             pool_blocks=args.pool_blocks)
        done = server.run(api.demo_requests(args.requests, args.max_new))
        for r in done:
            print(json.dumps({"uid": r.uid, "prompt": r.prompt, "out": r.out,
                              "ttft_s": (None if r.ttft_s is None
                                         else round(r.ttft_s, 4))}))
        print(json.dumps(server.stats_dict()))
    return done


if __name__ == "__main__":
    main()
