"""Trace a paged serving run with the telemetry layer, via ``repro.api``
and ``repro.telemetry`` only: attach one ``Recorder`` to the session,
serve a wave of requests, then re-derive the headline serving stats from
the recorded request-lifecycle events and export both a JSONL stream and
a Chrome trace (load it at chrome://tracing or ui.perfetto.dev).

  PYTHONPATH=src python examples/trace_serving.py
"""
import json

from repro.api import Session, demo_requests
from repro.telemetry import (Recorder, export_chrome_trace, export_jsonl,
                             read_jsonl)

rec = Recorder()                      # one recorder, shared by every handle
sess = Session.from_config("tinyllama_1_1b", reduced=True, compress="asi",
                           kernel_backend="reference", seed=0,
                           telemetry=rec)

server = sess.server(max_batch=4, max_len=48, cache="paged",
                     page_block=4, pool_blocks=24)
done = server.run(demo_requests(8, max_new=8))
assert all(r.done for r in done)

# the stats view and the event stream are one recorder observed two ways:
# lifecycle counts re-derived from the events match the engine's stats
retired = [e for e in rec.events
           if e["kind"] == "I" and e["name"] == "serve.request.retired"]
ttfts = [e["attrs"]["ttft_s"] for e in rec.events
         if e["kind"] == "I" and e["name"] == "serve.request.first_token"]
stats = server.stats_dict()
assert len(retired) == stats["requests"]
assert sum(e["attrs"]["tokens"] for e in retired) == stats["generated_tokens"]

export_jsonl(rec, "/tmp/trace_serving.jsonl")
export_chrome_trace(rec, "/tmp/trace_serving.trace.json")
events, metrics, dropped = read_jsonl("/tmp/trace_serving.jsonl")

print(json.dumps({
    "requests": stats["requests"],
    "generated_tokens": stats["generated_tokens"],
    "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
    "peak_kv_blocks": metrics["serve.kv.used_blocks.peak"],
    "events": len(events), "dropped": dropped,
    "jsonl": "/tmp/trace_serving.jsonl",
    "chrome_trace": "/tmp/trace_serving.trace.json"}))
