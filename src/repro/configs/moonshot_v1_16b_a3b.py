"""moonshot-v1-16b-a3b — kimi/moonlight-style MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_tok=6,
    rope_theta=50000.0,
    act="silu",
)
