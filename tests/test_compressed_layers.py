"""Gradient-correctness tests for the ASI/HOSVD compressed layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asi import MatrixASIState, TuckerASIState, tucker_asi_step
from repro.core.compressed_conv import (ConvCompressionCfg, asi_conv2d, conv2d,
                                        hosvd_conv2d)
from repro.core.compressed_linear import (GroupedASIState,
                                          LinearCompressionCfg, asi_linear,
                                          dense_linear, grouped_asi_linear,
                                          hosvd_linear)

KEY = jax.random.PRNGKey(0)


def _setup_linear(m=32, b=4, k=24, n=16):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, m, k))
    w = jax.random.normal(ks[1], (k, n)) * 0.2
    bias = jax.random.normal(ks[2], (n,)) * 0.1
    return x, w, bias


def test_asi_linear_dx_exact_any_rank():
    """Paper eq. 2: activation grads never approximated."""
    x, w, bias = _setup_linear()
    for rank in (2, 8, 24):
        st = MatrixASIState.init(KEY, x.shape[-1], rank)
        cfg = LinearCompressionCfg(rank=rank)

        def f(x):
            y, _ = asi_linear(cfg, x, w, bias, st)
            return jnp.sum(jnp.sin(y))

        def f0(x):
            return jnp.sum(jnp.sin(dense_linear(x, w, bias)))

        np.testing.assert_allclose(np.asarray(jax.grad(f)(x)),
                                   np.asarray(jax.grad(f0)(x)), atol=1e-5)


def test_asi_linear_dw_exact_at_full_rank():
    x, w, bias = _setup_linear()
    k = x.shape[-1]
    st = MatrixASIState.init(KEY, k, k)           # full rank
    cfg = LinearCompressionCfg(rank=k)

    def f(w):
        y, _ = asi_linear(cfg, x, w, bias, st)
        return jnp.sum(y ** 2)

    def f0(w):
        return jnp.sum(dense_linear(x, w, bias) ** 2)

    gw = jax.grad(f)(w)
    gw0 = jax.grad(f0)(w)
    rel = float(jnp.linalg.norm(gw - gw0) / jnp.linalg.norm(gw0))
    assert rel < 1e-4


def test_asi_linear_dw_error_decreases_with_rank():
    x, w, bias = _setup_linear()
    k = x.shape[-1]

    def dw_err(rank):
        st = MatrixASIState.init(KEY, k, rank)
        # warm the subspace a couple of iterations (paper's warm start)
        x2 = x.reshape(-1, k)
        for _ in range(3):
            from repro.core.asi import matrix_asi_step
            _, _, st = matrix_asi_step(x2, st)
        cfg = LinearCompressionCfg(rank=rank)

        def f(w):
            y, _ = asi_linear(cfg, x, w, bias, st)
            return jnp.sum(y ** 2)

        def f0(w):
            return jnp.sum(dense_linear(x, w, bias) ** 2)

        return float(jnp.linalg.norm(jax.grad(f)(w) - jax.grad(f0)(w)))

    errs = [dw_err(r) for r in (2, 8, 16, 24)]
    assert errs[-1] < errs[0]
    assert errs[-1] < 1e-3


def test_hosvd_linear_matches_asi_backward_contract():
    x, w, bias = _setup_linear()
    cfg = LinearCompressionCfg(rank=x.shape[-1])

    def f(w):
        return jnp.sum(hosvd_linear(cfg, x, w, bias) ** 2)

    def f0(w):
        return jnp.sum(dense_linear(x, w, bias) ** 2)

    rel = float(jnp.linalg.norm(jax.grad(f)(w) - jax.grad(f0)(w))
                / jnp.linalg.norm(jax.grad(f0)(w)))
    assert rel < 1e-4


def test_grouped_asi_linear_per_expert():
    e, t, k, n, r = 3, 16, 12, 8, 12
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (e, t, k))
    w = jax.random.normal(ks[1], (e, k, n)) * 0.2
    st = GroupedASIState.init(KEY, e, k, r)
    cfg = LinearCompressionCfg(rank=r)

    def f(w):
        y, _ = grouped_asi_linear(cfg, x, w, st)
        return jnp.sum(y ** 2)

    def f0(w):
        return jnp.sum(jnp.einsum("etk,ekn->etn", x, w) ** 2)

    rel = float(jnp.linalg.norm(jax.grad(f)(w) - jax.grad(f0)(w))
                / jnp.linalg.norm(jax.grad(f0)(w)))
    assert rel < 1e-4


def test_asi_conv_gradients():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (4, 6, 10, 12))
    w = jax.random.normal(ks[1], (8, 6, 3, 3)) * 0.1
    ranks = (4, 6, 10, 12)                        # full ranks -> exact
    ccfg = ConvCompressionCfg(ranks=ranks)
    st = TuckerASIState.init(KEY, x.shape, ranks)
    for _ in range(3):
        _, _, st = tucker_asi_step(x, st)

    def f(x, w):
        y, _ = asi_conv2d(ccfg, x, w, st)
        return jnp.sum(y ** 2)

    def f0(x, w):
        return jnp.sum(conv2d(x, w) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    gx0, gw0 = jax.grad(f0, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0), atol=1e-4)
    rel = float(jnp.linalg.norm(gw - gw0) / jnp.linalg.norm(gw0))
    assert rel < 1e-4


def test_hosvd_conv_strided():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (2, 4, 8, 8))
    w = jax.random.normal(ks[1], (6, 4, 3, 3)) * 0.1
    ccfg = ConvCompressionCfg(ranks=(2, 4, 8, 8), stride=(2, 2))

    def f(w):
        return jnp.sum(hosvd_conv2d(ccfg, x, w) ** 2)

    def f0(w):
        return jnp.sum(conv2d(x, w, stride=(2, 2)) ** 2)

    rel = float(jnp.linalg.norm(jax.grad(f)(w) - jax.grad(f0)(w))
                / jnp.linalg.norm(jax.grad(f0)(w)))
    assert rel < 1e-4        # full spatial/batch rank, rank-2 on B: B dim is
                             # exactly rank<=2 here? no: rank 2 of 2 = full


def test_residuals_are_compressed_not_full():
    """The custom_vjp must save only the factors: differentiate and inspect
    the jaxpr for any residual of the full activation size."""
    m, k, n, r = 64, 32, 16, 4
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(KEY, (k, n))
    st = MatrixASIState.init(KEY, k, r)
    cfg = LinearCompressionCfg(rank=r)

    def f(w):
        y, _ = asi_linear(cfg, x, w, None, st)
        return jnp.sum(y ** 2)

    # vjp residuals: closure of the backward — check P̂/Q shapes exist and no
    # (m, k) array other than the input x itself is carried.
    _, vjp = jax.vjp(lambda w: f(w), w)
    res_shapes = [v.shape for v in jax.tree.leaves(vjp)]
    assert (m, r) in res_shapes and (k, r) in res_shapes
