"""Continuous-batching serving launcher (reduced configs run on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 6 --max-new 12

``--engine sequential`` selects the legacy one-request-at-a-time loop
(useful for A/B sanity checks; ``benchmarks/serve_throughput.py`` does the
systematic comparison).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import ARCHS, get_config
from repro.models import build_model
from repro.runtime.serve_loop import (Engine, Request, SequentialEngine,
                                      ServeCfg)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced (CPU-sized) config; "
                         "--no-reduced serves the full architecture")
    ap.add_argument("--engine", choices=("continuous", "sequential"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "encdec":
        raise SystemExit("encdec serving needs audio frames; use "
                         "examples/serve_decode.py for the full pipeline")
    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)
    engine_cls = Engine if args.engine == "continuous" else SequentialEngine
    eng = engine_cls(api, params, ServeCfg(max_batch=args.max_batch,
                                           max_len=args.max_len,
                                           temperature=args.temperature),
                     seed=args.seed)
    reqs = [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(5)],
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    done = eng.run(reqs)
    for r in done:
        print(json.dumps({"uid": r.uid, "prompt": r.prompt, "out": r.out,
                          "ttft_s": (None if r.ttft_s is None
                                     else round(r.ttft_s, 4))}))
    s = eng.last_stats
    print(json.dumps({"engine": args.engine, "requests": s.requests,
                      "generated_tokens": s.generated_tokens,
                      "decode_steps": s.decode_steps,
                      "tokens_per_s": round(s.tokens_per_s, 1),
                      "ttft_mean_s": round(s.ttft_mean_s, 4)}))
    return done


if __name__ == "__main__":
    main()
