"""Partition rules: map every leaf of params / optimizer state / ASI state /
KV-caches / batches to a PartitionSpec for the current mesh.

Scheme (Megatron-TP x DP, optional FSDP/ZeRO-3):
  batch                  -> ('pod','data')        [multi-pod] or 'data'
  heads / kv / d_ff / vocab / experts -> 'model'
  weight d_model dim     -> FSDP axes when cfg.fsdp (ZeRO-3)
  optimizer state        -> mirrors its parameter (ZeRO-1 comes free)
  KV cache               -> kv-heads on 'model' when divisible, else the
                            sequence dim (decode softmax over a sharded seq
                            is handled by GSPMD with a partial-max/sum pair)

All specs pass through ``safe_spec`` so a non-divisible dim degrades to
replication instead of failing — this is what lets ONE rule set cover all
40 (arch x shape) cells.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.parallel.sharding import safe_spec

MODEL = "model"

# Layout selector: 'tp' (Megatron TP x DP, default), 'fsdp' (ZeRO-3: all
# mesh axes shard batch+weights, no tensor parallelism) or 'dp' (replicate
# weights, shard only the batch).  A hillclimb lever — set via set_layout()
# before building specs (dryrun/train --layout fsdp).
LAYOUT = "tp"

# Per-layout communication signature: which collective kinds the compiled
# train step is ALLOWED to contain, with (min, max) count bounds per kind
# (``None`` max = unbounded — the kind is structural to the layout and its
# count scales with depth).  The graph-lint collectives-audit compiles the
# train step on a forced-host-device mesh, counts collectives in the
# per-device HLO (``roofline.collective_counts``) and gates against this
# table: a kind appearing outside its row — e.g. a collective-permute in
# the dp backward, or an all-to-all sneaking into dp — is exactly the
# silent comm regression tensor-parallel serving would inherit.  Kinds
# absent from a row must not appear at all.
#
# dp   : gradient/metric all-reduce over 'data'; XLA emits a handful of
#        all-gathers reassembling batch-sharded aux outputs — never
#        reduce-scatter / all-to-all / permute.
# fsdp : ZeRO-3 adds parameter all-gathers and (re)sharding all-to-alls;
#        permute stays forbidden.
# tp   : Megatron row/column contractions add permutes and all-to-alls on
#        'model'; every kind except reduce-scatter is structural.
COMM_SIGNATURE: dict[str, dict[str, tuple[int, int | None]]] = {
    "dp":   {"all-gather": (0, None), "all-reduce": (1, None)},
    "fsdp": {"all-gather": (1, None), "all-reduce": (1, None),
             "reduce-scatter": (0, None), "all-to-all": (0, None)},
    "tp":   {"all-gather": (1, None), "all-reduce": (1, None),
             "all-to-all": (0, None), "collective-permute": (0, None)},
}


def set_layout(name: str):
    """Set the module-global layout consumed by the ``*_specs`` builders.

    Must be called before building specs; ``runtime.train_loop.make_mesh_plan``
    does this for you."""
    global LAYOUT
    assert name in ("tp", "fsdp", "dp")
    LAYOUT = name


def batch_axes(mesh: Mesh):
    """Mesh axes the batch dim shards over: the data axes, plus ``model``
    under FSDP (every device holds a distinct microbatch slice)."""
    base = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if LAYOUT == "fsdp":
        base = base + (MODEL,)
    return base if len(base) > 1 else base[0]


def _fsdp(cfg: ModelConfig, mesh: Mesh):
    if LAYOUT == "dp":          # dp replicates weights even for fsdp-flagged
        return None             # configs — it is the parity oracle
    return batch_axes(mesh) if (cfg.fsdp or LAYOUT == "fsdp") else None


def _strip_model(spec: P) -> P:
    return P(*[None if ax == MODEL else ax for ax in tuple(spec)])


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _param_rule(name: str, ndim: int, cfg: ModelConfig, mesh: Mesh) -> P:
    fsdp = _fsdp(cfg, mesh)
    last = name.split("/")[-1]
    stacked = name.startswith(("stack", "encoder", "decoder"))
    lead = (None,) if stacked else ()

    def sp(*axes):
        axes = lead + axes
        # pad/truncate to ndim
        axes = axes + (None,) * (ndim - len(axes))
        return P(*axes[:ndim])

    if last == "embed":
        return P(MODEL, fsdp)
    if last in ("unembed", "head_w"):
        return P(fsdp, MODEL)
    if last == "dec_pos":
        return P(None, None)
    if last in ("wq", "wk", "wv", "gate", "up", "in_proj"):
        if "ffn" in name and cfg.n_experts and "router" not in last:
            # MoE expert weights (L, E, d, f)
            if cfg.n_experts % mesh.shape[MODEL] == 0:
                return sp(MODEL, fsdp, None)
            return sp(None, fsdp, MODEL)
        return sp(fsdp, MODEL)
    if last == "down":
        if "ffn" in name and cfg.n_experts:
            if cfg.n_experts % mesh.shape[MODEL] == 0:
                return sp(MODEL, None, fsdp)
            return sp(None, MODEL, fsdp)
        return sp(MODEL, fsdp)
    if last in ("wo", "out_proj"):
        return sp(MODEL, fsdp)
    if last == "router":
        return sp(fsdp, None)
    if last in ("conv_w", "conv_b"):
        return sp(None, MODEL) if last == "conv_w" else sp(MODEL)
    if last in ("a_log", "d_skip", "dt_bias"):
        return sp(MODEL)
    # norms, biases, scalars
    return P(*([None] * ndim))


def param_specs(cfg: ModelConfig, params_struct: Any, mesh: Mesh):
    """PartitionSpec tree for a parameter pytree (structure from the concrete
    params or an ``eval_shape`` of ``api.init``).  Under ``fsdp``/``dp`` the
    TP (``model``) placements are stripped: fsdp re-shards weights over the
    batch axes instead; dp replicates them."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_struct)
    out = []
    for path, leaf in flat:
        name = _leaf_name(path)
        spec = _param_rule(name, len(leaf.shape), cfg, mesh)
        if LAYOUT in ("fsdp", "dp"):
            spec = _strip_model(spec)
        out.append(safe_spec(leaf.shape, spec, mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_specs(cfg: ModelConfig, opt_struct: Any, mesh: Mesh):
    """Optimizer state mirrors parameters; adafactor's factored vr/vc drop
    the corresponding trailing dim of the parameter spec."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_struct)
    out = []
    for path, leaf in flat:
        name = _leaf_name(path)
        # strip state prefixes: mu/nu/f + trailing vr/vc/v markers
        parts = [p for p in name.split("/") if p not in ("mu", "nu", "f")]
        marker = parts[-1] if parts and parts[-1] in ("vr", "vc", "v") else None
        core = "/".join(p for p in parts if p not in ("vr", "vc", "v"))
        base_nd = len(leaf.shape) + (1 if marker in ("vr", "vc") else 0)
        spec = _param_rule(core, base_nd, cfg, mesh)
        axes = tuple(spec)
        if marker == "vr":            # param spec minus last dim
            axes = axes[:-1]
        elif marker == "vc":          # param spec minus second-to-last dim
            axes = axes[:-2] + axes[-1:]
        spec2 = P(*axes)
        if LAYOUT in ("fsdp", "dp"):
            spec2 = _strip_model(spec2)
        out.append(safe_spec(leaf.shape, spec2, mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def asi_specs(asi_struct: Any, mesh: Mesh):
    """ASI factors are small (K x r); replicate."""
    return jax.tree.map(lambda leaf: P(*([None] * len(leaf.shape))),
                        asi_struct)


def batch_specs(cfg: ModelConfig, batch_struct: Any, mesh: Mesh):
    """Shard dim 0 (batch) of every batch leaf over ``batch_axes``; a batch
    that does not divide the axes degrades to replication via safe_spec."""
    ba = batch_axes(mesh)

    def rule(leaf):
        nd = len(leaf.shape)
        return safe_spec(leaf.shape, P(ba, *([None] * (nd - 1))), mesh)

    return jax.tree.map(rule, batch_struct)


def cache_specs(cfg: ModelConfig, cache_struct: Any, mesh: Mesh):
    """KV caches (L, B, S, KV, hd) and mamba states (L, B, H, P, N) /
    (L, B, w, C).  kv-heads on 'model' when divisible, else sequence."""
    ba = batch_axes(mesh)
    msize = mesh.shape[MODEL]
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    out = []
    for path, leaf in flat:
        name = _leaf_name(path)
        last = name.split("/")[-1]
        shape = leaf.shape
        if last in ("k", "v", "k_scale", "v_scale") and len(shape) == 5:
            if shape[3] % msize == 0 and shape[4] > 1:    # kv heads
                spec = P(None, ba, None, MODEL, None)
            elif last in ("k_scale", "v_scale"):
                spec = P(None, ba, None,
                         MODEL if shape[3] % msize == 0 else None, None)
            else:
                spec = P(None, ba, MODEL, None, None)     # sequence
        elif last == "ssm" and len(shape) == 5:
            spec = P(None, ba, MODEL, None, None)         # SSD heads
        elif last == "conv" and len(shape) == 4:
            spec = P(None, ba, None, MODEL)
        else:
            spec = P(*([None] * len(shape)))
        out.append(safe_spec(shape, spec, mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def to_shardings(spec_tree: Any, mesh: Mesh):
    """Materialize a PartitionSpec tree into NamedShardings (jit
    in_shardings/out_shardings take these directly)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
