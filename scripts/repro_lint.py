#!/usr/bin/env python
"""Thin launcher for repro-lint that works without PYTHONPATH set.

Equivalent to ``PYTHONPATH=src python -m repro.analysis``; see
``python -m repro.analysis --help`` for the flag reference.  CI runs
``python scripts/repro_lint.py --format json`` and uploads the document
as the ``lint-findings`` artifact before any test job starts.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
