"""Benchmark-snapshot schema tests: every checked-in ``BENCH_<name>.json``
must validate against the shared schema, and malformed snapshots must fail
loudly (both at validation and at write time)."""
import glob
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.snapshots import (SCHEMA_VERSION, SNAPSHOT_DIR,  # noqa: E402
                                  load_snapshot, snapshot_path,
                                  validate_snapshot, write_snapshot)

CHECKED_IN = sorted(glob.glob(os.path.join(SNAPSHOT_DIR, "BENCH_*.json")))


def test_snapshots_are_checked_in():
    """The repo records at least the four core benchmark snapshots."""
    names = {os.path.basename(p) for p in CHECKED_IN}
    for required in ("BENCH_fused_asi.json", "BENCH_serve_throughput.json",
                     "BENCH_activation_memory.json",
                     "BENCH_scenario_suite.json", "BENCH_serve_trace.json",
                     "BENCH_telemetry_overhead.json"):
        assert required in names, f"{required} missing from {SNAPSHOT_DIR}"


@pytest.mark.parametrize("path", CHECKED_IN,
                         ids=[os.path.basename(p) for p in CHECKED_IN])
def test_checked_in_snapshot_schema(path):
    with open(path) as f:
        snap = json.load(f)
    assert validate_snapshot(snap, where=os.path.basename(path)) == []
    # the filename encodes the benchmark name
    assert os.path.basename(path) == f"BENCH_{snap['name']}.json"
    assert snap["schema_version"] == SCHEMA_VERSION


def test_scenario_suite_snapshot_contents():
    snap = load_snapshot("scenario_suite")
    assert snap["metrics"]["recovered"] is True
    assert snap["metrics"]["forgetting_bounded"] is True
    assert snap["config"]["scenario"] == "domain-shift"
    # the snapshot carries the actual curves, one point per burst
    assert len(snap["series"]["probe_phase0"]) == snap["metrics"]["bursts"]
    assert snap["series"]["quality"]


def test_serve_trace_snapshot_contents():
    """The recorded traffic-trace run holds the paged-cache claims: token
    parity with the dense engine, >= 2x peak-KV reduction, and throughput
    within 10% of dense."""
    snap = load_snapshot("serve_trace")
    m = snap["metrics"]
    assert m["parity"] is True
    assert m["kv_reduction_x"] >= 2.0
    assert m["tok_s_ratio"] >= 0.9
    assert m["paged_peak_cache_bytes"] < m["dense_peak_cache_bytes"]
    # the pool is sized by config, the high-water mark can't exceed it
    assert m["paged_peak_used_blocks"] <= snap["config"]["pool_blocks"] - 1
    # TTFT percentiles ride along as [dense, paged] series
    assert len(snap["series"]["ttft_p50_s"]) == 2
    assert len(snap["series"]["ttft_p99_s"]) == 2


def test_telemetry_overhead_snapshot_contents():
    """The recorded overhead run holds the telemetry claims: event recording
    costs < the 2% gate, zero ring drops, and the lifecycle counts derived
    from the event stream matched ``last_stats`` exactly."""
    snap = load_snapshot("telemetry_overhead")
    m = snap["metrics"]
    assert m["derived_matches_stats"] is True
    assert m["overhead_frac"] < m["gate_frac"] == 0.02
    assert m["off_tok_s"] > 0 and m["on_tok_s"] > 0
    assert m["dropped"] == 0
    assert m["events_per_run"] > 0


def test_validate_flags_malformed():
    good = {"schema_version": SCHEMA_VERSION, "name": "x", "git": "abc",
            "config": {}, "metrics": {"m": 1.0}}
    assert validate_snapshot(good) == []
    for mutate, frag in [
        (lambda s: s.pop("git"), "git"),
        (lambda s: s.update(schema_version=99), "schema_version"),
        (lambda s: s.update(metrics={}), "metrics is empty"),
        (lambda s: s.update(metrics={"m": [1, 2]}), "want scalar"),
        (lambda s: s.update(series={"q": ["a"]}), "numeric list"),
        (lambda s: s.update(extra=1), "unknown keys"),
    ]:
        bad = json.loads(json.dumps(good))
        mutate(bad)
        errs = validate_snapshot(bad)
        assert errs and any(frag in e for e in errs), (frag, errs)


def test_write_snapshot_refuses_malformed_and_roundtrips(tmp_path):
    with pytest.raises(ValueError, match="malformed"):
        write_snapshot("bad", {}, {}, directory=str(tmp_path))
    p = write_snapshot("ok", {"b": 2}, {"m": 1.5},
                       series={"curve": [1.0, 0.5]},
                       directory=str(tmp_path))
    assert p == snapshot_path("ok", str(tmp_path))
    snap = load_snapshot("ok", str(tmp_path))
    assert validate_snapshot(snap) == []
    assert snap["metrics"]["m"] == 1.5 and snap["series"]["curve"] == [1, 0.5]
    assert isinstance(snap["git"], str) and snap["git"]
