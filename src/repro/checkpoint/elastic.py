"""Elastic resume: reshard a restored pytree onto a (possibly different) mesh.

Checkpoints store logical (unsharded) arrays plus the layout metadata; on
resume we device_put each leaf with the sharding derived from the *current*
mesh and partition rules.  Growing/shrinking the data axis (elastic scaling)
therefore needs no array surgery — only the batch-schedule offset changes,
and the data pipeline is a pure function of step, so nothing else moves.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import safe_spec


def reshard(tree: Any, spec_tree: Any, mesh: Mesh):
    """device_put every leaf with its (divisibility-checked) NamedSharding."""
    def place(x, spec):
        if not hasattr(x, "shape"):
            return x
        s = safe_spec(x.shape, spec if spec is not None else P(), mesh)
        return jax.device_put(x, NamedSharding(mesh, s))
    return jax.tree.map(place, tree, spec_tree,
                        is_leaf=lambda x: x is None)


def replicate(tree: Any, mesh: Mesh):
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P()))
        if hasattr(x, "shape") else x, tree)
