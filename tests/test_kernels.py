"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (per the deliverable-(c) contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("m,k,n,r", [
    (128, 128, 128, 8), (256, 128, 192, 16), (100, 70, 50, 8),
    (512, 256, 256, 128), (64, 300, 40, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sketch(m, k, n, r, dtype):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = (jax.random.normal(ks[1], (k, n)) * 0.1).astype(dtype)
    v = jax.random.normal(ks[2], (k, r), jnp.float32).astype(dtype)
    y, p = ops.matmul_sketch(x, w, v)
    y0, p0 = ref.matmul_sketch_ref(x, w, v)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               atol=tol * k, rtol=tol)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p0),
                               atol=tol * k, rtol=tol)


@pytest.mark.parametrize("bh,sq,skv,d", [
    (4, 128, 128, 64), (2, 64, 128, 32), (1, 256, 256, 128), (3, 96, 96, 48),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
def test_flash_attention(bh, sq, skv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, sq, d))
    k = jax.random.normal(ks[1], (bh, skv, d))
    v = jax.random.normal(ks[2], (bh, skv, d))
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            bq=32, bk=32, q_offset=skv - sq)
    o0 = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o0), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 64, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 64, 64)).astype(dtype)
    o = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    o0 = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o0, np.float32), atol=3e-2)


@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (2, 3, 64, 8, 16, 16), (1, 2, 128, 16, 8, 32), (2, 1, 32, 4, 4, 8),
])
def test_ssd_scan(b, h, s, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b * h, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b * h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (b * h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y, hf = ops.ssd_scan(x, dt, a, bb, cc, n_heads=h, chunk=chunk)
    y0, h0 = ref.ssd_ref(x, dt, a, jnp.repeat(bb, h, 0), jnp.repeat(cc, h, 0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h0),
                               atol=1e-4, rtol=1e-3)


def test_ssd_scan_matches_model_ssd():
    """Kernel agrees with the model's chunked-scan implementation too."""
    from repro.models.ssm import ssd_chunked
    b, h, s, p, n = 2, 4, 64, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y_model, h_model = ssd_chunked(x, dt, a, bb, cc, chunk=16)
    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s)
    ak = jnp.tile(a, b)
    yk, hk = ops.ssd_scan(xk, dtk, ak, bb, cc, n_heads=h, chunk=16)
    yk = yk.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(yk),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_model),
                               np.asarray(hk.reshape(b, h, p, n)),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("B,L,bs,kv,g,hd", [
    (2, 4, 4, 2, 2, 8), (1, 8, 2, 1, 4, 16), (3, 2, 8, 4, 1, 32),
])
def test_paged_attention_kernel_vs_reference(B, L, bs, kv, g, hd):
    """Paged decode attention: the scalar-prefetch Pallas kernel (interpret
    mode, the CI backend) gathers K/V blocks through the table and matches
    the pure-jnp gather reference at every slot depth."""
    from repro.kernels.paged_attention import paged_attention_ref
    n_blocks = B * L + 1
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, kv, g, hd))
    k = jax.random.normal(ks[1], (n_blocks, bs, kv, hd))
    v = jax.random.normal(ks[2], (n_blocks, bs, kv, hd))
    # every slot gets distinct physical blocks, shuffled
    perm = np.random.default_rng(0).permutation(np.arange(1, n_blocks))
    table = jnp.asarray(perm.reshape(B, L).astype(np.int32))
    for depth in (0, bs - 1, bs, L * bs - 1):
        pos = jnp.full((B,), depth, jnp.int32)
        got = ops.paged_attention(q, k, v, table, pos, interpret=True)
        want = paged_attention_ref(q, k, v, table, pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
