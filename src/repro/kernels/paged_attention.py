"""Paged-attention decode Pallas TPU kernel.

Single-token decode over a block-paged KV cache: physical K/V blocks live in
one shared pool ``(n_blocks, block_size, KV, hd)`` and each batch slot maps
its logical blocks through a ``(B, L)`` block table.  The table and per-slot
positions ride in as *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps gather
physical blocks by table lookup — the kernel never materializes a dense
``(B, max_len)`` cache.

Grid is (batch, logical-block); the logical-block dimension is sequential
with the running max/denominator/accumulator in VMEM scratch (same online
softmax as ``flash_attention``).  Blocks wholly past a slot's frontier
(table rows point at the trash block, see ``runtime/paged_kv.py``) are
skipped block-granularly; the last partial block is masked per-position.

``paged_attention_ref`` is the pure-jnp oracle: gather-by-table + the exact
masked softmax ``models/attention.py:attn_decode`` uses, so off-TPU serving
is bit-identical to the dense engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, nl: int, n_kv: int,
            scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos_b = pos_ref[b]

    # block-level skip: logical blocks wholly past the slot's write frontier
    # hold no valid positions (their table entries point at trash) — issue no
    # MXU work for them
    @pl.when(j * bs <= pos_b)
    def _compute():
        q = q_ref[0]                                   # (KV, G, hd)
        k_blk = k_ref[0]                               # (bs, KV, hd)
        v_blk = v_ref[0]
        for kh in range(n_kv):
            s = jax.lax.dot_general(
                q[kh], k_blk[:, kh], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale      # (G, bs)
            kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= pos_b, s, NEG_INF)   # partial-block mask
            m_prev = m_ref[kh]
            m_new = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[kh] = l_ref[kh] * corr + p.sum(-1)
            pv = jax.lax.dot_general(
                p.astype(v_blk.dtype), v_blk[:, kh], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)              # (G, hd)
            acc_ref[kh] = acc_ref[kh] * corr[:, None] + pv
            m_ref[kh] = m_new

    @pl.when(j == nl - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: Array, k_pool: Array, v_pool: Array, table: Array,
                    pos: Array, *, interpret: bool = False) -> Array:
    """q (B, KV, G, hd); k/v pools (n_blocks, bs, KV, hd); table (B, L)
    int32 physical-block ids; pos (B,) int32 — the highest valid cache
    position per slot (the token just written).  Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    L = table.shape[1]
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # table + pos feed the index maps
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, j, tbl, pos: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, tbl, pos: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, tbl, pos: (tbl[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, j, tbl, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, nl=L, n_kv=KV, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)


def _write_kernel(row_ref, pool_in_ref, blocks_ref, pool_ref):
    # one grid step copies one logical block into the physical block the
    # table row names (the out BlockSpec does the scatter); pool_in only
    # exists to be aliased into the output
    del row_ref, pool_in_ref
    pool_ref[...] = blocks_ref[...]


def write_kv_block(pool: Array, blocks: Array, row: Array, *,
                   interpret: bool = False) -> Array:
    """Scatter one slot's prefilled KV blocks into the shared pool, in
    place: pool (n_blocks, bs, KV, hd); blocks (L, bs, KV, hd); row (L,)
    int32 physical-block ids for the slot's logical blocks.

    The pool is donated via ``input_output_aliases`` — physical blocks not
    named by ``row`` keep their contents without ever being copied, so the
    admission write-back touches O(slot) bytes, not O(pool) (the in-place
    discipline the graph-lint donation-audit checks from the jit side and
    the ast-plane pallas-contract checks from the source side).  Rows may
    repeat the trash block; later grid steps simply overwrite it.
    """
    L = row.shape[0]
    _n, bs, KV, hd = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,              # the row feeds the out index map
        grid=(L,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((1, bs, KV, hd), lambda j, row: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, KV, hd),
                               lambda j, row: (row[j], 0, 0, 0)),
    )
    return pl.pallas_call(
        _write_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},        # pool -> updated pool
        interpret=interpret,
    )(row.astype(jnp.int32), pool, blocks.astype(pool.dtype))


def write_kv_block_ref(pool: Array, blocks: Array, row: Array) -> Array:
    """Pure-jnp oracle for :func:`write_kv_block` (functional scatter).
    Exact for distinct rows; on repeated rows jnp scatter order is
    unspecified while the kernel's sequential grid makes the last write
    win — callers (and the parity tests) use distinct physical blocks."""
    return pool.at[row].set(blocks.astype(pool.dtype))


def paged_attention_ref(q: Array, k_pool: Array, v_pool: Array, table: Array,
                        pos: Array) -> Array:
    """Pure-jnp oracle: gather blocks by table, then the dense decode
    softmax.  With ``L * bs == max_len`` this is shape-for-shape the same
    reduction ``attn_decode`` runs on a dense cache, hence bit-identical."""
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    L = table.shape[1]
    k = k_pool[table].reshape(B, L * bs, KV, hd)
    v = v_pool[table].reshape(B, L * bs, KV, hd)
    valid = jnp.arange(L * bs)[None, :] <= pos[:, None]
    s = jnp.einsum("bkgh,bskh->bkgs", q, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)
