"""Re-run cells recorded before the collective-parser + MoE-dispatch fixes."""
import subprocess, sys, os, time
CELLS = [
    # (arch, shape, multi_pod)
    ("granite-moe-3b-a800m", "decode_32k", False),
    ("moonshot-v1-16b-a3b", "decode_32k", False),
    ("moonshot-v1-16b-a3b", "prefill_32k", False),
    ("jamba-1.5-large-398b", "decode_32k", False),
    ("jamba-1.5-large-398b", "long_500k", False),
    ("jamba-1.5-large-398b", "prefill_32k", False),
    ("tinyllama-1.1b", "decode_32k", False),
    ("tinyllama-1.1b", "prefill_32k", False),
    ("tinyllama-1.1b", "train_4k", False),
    ("tinyllama-1.1b", "train_4k", True),
    ("mamba2-130m", "decode_32k", False),
    ("mamba2-130m", "long_500k", False),
    ("mamba2-130m", "prefill_32k", False),
    ("mamba2-130m", "train_4k", False),
    ("internvl2-1b", "decode_32k", False),
    ("internvl2-1b", "prefill_32k", False),
    ("internvl2-1b", "train_4k", False),
    ("phi3-mini-3.8b", "decode_32k", False),
    ("phi3-mini-3.8b", "prefill_32k", False),
    ("phi3-mini-3.8b", "train_4k", False),
    ("h2o-danube-3-4b", "decode_32k", False),
    ("h2o-danube-3-4b", "long_500k", False),
    ("h2o-danube-3-4b", "prefill_32k", False),
    ("h2o-danube-3-4b", "train_4k", False),
    ("whisper-medium", "decode_32k", False),
    ("whisper-medium", "prefill_32k", False),
    ("internlm2-20b", "decode_32k", False),
    ("internlm2-20b", "prefill_32k", False),
]
env = dict(os.environ, PYTHONPATH="src"); env.pop("REPRO_XLA_FLAGS", None)
for arch, shape, mp in CELLS:
    args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", shape, "--out", "results/dryrun.jsonl"]
    if mp: args.append("--multi-pod")
    t0 = time.time()
    try:
        p = subprocess.run(args, env=env, capture_output=True, text=True, timeout=4000)
        ok = p.returncode == 0
    except subprocess.TimeoutExpired:
        ok = False
    print(f"{arch:24s} {shape:12s} mp={int(mp)} {'ok' if ok else 'FAIL'} {time.time()-t0:5.0f}s", flush=True)
