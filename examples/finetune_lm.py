"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with the fault-tolerant loop, checkpoint/restart, and ASI
compression — the paper's TinyLlama/BoolQ setting scaled to CPU.

  PYTHONPATH=src python examples/finetune_lm.py [--steps 300] [--full-100m]

--full-100m uses a ~100M-parameter config (slow on CPU but runs); the
default is a ~10M config that finishes in a few minutes.
"""
import argparse
import json
import tempfile

import jax

from repro.configs.registry import get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import TrainLoopCfg, make_train_step, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--fail-at", type=int, default=150,
                    help="inject a node failure here to demo recovery")
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b")
    if args.full_100m:
        cfg = cfg.replace(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                          head_dim=64, d_ff=2048, vocab_size=32000,
                          dtype="float32", param_dtype="float32",
                          remat="none", attn_chunk=128)
        seq, batch = 256, 8
    else:
        cfg = cfg.reduced().replace(n_layers=4, d_model=128, d_ff=512,
                                    vocab_size=2048)
        seq, batch = 64, 16
    cfg = cfg.replace(compress="asi", asi_rank=16, asi_last_k=2)

    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, compress={cfg.compress} "
          f"rank={cfg.asi_rank} tail={cfg.asi_last_k}")

    asi_state = api.init_asi(key)
    mask = api.trainable_mask(params)
    opt = make_optimizer("adamw", warmup_cosine(3e-3, 20, args.steps),
                         clip_norm=2.0, weight_decay=1e-4)
    opt_state = opt.init(params)
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=mask)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=seq,
                                global_batch=batch, branching=2))
    ckpt_dir = tempfile.mkdtemp(prefix="finetune_lm_")
    res = run(step_fn, params, opt_state, asi_state, data,
              TrainLoopCfg(total_steps=args.steps, ckpt_dir=ckpt_dir,
                           ckpt_every=50, log_every=25,
                           fail_at_step=args.fail_at),
              hooks={"on_log": lambda s, m: print(
                         json.dumps({"step": s,
                                     "loss": round(m["loss"], 4)})),
                     "on_restart": lambda n: print(
                         f"!! simulated failure -> restart #{n} "
                         f"from latest checkpoint")})
    first, last = res.history[0]["loss"], res.history[-1]["loss"]
    print(f"done: steps={res.step} restarts={res.restarts} "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
