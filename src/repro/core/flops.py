"""Closed-form cost model — paper Appendix A (eqs. 5, 11-19).

Used by the benchmark harness to reproduce the paper's FLOPs/memory tables and
by the §Perf napkin math.  All counts are multiply-accumulate-style FLOPs in
the paper's convention (products only, matching eqs. 11-17).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ConvDims:
    """One conv layer:  A_i (B,C,H,W) * W (C',C,D,D) -> (B,C',H',W')."""
    b: int
    c_in: int
    h: int
    w: int
    c_out: int
    d: int
    stride: int = 1

    @property
    def h_out(self) -> int:
        return max(self.h // self.stride, 1)

    @property
    def w_out(self) -> int:
        return max(self.w // self.stride, 1)

    @property
    def dims(self) -> tuple[int, int, int, int]:
        return (self.b, self.c_in, self.h, self.w)


# ----- memory (elements) ----------------------------------------------------

def vanilla_activation_elems(cd: ConvDims) -> int:
    return cd.b * cd.c_in * cd.h * cd.w


def tucker_activation_elems(cd: ConvDims, ranks: Sequence[int]) -> int:
    """Eq. 5."""
    r = [min(rr, dd) for rr, dd in zip(ranks, cd.dims)]
    return math.prod(r) + sum(d * rr for d, rr in zip(cd.dims, r))


def compression_ratio(cd: ConvDims, ranks: Sequence[int]) -> float:
    """Eq. 19 (R_C)."""
    return vanilla_activation_elems(cd) / tucker_activation_elems(cd, ranks)


# ----- forward / overhead FLOPs ----------------------------------------------

def vanilla_forward_flops(cd: ConvDims) -> int:
    """Eq. 17:  O_vanilla = D²·C·C'·B·H·W  (paper uses input H·W)."""
    return cd.d ** 2 * cd.c_in * cd.c_out * cd.b * cd.h * cd.w


def hosvd_overhead_flops(cd: ConvDims) -> int:
    """Eq. 11/13:  Σ_d max(d,P_d)²·min(d,P_d)  — per-step HOSVD cost."""
    dims = cd.dims
    total = 0
    for i, d in enumerate(dims):
        p = math.prod(dd for j, dd in enumerate(dims) if j != i)
        total += max(d, p) ** 2 * min(d, p)
    return total


def asi_overhead_flops(cd: ConvDims, ranks: Sequence[int]) -> int:
    """Eq. 14:  Σ_m 2·d·d'·r_m + r_m³  (one subspace iteration per mode)."""
    dims = cd.dims
    total = 0
    for m, r in enumerate(ranks):
        d = dims[m]
        dprime = math.prod(dd for j, dd in enumerate(dims) if j != m)
        total += 2 * d * dprime * r + r ** 3
    return total


# ----- backward FLOPs ---------------------------------------------------------

def vanilla_backward_weight_flops(cd: ConvDims) -> int:
    """Eq. 16:  C_vanilla = D²·C·C'·B·H'·W'."""
    return cd.d ** 2 * cd.c_in * cd.c_out * cd.b * cd.h_out * cd.w_out


def asi_backward_weight_flops(cd: ConvDims, ranks: Sequence[int]) -> int:
    """Eq. 15 term-by-term."""
    r1, r2, r3, r4 = [min(rr, dd) for rr, dd in zip(ranks, cd.dims)]
    t1 = r1 * cd.b * cd.c_out * cd.h_out * cd.w_out
    t2 = r1 * r2 * r3 * r4 * cd.h
    t3 = r1 * r2 * r4 * cd.h * cd.w
    t4 = r1 * r2 * cd.c_out * cd.h_out * cd.w_out * cd.d ** 2
    t5 = r2 * cd.c_out * cd.c_in * cd.d ** 2
    return t1 + t2 + t3 + t4 + t5


def speedup_ratio(cd: ConvDims, ranks: Sequence[int]) -> float:
    """Eq. 18 (R_S): vanilla (fwd+bwd) over ASI (fwd + overhead + bwd)."""
    o_v = vanilla_forward_flops(cd)
    c_v = vanilla_backward_weight_flops(cd)
    o_asi = asi_overhead_flops(cd, ranks)
    c_asi = asi_backward_weight_flops(cd, ranks)
    return (o_v + c_v) / (o_v + o_asi + c_asi)


def hosvd_slowdown_ratio(cd: ConvDims, ranks: Sequence[int]) -> float:
    """FLOPs ratio HOSVD_ε/vanilla for a training step (fwd-side overhead)."""
    o_v = vanilla_forward_flops(cd)
    c_v = vanilla_backward_weight_flops(cd)
    c_asi = asi_backward_weight_flops(cd, ranks)   # HOSVD shares the low-rank bwd
    return (o_v + hosvd_overhead_flops(cd) + c_asi) / (o_v + c_v)


# ----- matrix (LLM linear) variants — paper Table 4 setting ------------------

@dataclasses.dataclass(frozen=True)
class LinearDims:
    m: int        # tokens  (B·S)
    k: int        # d_in
    n: int        # d_out


def linear_vanilla_activation_elems(ld: LinearDims) -> int:
    return ld.m * ld.k


def linear_asi_activation_elems(ld: LinearDims, rank: int) -> int:
    return (ld.m + ld.k) * rank


def linear_forward_flops(ld: LinearDims) -> int:
    return ld.m * ld.k * ld.n


def linear_asi_overhead_flops(ld: LinearDims, rank: int) -> int:
    return 2 * ld.m * ld.k * rank + rank ** 3


def linear_vanilla_backward_flops(ld: LinearDims) -> int:
    # dW = Xᵀg  +  dX = g Wᵀ
    return ld.m * ld.k * ld.n * 2


def linear_asi_backward_flops(ld: LinearDims, rank: int) -> int:
    # dW = Q (P̂ᵀ g): M·r·N + K·r·N ;  dX exact: M·K·N
    return ld.m * rank * ld.n + ld.k * rank * ld.n + ld.m * ld.k * ld.n


def linear_speedup_ratio(ld: LinearDims, rank: int) -> float:
    vanilla = linear_forward_flops(ld) + linear_vanilla_backward_flops(ld)
    asi = (linear_forward_flops(ld) + linear_asi_overhead_flops(ld, rank)
           + linear_asi_backward_flops(ld, rank))
    return vanilla / asi
