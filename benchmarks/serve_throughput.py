"""A/B: continuous-batching Engine vs the legacy SequentialEngine.

For each architecture family (dense GQA, MoE, SSM, hybrid — reduced configs
so the A/B runs anywhere, including CPU CI boxes) the same request stream is
served by both engines and we report tokens/s, decode-step counts, and
time-to-first-token.  The continuous engine advances all ``max_batch`` slots
per jitted step and prefills whole prompts in one call, so at max_batch=4 it
needs ~4x fewer device round-trips per generated token; the sequential
engine decodes one slot at a time with per-token Python prefill.

Also verifies the batch=1 greedy parity invariant (the continuous engine
must reproduce the sequential engine token-for-token) before timing.

Traffic-trace mode (``--trace``) replays a seeded Poisson arrival process
with mixed prompt lengths through the *same* chunked-prefill engine twice —
once with the dense per-slot cache, once with the paged block pool — so the
A/B isolates the cache layout: tokens/s, P50/P99 TTFT, peak cache bytes,
preemptions, and token-for-token parity between the two runs.

Run:  PYTHONPATH=src python -m benchmarks.serve_throughput [--trace]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import build_model
from repro.runtime.serve_loop import (Engine, Request, SequentialEngine,
                                      ServeCfg)
from repro.telemetry import Recorder

ARCHS = [
    ("tinyllama-1.1b", "dense-gqa"),
    ("moonshot-v1-16b-a3b", "moe"),
    ("mamba2-130m", "ssm"),
    ("jamba-1.5-large-398b", "hybrid"),
]

MAX_BATCH = 4
MAX_LEN = 64
MAX_NEW = 16
N_REQUESTS = 8


def _requests(n=N_REQUESTS, max_new=MAX_NEW):
    # two prompt lengths: bounded prefill compiles, staggered slot positions
    return [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(4 + i % 2)],
                    max_new_tokens=max_new) for i in range(n)]


def run(verbose: bool = True) -> dict:
    rows = []
    for arch, family in ARCHS:
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        scfg = ServeCfg(max_batch=MAX_BATCH, max_len=MAX_LEN)

        # --- parity gate: batch=1 continuous == sequential, greedy --------
        par = _requests(2, max_new=6)
        a = Engine(api, params, ServeCfg(max_batch=1, max_len=MAX_LEN)).run(
            [Request(uid=r.uid, prompt=list(r.prompt), max_new_tokens=6)
             for r in par])
        b = SequentialEngine(
            api, params, ServeCfg(max_batch=1, max_len=MAX_LEN)).run(par)
        parity = ({r.uid: r.out for r in a} == {r.uid: r.out for r in b})

        # --- timed A/B (engines warmed so compiles don't count) -----------
        cont_rec, seq_rec = Recorder(), Recorder()
        cont = Engine(api, params, scfg, telemetry=cont_rec)
        seq = SequentialEngine(api, params, scfg, telemetry=seq_rec)
        cont.run(_requests(2, max_new=2))           # warm-up: compile
        seq.run(_requests(2, max_new=2))
        ctok0 = cont_rec.counter("serve.tokens").value
        stok0 = seq_rec.counter("serve.tokens").value
        cont.run(_requests())
        c = cont.last_stats
        seq.run(_requests())
        s = seq.last_stats
        # last_stats is a derived view over the recorder's counter streams
        # (one source of truth) — the timed run's delta must reconcile
        assert (cont_rec.counter("serve.tokens").value - ctok0
                == c.generated_tokens)
        assert (seq_rec.counter("serve.tokens").value - stok0
                == s.generated_tokens)

        row = {
            "arch": arch, "family": family, "parity_batch1": parity,
            "cont_tok_s": c.tokens_per_s, "seq_tok_s": s.tokens_per_s,
            "speedup": c.tokens_per_s / s.tokens_per_s if s.tokens_per_s else 0,
            "cont_steps": c.decode_steps, "seq_steps": s.decode_steps,
            "cont_ttft_mean_s": c.ttft_mean_s, "seq_ttft_mean_s": s.ttft_mean_s,
        }
        rows.append(row)
        if verbose:
            print(f"{arch:22s} [{family:9s}] parity={'OK' if parity else 'FAIL'}"
                  f"  continuous {row['cont_tok_s']:7.1f} tok/s"
                  f" ({row['cont_steps']} steps)"
                  f"  sequential {row['seq_tok_s']:7.1f} tok/s"
                  f" ({row['seq_steps']} steps)"
                  f"  speedup {row['speedup']:.2f}x")
    wins = sum(r["speedup"] > 1.0 for r in rows)
    out = {"max_batch": MAX_BATCH, "rows": rows, "families_won": wins}
    if verbose:
        print(f"continuous batching faster on {wins}/{len(rows)} families "
              f"at max_batch={MAX_BATCH}")
    return out


# --- traffic-trace mode -----------------------------------------------------

TRACE_ARCH = "tinyllama-1.1b"
TRACE_MAX_BATCH = 8
TRACE_MAX_LEN = 48
TRACE_PAGE_BLOCK = 8
TRACE_POOL_BLOCKS = 17          # 16 usable + trash: 2.35x below dense rows


def make_trace(n: int = 24, seed: int = 0, *,
               max_len: int = TRACE_MAX_LEN) -> list[Request]:
    """A seeded request trace: Poisson inter-arrival gaps (in decode steps)
    over a bimodal prompt-length mix — ~70% short chat-style prompts, ~30%
    long context dumps — with varied generation budgets.  Deterministic for
    a given (n, seed), so two engines replay the identical workload."""
    rng = np.random.default_rng(seed)
    reqs, step = [], 0
    for i in range(n):
        step += int(rng.poisson(2))
        if rng.random() < 0.7:
            plen = int(rng.integers(4, 9))
        else:
            plen = int(rng.integers(24, 37))
        max_new = int(rng.integers(4, 13))
        max_new = min(max_new, max_len - plen - 1)
        reqs.append(Request(
            uid=i, prompt=[1 + int(t) for t in rng.integers(0, 37, plen)],
            max_new_tokens=max_new, arrival_step=step))
    return reqs


def _trace_cfgs(pool_blocks: int):
    dense = ServeCfg(max_batch=TRACE_MAX_BATCH, max_len=TRACE_MAX_LEN,
                     prefill_chunk=TRACE_PAGE_BLOCK)
    paged = ServeCfg(max_batch=TRACE_MAX_BATCH, max_len=TRACE_MAX_LEN,
                     cache="paged", page_block=TRACE_PAGE_BLOCK,
                     pool_blocks=pool_blocks)
    return dense, paged


def derived_lifecycle_counts(events) -> dict:
    """Request-lifecycle counts re-derived from a telemetry event slice —
    the independent cross-check that the event stream and the stats view
    (both fed by the same recorder) tell the same story."""
    retired = [e for e in events
               if e["kind"] == "I" and e["name"] == "serve.request.retired"]
    return {
        "requests": len(retired),
        "generated_tokens": int(sum(e["attrs"]["tokens"] for e in retired)),
        "first_tokens": sum(e["kind"] == "I"
                            and e["name"] == "serve.request.first_token"
                            for e in events),
        "preemptions": sum(e["kind"] == "I"
                           and e["name"] == "serve.request.preempted"
                           for e in events),
    }


def _stats_counts(st) -> dict:
    return {"requests": st.requests,
            "generated_tokens": st.generated_tokens,
            "first_tokens": st.requests,
            "preemptions": st.preemptions}


def run_trace(verbose: bool = True, *, n: int = 24, seed: int = 0,
              pool_blocks: int = TRACE_POOL_BLOCKS) -> dict:
    cfg = get_config(TRACE_ARCH).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    dense_cfg, paged_cfg = _trace_cfgs(pool_blocks)

    def replay(scfg):
        rec = Recorder(capacity=1 << 15)
        eng = Engine(api, params, scfg, telemetry=rec)
        eng.run(make_trace(n, seed))                 # warm-up: compile
        mark = len(rec.events)
        done = eng.run(make_trace(n, seed))          # timed replay
        return eng, {r.uid: r.out for r in done}, list(rec.events)[mark:]

    dense_eng, dense_out, dense_ev = replay(dense_cfg)
    paged_eng, paged_out, paged_ev = replay(paged_cfg)
    d, p = dense_eng.last_stats, paged_eng.last_stats
    parity = dense_out == paged_out
    tele_ok = all(derived_lifecycle_counts(ev) == _stats_counts(st)
                  for ev, st in ((dense_ev, d), (paged_ev, p)))
    out = {
        "telemetry": {"derived_matches_stats": tele_ok,
                      "events_timed_run": [len(dense_ev), len(paged_ev)]},
        "arch": TRACE_ARCH, "n_requests": n, "seed": seed,
        "max_batch": TRACE_MAX_BATCH, "max_len": TRACE_MAX_LEN,
        "page_block": TRACE_PAGE_BLOCK, "pool_blocks": pool_blocks,
        "parity": parity,
        "dense": {"tok_s": d.tokens_per_s, "ttft_p50_s": d.ttft_p50_s,
                  "ttft_p99_s": d.ttft_p99_s,
                  "peak_cache_bytes": d.peak_cache_bytes},
        "paged": {"tok_s": p.tokens_per_s, "ttft_p50_s": p.ttft_p50_s,
                  "ttft_p99_s": p.ttft_p99_s,
                  "peak_cache_bytes": p.peak_cache_bytes,
                  "peak_used_blocks": p.peak_used_blocks,
                  "preemptions": p.preemptions},
        "kv_reduction_x": (d.peak_cache_bytes / p.peak_cache_bytes
                           if p.peak_cache_bytes else 0.0),
        "tok_s_ratio": (p.tokens_per_s / d.tokens_per_s
                        if d.tokens_per_s else 0.0),
    }
    if verbose:
        print(f"trace n={n} seed={seed}  parity={'OK' if parity else 'FAIL'}")
        print(f"  dense  {d.tokens_per_s:7.1f} tok/s  "
              f"ttft p50/p99 {d.ttft_p50_s*1e3:.1f}/{d.ttft_p99_s*1e3:.1f} ms"
              f"  peak {d.peak_cache_bytes/1024:.0f} KiB")
        print(f"  paged  {p.tokens_per_s:7.1f} tok/s  "
              f"ttft p50/p99 {p.ttft_p50_s*1e3:.1f}/{p.ttft_p99_s*1e3:.1f} ms"
              f"  peak {p.peak_cache_bytes/1024:.0f} KiB"
              f"  ({p.peak_used_blocks} blocks, "
              f"{p.preemptions} preemptions)")
        print(f"  KV reduction {out['kv_reduction_x']:.2f}x, "
              f"paged/dense tok/s {out['tok_s_ratio']:.2f}")
        print(f"  telemetry derived==stats: "
              f"{'OK' if tele_ok else 'FAIL'} "
              f"({out['telemetry']['events_timed_run']} events)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", action="store_true",
                    help="traffic-trace A/B (dense vs paged cache) instead "
                         "of the engine A/B")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.trace:
        out = run_trace(n=args.requests, seed=args.seed)
        assert out["parity"], "paged engine diverged from dense on the trace"
        assert out["kv_reduction_x"] >= 2.0, (
            f"peak KV bytes only {out['kv_reduction_x']:.2f}x below dense")
        assert out["telemetry"]["derived_matches_stats"], (
            "telemetry-derived lifecycle counts diverged from last_stats")
    else:
        out = run()
        assert all(r["parity_batch1"] for r in out["rows"]), \
            "batch=1 parity broke"
        assert out["families_won"] >= 2, (
            "continuous batching must beat sequential on >= 2 families")
