"""CLI for repro-lint: ``python -m repro.analysis [paths] [options]``.

Examples:

    python -m repro.analysis --format json
    python -m repro.analysis --select jit-purity src/repro/runtime
    python -m repro.analysis --ignore partition-coverage --format text
    python -m repro.analysis --plane graph --format json
    python -m repro.analysis --plane graph --update-golden

Exit status is 0 when no *unsuppressed* findings remain, 1 otherwise
(suppressed findings are still reported, flagged, so CI artifacts keep
the full audit trail).

``--plane`` picks the rule plane (DESIGN.md §11 and §14): ``ast`` rules
read the source, ``graph`` rules read what JAX traces and compiles
(vjp residuals, collectives, donation aliasing, jit-cache signatures);
``all`` runs both.  ``--update-golden`` regenerates the graph plane's
per-family residual-census fixture instead of linting.
"""
from __future__ import annotations

import argparse
import sys


def build_parser() -> argparse.ArgumentParser:
    from repro.analysis.rules import RULES
    from repro.analysis.core import PLANES
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: static contract checks for ASI residuals, "
                    "jit purity, partition coverage, Pallas geometry, and "
                    "launch shims (ast plane), plus jaxpr/HLO-level proofs "
                    "for residuals, collectives, donation, and recompilation "
                    "(graph plane).",
        epilog="rules: " + "; ".join(
            f"{name} [{PLANES.get(name, 'ast')}] — {doc}"
            for name, (_s, _f, doc) in sorted(RULES.items())))
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="output format (default: text)")
    p.add_argument("--select", action="append", default=None,
                   metavar="RULE",
                   help="run only these rules (repeatable, or comma-"
                        "separated; overrides --plane)")
    p.add_argument("--ignore", action="append", default=None,
                   metavar="RULE",
                   help="skip these rules (repeatable, or comma-separated)")
    p.add_argument("--root", default=None,
                   help="repo root (default: auto-detected from the "
                        "installed package location)")
    p.add_argument("--plane", choices=("ast", "graph", "all"), default="ast",
                   help="rule plane: ast = source-level, graph = jaxpr/HLO-"
                        "level, all = both (default: ast)")
    p.add_argument("--update-golden", action="store_true",
                   help="regenerate the graph plane's golden residual-census "
                        "fixture (src/repro/analysis/graph/"
                        "golden_residuals.json) and exit")
    return p


def _split(values) -> list[str] | None:
    if not values:
        return None
    out: list[str] = []
    for v in values:
        out.extend(x.strip() for x in v.split(",") if x.strip())
    return out or None


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.analysis import core
    from repro.analysis import rules  # noqa: F401  (registers rules)

    root = args.root or core.find_repo_root()
    if args.update_golden:
        from repro.analysis.graph import residual_audit
        path = residual_audit.update_golden()
        print(f"repro-lint: wrote {path}")
        return 0
    findings = core.run_lint(root=root, paths=args.paths or None,
                             select=_split(args.select),
                             ignore=_split(args.ignore),
                             plane=args.plane)
    if args.format == "json":
        print(core.render_json(findings, root, plane=args.plane))
    else:
        print(core.render_text(findings))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
