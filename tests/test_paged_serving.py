"""Paged-engine parity battery.

* property test (hypothesis): random admission schedules through the paged
  continuous engine match the sequential engine token-for-token, and match
  the dense continuous engine's finish ordering, across dense / MoE /
  SSM-hybrid families;
* chunked-prefill equivalence: for every serving family the chunk runner's
  final logits are bit-identical across chunk sizes {1, 7, exact, > prompt}
  (including int8 KV and encdec/vlm embeds) and agree with whole-prompt
  ``ModelAPI.prefill``;
* the ``_prefill_jit`` growth fix: chunked prefill keeps compile-cache
  cardinality bounded over a 50-length trace;
* mid-decode pool exhaustion preempts + requeues (never raises) and stays
  exact.
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs.registry import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.runtime.serve_loop import (Engine, Request,  # noqa: E402
                                      SequentialEngine, ServeCfg)

KEY = jax.random.PRNGKey(0)
MAX_LEN = 32


@functools.lru_cache(maxsize=None)
def _api(arch, **replace):
    cfg = get_config(arch).reduced()
    if replace:
        cfg = cfg.replace(**replace)
    api = build_model(cfg)
    return api, api.init(KEY)


def _embeds_for(api):
    """Encoder frames / image-patch embeds matching the reduced config."""
    cfg = api.cfg
    if cfg.family == "encdec":
        n = cfg.enc_len
    elif cfg.family == "vlm":
        n = cfg.n_img_tokens
    else:
        return None
    rng = np.random.default_rng(7)
    return rng.standard_normal((1, n, cfg.d_model)).astype(np.float32)


def _reqs(specs, api):
    emb = _embeds_for(api)
    return [Request(uid=i, prompt=[1 + (i * 5 + j) % 37 for j in range(pl)],
                    max_new_tokens=mn, arrival_step=ar,
                    embeds=None if emb is None else emb.copy())
            for i, (pl, mn, ar) in enumerate(specs)]


# --- admission-schedule parity ---------------------------------------------

PROP_ARCHS = ["tinyllama-1.1b", "granite-moe-3b-a800m",
              "jamba-1.5-large-398b"]

# engines are built once per arch and reused across examples/schedules, so
# the jit compiles are paid exactly once
@functools.lru_cache(maxsize=None)
def _prop_engines(arch):
    api, params = _api(arch)
    # oracle at max_batch=2: per-request cache re-init makes the wave-shaped
    # loop exact for every family (recurrent SSM state no longer leaks
    # across slots), so the oracle itself exercises batched waves
    seq = SequentialEngine(api, params, ServeCfg(max_batch=2, max_len=MAX_LEN))
    dense = Engine(api, params, ServeCfg(max_batch=3, max_len=MAX_LEN,
                                         prefill_chunk=4))
    paged = Engine(api, params, ServeCfg(max_batch=3, max_len=MAX_LEN,
                                         cache="paged", page_block=4))
    return api, seq, dense, paged


def _check_schedule_parity(arch, sched):
    """One admission schedule: paged == sequential per-token, and the paged
    scheduler finishes requests in the same order as the dense one."""
    api, seq, dense, paged = _prop_engines(arch)
    specs, step = [], 0
    for plen, mn, gap in sched:
        step += gap
        specs.append((plen, mn, step))
    want = {r.uid: list(r.out) for r in seq.run(_reqs(specs, api))}
    dense_done = dense.run(_reqs(specs, api))
    paged_done = paged.run(_reqs(specs, api))
    assert {r.uid: r.out for r in paged_done} == want
    assert [r.uid for r in paged_done] == [r.uid for r in dense_done]
    assert all(r.ttft_s is not None for r in paged_done)


FIXED_SCHEDULES = [
    [(3, 6, 0), (8, 4, 0), (5, 8, 2), (2, 3, 5)],       # burst then trickle
    [(10, 2, 0), (1, 8, 1), (1, 8, 1), (1, 8, 1), (6, 5, 0)],
    [(4, 1, 3), (4, 1, 0), (4, 1, 0), (9, 7, 6)],       # single-token outs
]


@pytest.mark.parametrize("arch", PROP_ARCHS)
@pytest.mark.parametrize("sched", FIXED_SCHEDULES,
                         ids=[f"sched{i}" for i in range(len(FIXED_SCHEDULES))])
def test_fixed_schedules_token_and_order_parity(arch, sched):
    """Deterministic slice of the property below — runs even without
    hypothesis installed."""
    _check_schedule_parity(arch, sched)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                      # pragma: no cover
    st = None

if st is not None:
    schedule = st.lists(
        st.tuples(st.integers(1, 10),       # prompt length
                  st.integers(1, 8),        # max_new_tokens
                  st.integers(0, 6)),       # arrival gap (decode steps)
        min_size=1, max_size=6)

    @pytest.mark.parametrize("arch", PROP_ARCHS)
    @given(sched=schedule)
    @settings(max_examples=6, deadline=None, derandomize=True)
    def test_random_schedules_token_and_order_parity(arch, sched):
        _check_schedule_parity(arch, sched)
else:
    @pytest.mark.skip(reason="property test needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_random_schedules_token_and_order_parity():
        pass


# --- chunked-prefill equivalence -------------------------------------------

CHUNK_ARCHS = ["tinyllama-1.1b", "h2o-danube-3-4b", "granite-moe-3b-a800m",
               "mamba2-130m", "jamba-1.5-large-398b", "whisper-medium",
               "internvl2-1b"]
PROMPT = [3, 14, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9]          # 12 tokens


def _chunk_logits(api, params, chunk, prompt=PROMPT):
    """Drive the engine's real chunk runner to the end of ``prompt`` and
    return the next-token logits."""
    eng = Engine(api, params, ServeCfg(max_batch=1, max_len=MAX_LEN,
                                       prefill_chunk=chunk))
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=1,
                  embeds=_embeds_for(api))
    job = eng._start_job(req, 0, api.cfg.family)
    while job.done < len(job.items):
        eng._advance_job(job)
    return np.asarray(job.logits)


@pytest.mark.parametrize("arch", CHUNK_ARCHS)
def test_chunked_prefill_bitwise_across_chunk_sizes(arch):
    """Chunk size must be a pure scheduling knob: 1, a ragged 7, the exact
    item count, and larger-than-prompt all produce bit-identical logits."""
    api, params = _api(arch)
    exact = len(PROMPT) + (api.cfg.n_img_tokens
                           if api.cfg.family == "vlm" else 0)
    base = _chunk_logits(api, params, 1)
    for chunk in (7, exact, exact + 9):
        got = _chunk_logits(api, params, chunk)
        assert (got == base).all(), f"chunk={chunk} diverged bitwise"


@pytest.mark.parametrize("arch", CHUNK_ARCHS)
def test_chunked_prefill_matches_whole_prompt(arch):
    """The chunk runner agrees with ``ModelAPI.prefill`` on the reference
    backend: same argmax, logits equal to fp32 tolerance (the whole-prompt
    path reduces over all positions at once, so bitwise is not required
    across the two formulations — only across chunk sizes)."""
    api, params = _api(arch)
    chunked = _chunk_logits(api, params, 7)
    emb = _embeds_for(api)
    whole, _ = api.prefill(params, jnp.asarray([PROMPT], jnp.int32), MAX_LEN,
                           None if emb is None else jnp.asarray(emb))
    whole = np.asarray(whole, np.float32)
    assert chunked.argmax(-1) == whole.argmax(-1)
    # hybrid SSD prefill is a chunked parallel scan vs the decode recurrence:
    # same math, different reduction order, ~1e-3 fp32 drift at these widths
    np.testing.assert_allclose(chunked, whole, atol=2e-3)


def test_chunked_prefill_bitwise_int8_kv():
    """int8 KV quantizes per chunk step, so whole-prompt fp-then-quantize is
    a different (documented) rounding — the int8 contract is bitwise
    equality across chunk sizes only."""
    api, params = _api("tinyllama-1.1b", kv_cache_dtype="int8")
    base = _chunk_logits(api, params, 1)
    for chunk in (7, len(PROMPT), len(PROMPT) + 9):
        assert (_chunk_logits(api, params, chunk) == base).all()


def test_chunked_engine_end_to_end_matches_legacy():
    api, params = _api("tinyllama-1.1b")
    specs = [(3, 6, 0), (8, 6, 0), (5, 6, 0), (2, 6, 0)]
    legacy = Engine(api, params, ServeCfg(max_batch=2, max_len=MAX_LEN))
    want = {r.uid: r.out for r in legacy.run(_reqs(specs, api))}
    for chunk in (1, 7, 8, 40):
        eng = Engine(api, params, ServeCfg(max_batch=2, max_len=MAX_LEN,
                                           prefill_chunk=chunk))
        assert {r.uid: r.out for r in eng.run(_reqs(specs, api))} == want


# --- compile-cache growth regression ---------------------------------------

def test_prefill_compile_cache_bounded_over_mixed_lengths():
    """The serve_loop._prefill_jit fix: under a 50-distinct-length trace the
    legacy path compiled one prefill per length; chunked prefill shares one
    compiled chunk body (plus one tail program per residue is NOT allowed —
    padding keeps it to exactly one entry per chunk size)."""
    api, params = _api("tinyllama-1.1b")
    eng = Engine(api, params, ServeCfg(max_batch=4, max_len=64,
                                       prefill_chunk=8))
    reqs = [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(1 + i)],
                    max_new_tokens=1) for i in range(50)]
    eng.run(reqs)
    sizes = eng.compile_cache_sizes()
    assert sizes == {"prefill": 0, "chunk": 1}, sizes


def test_legacy_prefill_cache_grows_per_length():
    """The failure mode the fix addresses, pinned as a contrast: whole-prompt
    prefill compiles one entry per distinct prompt length."""
    api, params = _api("tinyllama-1.1b")
    eng = Engine(api, params, ServeCfg(max_batch=4, max_len=MAX_LEN))
    reqs = [Request(uid=i, prompt=[1] * (1 + i), max_new_tokens=1)
            for i in range(5)]
    eng.run(reqs)
    assert eng.compile_cache_sizes() == {"prefill": 5, "chunk": 0}


# --- sequential-engine wave batching ----------------------------------------

def test_sequential_batched_waves_exact_for_recurrent_family():
    """Regression for the wave-shared-cache leak: decode_step advances every
    batch row, so a cache shared across a wave let one slot's recurrent
    (SSM/conv) state pollute the next slot's prefill.  With per-request
    cache re-init, batched waves must match fully isolated serving on an
    SSM-hybrid arch token-for-token."""
    api, params = _api("jamba-1.5-large-398b")
    specs = [(3, 6, 0), (4, 6, 0), (5, 6, 0)]
    one = SequentialEngine(api, params,
                           ServeCfg(max_batch=1, max_len=MAX_LEN))
    want = {r.uid: r.out for r in one.run(_reqs(specs, api))}
    batched = SequentialEngine(api, params,
                               ServeCfg(max_batch=3, max_len=MAX_LEN))
    got = {r.uid: r.out for r in batched.run(_reqs(specs, api))}
    assert got == want


# --- pool exhaustion --------------------------------------------------------

def test_pool_exhaustion_preempts_and_stays_exact():
    """Mid-decode exhaustion must queue work (preempt newest, recompute on
    re-admission), never raise, and never change any request's tokens."""
    api, params = _api("tinyllama-1.1b")
    specs = [(3, 18, 0), (4, 18, 0), (5, 18, 0), (2, 18, 0)]
    seq = SequentialEngine(api, params, ServeCfg(max_batch=2, max_len=MAX_LEN))
    want = {r.uid: r.out for r in seq.run(_reqs(specs, api))}
    # worst case 6 blocks x 4 requests >> 9 usable: exhaustion guaranteed
    eng = Engine(api, params, ServeCfg(max_batch=4, max_len=MAX_LEN,
                                       cache="paged", page_block=4,
                                       pool_blocks=10))
    done = eng.run(_reqs(specs, api))
    assert {r.uid: r.out for r in done} == want
    assert eng.last_stats.preemptions > 0
    assert eng.last_stats.peak_used_blocks <= 9


def test_backpressure_admission_waits_for_blocks():
    api, params = _api("tinyllama-1.1b")
    # pool fits ~one worst-case request: admissions must serialize, not fail
    eng = Engine(api, params, ServeCfg(max_batch=4, max_len=MAX_LEN,
                                       cache="paged", page_block=8,
                                       pool_blocks=4))
    specs = [(6, 10, 0), (6, 10, 0), (6, 10, 0)]
    done = eng.run(_reqs(specs, api))
    assert all(len(r.out) == 10 for r in done)


# --- validation -------------------------------------------------------------

def test_paged_rejects_sliding_window():
    api, params = _api("h2o-danube-3-4b")
    with pytest.raises(ValueError, match="sliding-window"):
        Engine(api, params, ServeCfg(cache="paged"))


def test_paged_rejects_unaligned_max_len():
    api, params = _api("tinyllama-1.1b")
    with pytest.raises(ValueError, match="page_block"):
        Engine(api, params, ServeCfg(max_len=30, cache="paged",
                                     page_block=4))


def test_unknown_cache_flag_rejected():
    api, params = _api("tinyllama-1.1b")
    with pytest.raises(ValueError, match="dense|paged"):
        Engine(api, params, ServeCfg(cache="ring"))


def test_request_too_large_for_pool_rejected():
    api, params = _api("tinyllama-1.1b")
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=MAX_LEN,
                                       cache="paged", page_block=4,
                                       pool_blocks=3))
    with pytest.raises(ValueError, match="pool_blocks"):
        eng.run([Request(uid=0, prompt=[1] * 8, max_new_tokens=8)])
