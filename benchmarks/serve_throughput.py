"""A/B: continuous-batching Engine vs the legacy SequentialEngine.

For each architecture family (dense GQA, MoE, SSM, hybrid — reduced configs
so the A/B runs anywhere, including CPU CI boxes) the same request stream is
served by both engines and we report tokens/s, decode-step counts, and
time-to-first-token.  The continuous engine advances all ``max_batch`` slots
per jitted step and prefills whole prompts in one call, so at max_batch=4 it
needs ~4x fewer device round-trips per generated token; the sequential
engine decodes one slot at a time with per-token Python prefill.

Also verifies the batch=1 greedy parity invariant (the continuous engine
must reproduce the sequential engine token-for-token) before timing.

Run:  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import jax

from repro.configs.registry import get_config
from repro.models import build_model
from repro.runtime.serve_loop import (Engine, Request, SequentialEngine,
                                      ServeCfg)

ARCHS = [
    ("tinyllama-1.1b", "dense-gqa"),
    ("moonshot-v1-16b-a3b", "moe"),
    ("mamba2-130m", "ssm"),
    ("jamba-1.5-large-398b", "hybrid"),
]

MAX_BATCH = 4
MAX_LEN = 64
MAX_NEW = 16
N_REQUESTS = 8


def _requests(n=N_REQUESTS, max_new=MAX_NEW):
    # two prompt lengths: bounded prefill compiles, staggered slot positions
    return [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(4 + i % 2)],
                    max_new_tokens=max_new) for i in range(n)]


def run(verbose: bool = True) -> dict:
    rows = []
    for arch, family in ARCHS:
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        scfg = ServeCfg(max_batch=MAX_BATCH, max_len=MAX_LEN)

        # --- parity gate: batch=1 continuous == sequential, greedy --------
        par = _requests(2, max_new=6)
        a = Engine(api, params, ServeCfg(max_batch=1, max_len=MAX_LEN)).run(
            [Request(uid=r.uid, prompt=list(r.prompt), max_new_tokens=6)
             for r in par])
        b = SequentialEngine(
            api, params, ServeCfg(max_batch=1, max_len=MAX_LEN)).run(par)
        parity = ({r.uid: r.out for r in a} == {r.uid: r.out for r in b})

        # --- timed A/B (engines warmed so compiles don't count) -----------
        cont = Engine(api, params, scfg)
        seq = SequentialEngine(api, params, scfg)
        cont.run(_requests(2, max_new=2))           # warm-up: compile
        seq.run(_requests(2, max_new=2))
        cont.run(_requests())
        c = cont.last_stats
        seq.run(_requests())
        s = seq.last_stats

        row = {
            "arch": arch, "family": family, "parity_batch1": parity,
            "cont_tok_s": c.tokens_per_s, "seq_tok_s": s.tokens_per_s,
            "speedup": c.tokens_per_s / s.tokens_per_s if s.tokens_per_s else 0,
            "cont_steps": c.decode_steps, "seq_steps": s.decode_steps,
            "cont_ttft_mean_s": c.ttft_mean_s, "seq_ttft_mean_s": s.ttft_mean_s,
        }
        rows.append(row)
        if verbose:
            print(f"{arch:22s} [{family:9s}] parity={'OK' if parity else 'FAIL'}"
                  f"  continuous {row['cont_tok_s']:7.1f} tok/s"
                  f" ({row['cont_steps']} steps)"
                  f"  sequential {row['seq_tok_s']:7.1f} tok/s"
                  f" ({row['seq_steps']} steps)"
                  f"  speedup {row['speedup']:.2f}x")
    wins = sum(r["speedup"] > 1.0 for r in rows)
    out = {"max_batch": MAX_BATCH, "rows": rows, "families_won": wins}
    if verbose:
        print(f"continuous batching faster on {wins}/{len(rows)} families "
              f"at max_batch={MAX_BATCH}")
    return out


if __name__ == "__main__":
    out = run()
    assert all(r["parity_batch1"] for r in out["rows"]), "batch=1 parity broke"
    assert out["families_won"] >= 2, (
        "continuous batching must beat sequential on >= 2 families")
