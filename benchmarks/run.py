"""Benchmark harness entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline number
that reproduces the table's claim).

``--snapshot`` additionally records each benchmark that defines a snapshot
mapping as ``benchmarks/snapshots/BENCH_<name>.json`` (shared schema:
``benchmarks/snapshots.py``); ``--only`` restricts the run to named
benchmarks:

  PYTHONPATH=src python -m benchmarks.run --only scenario_suite --snapshot
"""
from __future__ import annotations

import argparse
import time


def _timed(name, fn, derive):
    t0 = time.perf_counter()
    out = fn(verbose=False)
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(out)}")
    return out


def _benches():
    from benchmarks import (activation_memory, adapt_throughput, fused_asi,
                            latency_ondevice, scenario_suite,
                            serve_throughput, shard_scaling, table1_imagenet,
                            table4_tinyllama, telemetry_overhead, warm_start)

    # (name, run, derive, snap) — snap: out -> (config, metrics, series)
    # for benchmarks with a recorded BENCH_<name>.json snapshot
    return [
        ("table1_imagenet", table1_imagenet.run,
         lambda rows: f"max_mem_ratio={max(r['mem_ratio'] for r in rows):.0f}x",
         None),
        ("table4_tinyllama", table4_tinyllama.run,
         lambda rows: f"mem_ratio_1layer={rows[0]['mem_ratio']:.0f}x;"
                      f"flops_ratio_5layer={rows[-1]['flops_ratio']:.2f}x",
         None),
        ("fig5_latency", latency_ondevice.run,
         lambda o: f"hosvd_fwd_blowup={o['ratios']['fwd_hosvd_over_vanilla']:.0f}x;"
                   f"asi_step_speedup={o['ratios']['asi_step_speedup']:.2f}x",
         None),
        ("fig3_warmstart", warm_start.run,
         lambda o: f"gerr_warm={o['gerr_warm']:.3f};gerr_cold={o['gerr_cold']:.3f}",
         None),
        ("fused_asi", fused_asi.run,
         lambda o: f"backend={o['backend']};"
                   f"hbm_pass_ratio={o['hbm_pass_ratio']:.0f}x",
         lambda o: ({"shapes": [r["shape"] for r in o["rows"]]},
                    {"backend": o["backend"],
                     "hbm_pass_ratio": float(o["hbm_pass_ratio"])}, None)),
        ("serve_throughput", serve_throughput.run,
         lambda o: f"families_won={o['families_won']}/{len(o['rows'])};"
                   f"min_speedup={min(r['speedup'] for r in o['rows']):.2f}x",
         lambda o: ({"max_batch": o["max_batch"],
                     "archs": [r["arch"] for r in o["rows"]]},
                    {"families": len(o["rows"]),
                     "families_won": o["families_won"],
                     "min_speedup": round(min(r["speedup"]
                                              for r in o["rows"]), 3),
                     "parity_all": all(r["parity_batch1"]
                                       for r in o["rows"])}, None)),
        ("serve_trace", serve_throughput.run_trace,
         lambda o: f"kv_reduction={o['kv_reduction_x']:.2f}x;"
                   f"tok_s_ratio={o['tok_s_ratio']:.2f};"
                   f"parity={o['parity']}",
         lambda o: ({"arch": o["arch"], "n_requests": o["n_requests"],
                     "seed": o["seed"], "max_batch": o["max_batch"],
                     "max_len": o["max_len"],
                     "page_block": o["page_block"],
                     "pool_blocks": o["pool_blocks"]},
                    {"parity": o["parity"],
                     "kv_reduction_x": round(float(o["kv_reduction_x"]), 3),
                     "tok_s_ratio": round(float(o["tok_s_ratio"]), 3),
                     "paged_peak_used_blocks":
                         o["paged"]["peak_used_blocks"],
                     "paged_preemptions": o["paged"]["preemptions"],
                     "dense_peak_cache_bytes":
                         o["dense"]["peak_cache_bytes"],
                     "paged_peak_cache_bytes":
                         o["paged"]["peak_cache_bytes"]},
                    {"ttft_p50_s": [round(o["dense"]["ttft_p50_s"], 5),
                                    round(o["paged"]["ttft_p50_s"], 5)],
                     "ttft_p99_s": [round(o["dense"]["ttft_p99_s"], 5),
                                    round(o["paged"]["ttft_p99_s"], 5)]})),
        ("shard_scaling", shard_scaling.run,
         lambda o: f"min_arg_mem_ratio_1to8="
                   f"{o['min_arg_mem_ratio_1to8']:.1f}x",
         None),
        ("activation_memory", activation_memory.run,
         lambda o: f"max_site_ratio={o['max_site_ratio']:.0f}x;"
                   f"measured_gap="
                   f"{o['measured_gap']['gap_asi']*100:.0f}%",
         lambda o: ({"archs": [r["arch"] for r in o["rows"]]},
                    {"max_site_ratio": round(float(o["max_site_ratio"]), 1),
                     "measured_gap_asi":
                         round(float(o["measured_gap"]["gap_asi"]), 4)},
                    None)),
        ("adapt_throughput", adapt_throughput.run,
         lambda o: f"retention={o['retention']:.2f}x;"
                   f"adapt_steps_per_s={o['adapt_steps_per_s']:.1f}",
         None),
        ("telemetry_overhead", telemetry_overhead.run,
         lambda o: f"overhead={o['overhead_frac'] * 100:.2f}%;"
                   f"parity={o['derived_matches_stats']}",
         lambda o: ({"arch": o["arch"], "n_requests": o["n_requests"],
                     "seed": o["seed"], "repeats": o["repeats"]},
                    {"overhead_frac": round(float(o["overhead_frac"]), 4),
                     "gate_frac": o["gate_frac"],
                     "off_tok_s": round(float(o["off_tok_s"]), 1),
                     "on_tok_s": round(float(o["on_tok_s"]), 1),
                     "derived_matches_stats": o["derived_matches_stats"],
                     "events_per_run": o["events_per_run"],
                     "dropped": o["dropped"]}, None)),
        ("scenario_suite", scenario_suite.run,
         lambda o: f"recovered={o['recovered']};"
                   f"forgetting_phase0={o['forgetting_phase0']:.3f};"
                   f"replans={o['summary']['replans']}",
         lambda o: (o["config"],
                    {"recovered": o["recovered"],
                     "forgetting_bounded": o["forgetting_bounded"],
                     "recovery_phase1": float(o["recovery_phase1"]),
                     "forgetting_phase0": float(o["forgetting_phase0"]),
                     "bursts": o["summary"]["bursts"],
                     "replans": o["summary"]["replans"]},
                    {"quality": o["quality"],
                     **{f"probe_phase{p}": c
                        for p, c in o["probe_curves"].items()}})),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="run only the named benchmark (repeatable)")
    ap.add_argument("--snapshot", action="store_true",
                    help="record BENCH_<name>.json for snapshot-mapped "
                         "benchmarks")
    ap.add_argument("--snapshot-dir", default=None,
                    help="override benchmarks/snapshots/")
    args = ap.parse_args(argv)

    benches = _benches()
    names = [b[0] for b in benches]
    for only in args.only or []:
        if only not in names:
            raise SystemExit(f"unknown benchmark {only!r}; choose from "
                             f"{names}")

    from benchmarks import snapshots
    print("name,us_per_call,derived")
    for name, fn, derive, snap in benches:
        if args.only and name not in args.only:
            continue
        out = _timed(name, fn, derive)
        if args.snapshot and snap is not None:
            config, metrics, series = snap(out)
            path = snapshots.write_snapshot(name, config, metrics,
                                            series=series,
                                            directory=args.snapshot_dir)
            print(f"# snapshot -> {path}")


if __name__ == "__main__":
    main()
