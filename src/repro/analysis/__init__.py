"""Static contract checker for the repro tree (``repro-lint``).

The paper's headline number — up to 120x activation-memory reduction —
rests on *source-level* invariants that no runtime test can exhaustively
cover: custom_vjp forwards must stash sketched ``(P_hat, Q)`` residuals
rather than dense activations, jit-traced code must stay pure, every
parameter must resolve to a partition rule under every layout, and
Pallas kernels must respect their BlockSpec/grid geometry.  This package
checks those invariants by walking the AST of every file under
``src/repro`` (plus a few importable facts, gathered without touching a
device).

Entry points::

    python -m repro.analysis --format json
    scripts/repro_lint.py --select jit-purity src/repro/runtime

Rules (see DESIGN.md §11 for the catalog):

- ``residual-contract``  dense activations saved as vjp residuals;
  fwd/bwd arity mismatches.
- ``jit-purity``         host effects inside traced code; device syncs in
  runtime loop bodies outside log-step guards.
- ``partition-coverage`` every param path resolves to exactly one rule
  per layout; ``LinearCompressionCfg`` calls declare ``out_axis``
  explicitly with an axis the layouts actually shard.
- ``pallas-contract``    BlockSpec/grid geometry; ``pl.dslice`` strides;
  ``GRAD_SKETCH_MAX_N`` confined to ``shard_local_kernels()`` scopes.
- ``shim-contract``      deprecation shims in ``launch/`` must not import
  the implementation at module top-level.

Suppression: append ``# repro-lint: disable=<rule>[,<rule>...]`` to the
offending line; ``# repro-lint: disable-file=<rule>`` anywhere in a file
silences the rule for the whole file.  Suppressed findings stay visible
in the JSON report with ``"suppressed": true``.
"""
from __future__ import annotations

from repro.analysis.core import (  # noqa: F401
    Finding,
    RULES,
    iter_source_files,
    run_lint,
    render_text,
    render_json,
)

__all__ = ["Finding", "RULES", "iter_source_files", "run_lint",
           "render_text", "render_json"]
