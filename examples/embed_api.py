"""The paper's deployment loop in ~30 lines of user code, via ``repro.api``
only: serve decode traffic, retire requests into the adapter's replay
buffer, adapt under a hard activation-memory budget, swap the new weights
into the live engine — then checkpoint the session.

  PYTHONPATH=src python examples/embed_api.py
"""
import json

from repro.api import Session, demo_requests

sess = Session.from_config("tinyllama_1_1b", reduced=True, compress="asi",
                           kernel_backend="reference", seed=0)

server = sess.server(max_batch=2, max_len=48)              # decode traffic
adapter = sess.adapter(mem_budget_mb=0.05, steps=4,        # paper §3.3 plan
                       batch=2, seq_len=16, adapt_every=2)

print(json.dumps({"budget_ok": adapter.plan_respects_budget,
                  "ranks": adapter.plan.summary()["ranks"]}))

losses = []
for wave in range(2):
    # serve a wave; every retirement streams into the replay buffer
    done = server.run(demo_requests(4, max_new=6, start_uid=4 * wave),
                      on_retire=adapter.observe)
    assert all(r.done for r in done)
    server.swap_params(adapter.step(2))     # adapt, then swap weights live
    losses.extend(adapter.report.adapt_losses[len(losses):])

print(json.dumps({"serving": server.stats_dict(),
                  "adapt_losses": [round(l, 3) for l in losses],
                  "probe_drift": adapter.report.probe_drift}))
ckpt = sess.save("/tmp/embed_api_ckpt")
print(json.dumps({"ckpt": ckpt, "restored_step":
                  Session.load("/tmp/embed_api_ckpt").step}))
