"""Unified decoder-only LM stack covering the dense / MoE / SSM / hybrid
families with one implementation.

Layers are grouped into *periods* (jamba: 8 sublayers = 1 attention + 7 mamba;
everything else: period 1) and the stack scans over stacked period params —
HLO stays small regardless of depth, which is what makes the 72-layer 398B
dry-run compile in minutes on one CPU core.

Paper integration (``cfg.compress == 'asi' | 'hosvd'``): the first
``n_periods - tail`` periods run under ``stop_gradient`` (frozen backbone, no
activations stored — on-device fine-tuning regime); the last ``asi_last_k``
periods are unrolled with ASI-compressed linears whose warm-start factor
states thread through the step as explicit inputs/outputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.asi import MatrixASIState
from repro.kernels import dispatch
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (attn_decode, attn_decode_paged,
                                    attn_forward, attn_init, init_kv_cache,
                                    init_paged_kv_cache, quantize_cache)
from repro.models.layers import (embed_init, mlp_apply, mlp_init, norm_apply,
                                 norm_init, unembed_init)
from repro.parallel.sharding import logical_shard

Array = jax.Array


# --- layer pattern -----------------------------------------------------------

def period_pattern(cfg: ModelConfig) -> list[tuple[str, str | None]]:
    """One period of (mixer, ffn) sublayer specs."""
    if cfg.family in ("dense", "vlm"):
        return [("attn", "dense")]
    if cfg.family == "moe":
        return [("attn", "moe")]
    if cfg.family == "ssm":
        return [("mamba", None)]
    if cfg.family == "hybrid":
        period = cfg.attn_layer_period
        out = []
        for j in range(period):
            mixer = "attn" if j == 0 else "mamba"
            ffn = "moe" if (j % cfg.moe_layer_period == 1) else "dense"
            out.append((mixer, ffn))
        return out
    raise ValueError(cfg.family)


def n_periods(cfg: ModelConfig) -> int:
    plen = len(period_pattern(cfg))
    assert cfg.n_layers % plen == 0, (cfg.n_layers, plen)
    return cfg.n_layers // plen


# --- init ---------------------------------------------------------------------

def _sublayer_init(key: Array, cfg: ModelConfig, spec, dtype) -> dict:
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"norm1": norm_init(cfg, dtype)}
    p["mixer"] = (attn_init(k1, cfg, dtype) if mixer == "attn"
                  else ssm_lib.mamba_init(k1, cfg, dtype))
    if ffn:
        p["norm2"] = norm_init(cfg, dtype)
        p["ffn"] = (mlp_init(k2, cfg, dtype) if ffn == "dense"
                    else moe_lib.moe_init(k2, cfg, dtype))
    return p


def _period_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    specs = period_pattern(cfg)
    keys = jax.random.split(key, len(specs))
    return {f"sub{j}": _sublayer_init(keys[j], cfg, s, dtype)
            for j, s in enumerate(specs)}


def init_params(key: Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_stack, k_out = jax.random.split(key, 3)
    np_ = n_periods(cfg)
    stack = jax.vmap(lambda k: _period_init(k, cfg, dtype))(
        jax.random.split(k_stack, np_))
    params = {
        "embed": embed_init(k_embed, cfg, dtype),
        "stack": stack,
        "final_norm": norm_init(cfg, dtype),
        "unembed": unembed_init(k_out, cfg, dtype),
    }
    return params


# --- sublayer application -------------------------------------------------------

def _sublayer_apply(params: dict, x: Array, cfg: ModelConfig, spec,
                    positions, asi_state: dict | None):
    mixer, ffn = spec
    aux = jnp.float32(0.0)
    new_asi: dict = {}
    h = norm_apply(params["norm1"], x, cfg)
    if mixer == "attn":
        st = asi_state.get("mixer") if asi_state is not None else None
        y, ns, _ = attn_forward(params["mixer"], h, cfg, positions, st)
        if ns is not None:
            new_asi["mixer"] = ns
    else:
        st = asi_state.get("mixer") if asi_state is not None else None
        y, _, ns = ssm_lib.mamba_forward(params["mixer"], h, cfg,
                                         asi_state=st)
        if ns is not None:
            new_asi["mixer"] = ns
    x = x + y  # repro-lint: disable=residual-audit — residual-stream add: kept as the next block's input, the stream itself is not an ASI site
    if ffn:
        h = norm_apply(params["norm2"], x, cfg)
        st = asi_state.get("ffn") if asi_state is not None else None
        if ffn == "dense":
            y, ns = mlp_apply(params["ffn"], h, cfg, st)
        else:
            y, aux, ns = moe_lib.moe_apply(params["ffn"], h, cfg, st)
        if ns is not None:
            new_asi["ffn"] = ns
        x = x + y  # repro-lint: disable=residual-audit — residual-stream add after the ffn; same story as the attention-side add
    # sequence-parallel TP (hillclimb lever): shard the seq dim over the TP
    # axis between blocks; GSPMD turns the per-block all-reduce into
    # reduce-scatter + all-gather (half the wire bytes).  No-op unless the
    # active rules map 'seq_tp' to a mesh axis.
    x = logical_shard(x, "batch", "seq_tp", None)
    return x, aux, (new_asi or None)


def _period_apply(params: dict, x: Array, cfg: ModelConfig, positions,
                  asi_state: dict | None):
    specs = period_pattern(cfg)
    total_aux = jnp.float32(0.0)
    new_asi: dict = {}
    for j, spec in enumerate(specs):
        st = asi_state.get(f"sub{j}") if asi_state is not None else None
        x, aux, ns = _sublayer_apply(params[f"sub{j}"], x, cfg, spec,
                                     positions, st)
        total_aux = total_aux + aux
        if ns is not None:
            new_asi[f"sub{j}"] = ns
    return x, total_aux, (new_asi or None)


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat == "offload":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[], names_which_can_be_offloaded=[],
                offload_src="device", offload_dst="pinned_host"))
    return jax.checkpoint(f)


# --- full forward -----------------------------------------------------------------

def forward(params: dict, tokens: Array, cfg: ModelConfig,
            asi_state: dict | None = None, prefix_embeds: Array | None = None):
    """Training/prefill forward.  Returns (logits, aux_loss, new_asi_state)."""
    # Fail fast on kernel_backend typos at trace time — every ASI-wrapped
    # linear below routes through this flag, and an unknown value must not
    # silently fall back to a different code path mid-training.
    dispatch.resolve(cfg.kernel_backend)
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # repro-lint: disable=residual-audit — embedding gather output: the stream's source value, not a matmul-site activation
    if prefix_embeds is not None:                       # VLM: image patches
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)  # repro-lint: disable=residual-audit — vlm prefix concat rides the stream like the embed gather above
    B, S, _ = x.shape
    x = logical_shard(x, "batch", None, "embed")
    positions = jnp.arange(S)[None, :]
    np_ = n_periods(cfg)
    tail = min(cfg.asi_last_k, np_) if cfg.compress != "none" else 0

    total_aux = jnp.float32(0.0)
    new_asi: dict = {}

    def scan_body(carry, pparams):
        x, aux = carry
        x, a, _ = _period_apply(pparams, x, cfg, positions, None)
        return (x, aux + a), None

    body = _remat(scan_body, cfg)

    unroll = np_ if cfg.scan_unroll else 1
    if tail == 0:
        (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), params["stack"],
                                         unroll=unroll)
    else:
        n_prefix = np_ - tail
        if n_prefix > 0:
            prefix = jax.tree.map(lambda a: a[:n_prefix], params["stack"])
            # frozen backbone: no grads flow, no activations stored
            (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), prefix,
                                             unroll=n_prefix if cfg.scan_unroll else 1)
            x = jax.lax.stop_gradient(x)
            total_aux = jax.lax.stop_gradient(total_aux)
        for i in range(n_prefix, np_):
            pparams = jax.tree.map(lambda a: a[i], params["stack"])
            st = asi_state.get(f"period_{i}") if asi_state else None
            x, a, ns = _period_apply(pparams, x, cfg, positions, st)
            total_aux = total_aux + a
            if ns is not None:
                new_asi[f"period_{i}"] = ns

    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["unembed"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = logical_shard(logits, "batch", None, "vocab")
    return logits, total_aux, (new_asi or None)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            asi_state: dict | None = None):
    """Next-token cross-entropy.  batch: {'tokens','targets'} (+ 'embeds')."""
    # anchor the batch on the data axes even when the caller did not
    # device_put it (no-op outside an axis_rules context)
    batch = {k: logical_shard(v, "batch", *([None] * (v.ndim - 1)))
             for k, v in batch.items()}
    logits, aux, new_asi = forward(params, batch["tokens"], cfg, asi_state,
                                   batch.get("embeds"))
    targets = batch["targets"]
    if batch.get("embeds") is not None:                 # drop image positions
        logits = logits[:, -targets.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)  # repro-lint: disable=residual-audit — softmax-CE vjp keeps exp(logits - lse); the loss head is outside ASI's sites
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    metrics = {"ce": ce, "aux": aux}
    return ce + aux, (metrics, new_asi)


# --- ASI state construction ------------------------------------------------------

def init_asi_state(key: Array, cfg: ModelConfig,
                   rank_plan: dict | None = None) -> dict:
    """Warm-start factors for the fine-tuned tail (cfg.asi_last_k periods).

    ``rank_plan`` maps site paths (``period_{i}/sub{j}/mixer/wq``,
    ``period_{i}/sub{j}/ffn/gate``, ...) to per-site ranks; unlisted sites
    fall back to ``cfg.asi_rank``.  Since ``asi_linear``'s compute rank is
    the state's column count, this is the whole mechanism by which the
    on-device planner's budget choices reach the training step.
    """
    if cfg.compress == "none":
        return {}
    plan = rank_plan or {}
    np_ = n_periods(cfg)
    tail = min(cfg.asi_last_k, np_)
    specs = period_pattern(cfg)
    d, hd, h = cfg.d_model, cfg.hd, cfg.n_heads
    out = {}
    for i in range(np_ - tail, np_):
        key, sub = jax.random.split(key)
        period_state: dict = {}
        for j, (mixer, ffn) in enumerate(specs):
            sub, *ks = jax.random.split(sub, 8)
            at = f"period_{i}/sub{j}"
            r = lambda site: plan.get(f"{at}/{site}", cfg.asi_rank)
            st: dict = {}
            if mixer == "attn":
                st["mixer"] = {
                    "wq": MatrixASIState.init(ks[0], d, r("mixer/wq")),
                    "wk": MatrixASIState.init(ks[1], d, r("mixer/wk")),
                    "wv": MatrixASIState.init(ks[2], d, r("mixer/wv")),
                    "wo": MatrixASIState.init(ks[3], h * hd, r("mixer/wo")),
                }
            else:       # mamba: compress the in/out projections
                st["mixer"] = {
                    "in_proj": MatrixASIState.init(ks[0], d,
                                                   r("mixer/in_proj")),
                    "out_proj": MatrixASIState.init(
                        ks[1], cfg.ssm_d_inner, r("mixer/out_proj")),
                }
            if ffn == "dense":
                st["ffn"] = {
                    "gate": MatrixASIState.init(ks[4], d, r("ffn/gate")),
                    "up": MatrixASIState.init(ks[5], d, r("ffn/up")),
                    "down": MatrixASIState.init(ks[6], cfg.d_ff,
                                                r("ffn/down")),
                } if cfg.act == "silu" else {
                    "up": MatrixASIState.init(ks[5], d, r("ffn/up")),
                    "down": MatrixASIState.init(ks[6], cfg.d_ff,
                                                r("ffn/down")),
                }
            elif ffn == "moe":
                st["ffn"] = moe_lib.moe_asi_state_init(
                    ks[4], cfg, 0,
                    ranks={n: r(f"ffn/{n}") for n in ("gate", "up", "down")})
            if st:
                period_state[f"sub{j}"] = st
        out[f"period_{i}"] = period_state
    return out


def trainable_mask(params: dict, cfg: ModelConfig):
    """True where the optimizer should update (fine-tune tail only in
    compressed mode; everything in full-training mode)."""
    if cfg.compress == "none":
        return jax.tree.map(lambda _: True, params)
    np_ = n_periods(cfg)
    tail = min(cfg.asi_last_k, np_)

    def mask_stack(a):
        m = jnp.zeros((np_,), bool).at[np_ - tail:].set(True)
        return jnp.broadcast_to(m.reshape((np_,) + (1,) * (a.ndim - 1)), a.shape)

    return {
        "embed": False,
        "stack": jax.tree.map(mask_stack, params["stack"]),
        "final_norm": jax.tree.map(lambda _: True, params["final_norm"]),
        "unembed": True,
    }


# --- decode -----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    specs = period_pattern(cfg)
    np_ = n_periods(cfg)
    one = {}
    for j, (mixer, _) in enumerate(specs):
        if mixer == "attn":
            one[f"sub{j}"] = init_kv_cache(cfg, batch, max_len, dtype)
        else:
            one[f"sub{j}"] = ssm_lib.init_mamba_state(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((np_,) + a.shape, a.dtype), one)


def init_paged_cache(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int) -> dict:
    """Like ``init_cache`` but attention sublayers get a shared block pool
    (``n_blocks`` physical blocks, block 0 = trash) instead of dense
    per-slot rows.  SSM/conv states stay per-slot — they are O(1) in
    sequence length, so there is nothing to page."""
    dtype = jnp.dtype(cfg.dtype)
    specs = period_pattern(cfg)
    np_ = n_periods(cfg)
    one = {}
    for j, (mixer, _) in enumerate(specs):
        if mixer == "attn":
            one[f"sub{j}"] = init_paged_kv_cache(cfg, n_blocks, block_size,
                                                 dtype)
        else:
            one[f"sub{j}"] = ssm_lib.init_mamba_state(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((np_,) + a.shape, a.dtype), one)


def write_paged_slot(cfg: ModelConfig, cache: dict, one: dict,
                     table_row: Array, slot) -> dict:
    """Install a batch-1 prefill cache into the paged shared cache: attention
    K/V rows scatter into the physical blocks named by ``table_row`` (the
    slot's block-table row, (L,) int32); SSM states write per-slot as in the
    dense engine.  Unallocated table entries point at the trash block, so
    their writes land there harmlessly."""
    specs = period_pattern(cfg)
    L = table_row.shape[0]
    new = {}
    for j, (mixer, _) in enumerate(specs):
        sub = f"sub{j}"
        if mixer == "attn":
            def put(pool, leaf):
                np_, _, s = leaf.shape[:3]
                r = leaf.reshape((np_, L, s // L) + leaf.shape[3:])
                return pool.at[:, table_row].set(r.astype(pool.dtype))
            new[sub] = jax.tree.map(put, cache[sub], one[sub])
        else:
            new[sub] = jax.tree.map(
                lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                    c, o.astype(c.dtype), slot, axis=1), cache[sub], one[sub])
    return new


def _sublayer_decode(params, x, cache, pos, cfg, spec, table=None):
    mixer, ffn = spec
    h = norm_apply(params["norm1"], x, cfg)
    if mixer == "attn":
        if table is None:
            y, new_cache = attn_decode(params["mixer"], h, cache, pos, cfg)
        else:
            y, new_cache = attn_decode_paged(params["mixer"], h, cache,
                                             table, pos, cfg)
    else:
        y, new_cache = ssm_lib.mamba_decode(params["mixer"], h, cache, cfg)
    x = x + y
    if ffn:
        h = norm_apply(params["norm2"], x, cfg)
        if ffn == "dense":
            y, _ = mlp_apply(params["ffn"], h, cfg)
        else:
            y, _, _ = moe_lib.moe_apply(params["ffn"], h, cfg)
        x = x + y
    return x, new_cache


def _decode_x(params: dict, cache: dict, x: Array, pos: Array,
              cfg: ModelConfig, table: Array | None = None):
    """Shared one-step decode body over an embedded input x (B, 1, d)."""
    specs = period_pattern(cfg)

    def period_fn(x, xs):
        pparams, pcache = xs
        new_pc = {}
        for j, spec in enumerate(specs):
            x, nc = _sublayer_decode(pparams[f"sub{j}"], x, pcache[f"sub{j}"],
                                     pos, cfg, spec, table)
            new_pc[f"sub{j}"] = nc
        return x, new_pc

    x, new_cache = jax.lax.scan(period_fn, x, (params["stack"], cache),
                                unroll=n_periods(cfg) if cfg.scan_unroll else 1)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = logical_shard(logits, "batch", None, "vocab")
    return logits[:, 0], new_cache


def embed_tokens(params: dict, token: Array, cfg: ModelConfig) -> Array:
    """token (B,) int32 -> (B, d) embeddings (the decode-step input)."""
    return params["embed"].astype(jnp.dtype(cfg.dtype))[token]


def decode_step(params: dict, cache: dict, token: Array, pos: Array,
                cfg: ModelConfig):
    """One decode step.  token (B,) int32; pos scalar or (B,) per-slot
    positions (continuous batching).  Returns (logits, cache)."""
    x = embed_tokens(params, token, cfg)[:, None]                   # (B,1,d)
    return _decode_x(params, cache, x, pos, cfg)


def decode_step_embed(params: dict, cache: dict, x: Array, pos: Array,
                      cfg: ModelConfig):
    """Decode step over a pre-embedded input x (B, d) — lets chunked prefill
    feed VLM image-patch embeddings and token embeddings through one body."""
    return _decode_x(params, cache, x[:, None], pos, cfg)


def decode_step_paged(params: dict, cache: dict, table: Array, token: Array,
                      pos: Array, cfg: ModelConfig):
    """Decode step against a block-paged cache (``init_paged_cache``);
    table (B, L) int32 maps each slot's logical blocks to pool blocks."""
    x = embed_tokens(params, token, cfg)[:, None]
    return _decode_x(params, cache, x, pos, cfg, table)


def prefill(params: dict, tokens: Array, cfg: ModelConfig, max_len: int,
            prefix_embeds: Array | None = None):
    """Run the prompt through the stack, returning (last_logits, cache).

    Reuses the training forward for activations and projects K/V per layer
    (exact, cache-capacity ``max_len``; SWA archs keep a ring of window size).
    """
    B, S = tokens.shape[0], tokens.shape[1]
    if prefix_embeds is not None:
        S = S + prefix_embeds.shape[1]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(S)[None, :]
    specs = period_pattern(cfg)
    s_cache = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len

    def period_fn(x, pparams):
        new_pc = {}
        for j, (mixer, ffn) in enumerate(specs):
            sp = pparams[f"sub{j}"]
            h = norm_apply(sp["norm1"], x, cfg)
            if mixer == "attn":
                y, _, (k, v) = attn_forward(sp["mixer"], h, cfg, positions)
                ck = jnp.zeros((B, s_cache) + k.shape[2:], k.dtype)
                n = min(S, s_cache)
                ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, -n:], 0, 1)
                cv = jnp.zeros((B, s_cache) + v.shape[2:], v.dtype)
                cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, -n:], 0, 1)
                if cfg.sliding_window and S > s_cache:
                    # ring alignment: token at position p lives in slot p % cache
                    ck = jnp.roll(ck, S % s_cache, axis=1)
                    cv = jnp.roll(cv, S % s_cache, axis=1)
                if cfg.kv_cache_dtype == "int8":
                    new_pc[f"sub{j}"] = quantize_cache({"k": ck, "v": cv})
                else:
                    new_pc[f"sub{j}"] = {"k": ck, "v": cv}
            else:
                y, st, _ = ssm_lib.mamba_forward(sp["mixer"], h, cfg)
                new_pc[f"sub{j}"] = st
            x = x + y
            if ffn:
                h = norm_apply(sp["norm2"], x, cfg)
                if ffn == "dense":
                    y, _ = mlp_apply(sp["ffn"], h, cfg)
                else:
                    y, _, _ = moe_lib.moe_apply(sp["ffn"], h, cfg)
                x = x + y
            x = logical_shard(x, "batch", "seq_tp", None)
        return x, new_pc

    x, caches = jax.lax.scan(period_fn, x, params["stack"],
                             unroll=n_periods(cfg) if cfg.scan_unroll else 1)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = (x[:, -1] @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, caches
