"""A domain-shift continual-learning scenario in ~20 lines of user code:
stream Poisson decode traffic from a Markov chain, swap the transition table
mid-stream, adapt under a hard activation-memory budget, and read off the
forgetting curves — via ``repro.scenarios`` / ``repro.api`` only.

  PYTHONPATH=src python examples/scenario_domain_shift.py
"""
import json

from repro.scenarios import run_scenario

report = run_scenario(scenario="domain-shift", arch="tinyllama_1_1b",
                      reduced=True, seed=0, mem_budget_mb=0.05,
                      waves_per_phase=2, rate=3.0, steps=16,
                      replay_policy="stratified")

# one frozen probe per seen phase, re-measured after every burst
print(json.dumps({"probe_curves": report.probe_curves,
                  "burst_phase": report.burst_phase}))

# recovery: did quality on the *new* domain improve after the shift?
# forgetting: how far did the *old* domain's probe drift from its best?
print(json.dumps({"summary": report.summary()}))

# the full deterministic series (re-run with the same seed -> identical)
assert report.curves() == run_scenario(
    scenario="domain-shift", arch="tinyllama_1_1b", reduced=True, seed=0,
    mem_budget_mb=0.05, waves_per_phase=2, rate=3.0, steps=16,
    replay_policy="stratified").curves()
print(json.dumps({"bit_reproducible": True}))
