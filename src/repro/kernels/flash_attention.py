"""Flash (online-softmax) attention Pallas TPU kernel.

Standard tiled formulation: grid (batch*heads, q-blocks, kv-blocks) with the
kv dimension sequential; running max/denominator/accumulator live in VMEM
scratch across kv steps.  Supports causal masking and sliding windows (SWA),
with fully-masked kv blocks skipped (no MXU work issued for them) — on TPU
this recovers the ~2x causal-compute saving block-granularly.

The pure-jnp reference with identical blocking lives in
``repro.models.attention.chunked_attention``; the naive oracle in
``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, causal: bool, window: int,
            q_offset: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # block-level skip: entire kv block out of the causal/window range?
    q_lo = q_offset + qi * bq
    q_hi = q_lo + bq - 1
    k_lo = kj * bk
    k_hi = k_lo + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_offset", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, bq: int = 512, bk: int = 512,
                    q_offset: int = 0, interpret: bool = False) -> Array:
    """q (BH, Sq, d); k/v (BH, Skv, d).  Sq/Skv must divide by the blocks
    (the ops wrapper picks valid blocks).  Positions are right-aligned:
    q[i] sits at absolute position q_offset + i (q_offset defaults to 0;
    pass Skv - Sq for cached decode prefill continuation)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, q_offset=q_offset, scale=scale),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
