"""Substrate tests: optimizers, schedules, checkpointing, data determinism,
fault-tolerant train loop, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.registry import get_config
from repro.data.synthetic import (ImageStream, ImageStreamCfg, LMStream,
                                  LMStreamCfg)
from repro.models import build_model
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    global_norm, make_optimizer, sgdm)
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import (SimulatedFailure, TrainLoopCfg,
                                      make_train_step, run)
from repro.runtime.serve_loop import Engine, Request, ServeCfg

KEY = jax.random.PRNGKey(0)


# --- optimizers ----------------------------------------------------------------

def _quadratic_converges(opt, steps=200):
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for t in range(steps):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, jnp.int32(t))
    return float(jnp.abs(params["w"]).max())


@pytest.mark.parametrize("name,kw", [
    ("sgdm", {}), ("adamw", {}), ("adafactor", {}),
])
def test_optimizer_converges_quadratic(name, kw):
    opt = make_optimizer(name, lambda s: 0.05, **kw)
    assert _quadratic_converges(opt) < 0.05


def test_mask_freezes_params_and_no_decay_leak():
    opt = adamw(lambda s: 0.1, weight_decay=0.1)
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    state = opt.init(params)
    grads = {"a": jnp.ones(3), "b": jnp.ones(3)}
    mask = {"a": True, "b": False}
    new, _ = opt.update(grads, state, params, jnp.int32(0), mask)
    assert bool(jnp.all(new["b"] == 1.0))          # frozen: no update, no decay
    assert bool(jnp.all(new["a"] != 1.0))


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 0.01)
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((32,))}
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (32,)
    # factored state is ~ (64+32)/(64*32) of adam's
    n_fact = sum(x.size for x in jax.tree.leaves(st))
    n_adam = 2 * sum(x.size for x in jax.tree.leaves(params))
    assert n_fact < 0.1 * n_adam


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_warmup_cosine_shape():
    sch = warmup_cosine(1.0, 10, 100)
    assert float(sch(0)) == 0.0
    assert abs(float(sch(10)) - 1.0) < 1e-6
    assert float(sch(100)) < 1e-6
    assert float(sch(55)) < float(sch(20))


# --- checkpointing ----------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    with tempfile.TemporaryDirectory() as d:
        for step in (10, 20, 30, 40):
            checkpointer.save(d, step, tree, keep=2)
        assert checkpointer.latest_step(d) == 40
        kept = sorted(os.listdir(d))
        assert kept == ["step_00000030", "step_00000040"]
        restored, step, meta = checkpointer.restore(d, tree)
        assert step == 40
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.zeros((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 1, tree)
        with pytest.raises(ValueError):
            checkpointer.restore(d, {"a": jnp.zeros((3, 3))})


def test_checkpoint_atomicity_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 5, {"x": jnp.ones(3)})
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_checkpoint_stale_tmp_swept_after_simulated_crash():
    """A hard crash between mkdtemp and os.rename leaves an orphan .tmp_*
    dir (the in-save handler never runs); the next save must sweep it —
    but only once it is old enough to not be a concurrent writer's."""
    import time as _time
    with tempfile.TemporaryDirectory() as d:
        tree = {"x": jnp.ones(3)}
        checkpointer.save(d, 1, tree)
        # simulate the post-crash state: partially written, *old* tmp dirs
        old = _time.time() - 2 * checkpointer.STALE_TMP_TTL_S
        for n in ("a", "b"):
            crashed = os.path.join(d, f".tmp_crashed_{n}")
            os.makedirs(crashed)
            shard = os.path.join(crashed, "shard_0.npz")
            with open(shard, "wb") as f:
                f.write(b"partial")
            os.utime(shard, (old, old))
            os.utime(crashed, (old, old))
        # a fresh tmp dir (concurrent writer mid-save) must survive
        live = os.path.join(d, ".tmp_live")
        os.makedirs(live)
        checkpointer.save(d, 2, tree)
        left = [f for f in os.listdir(d) if f.startswith(".tmp")]
        assert left == [".tmp_live"]
        # the swept dirs must not have corrupted real checkpoints
        assert checkpointer.latest_step(d) == 2
        restored, step, _ = checkpointer.restore(d, tree)
        assert step == 2


def test_windowed_median_matches_sorted_and_evicts():
    from repro.runtime.train_loop import WindowedMedian
    import random
    rng = random.Random(0)
    wm = WindowedMedian(window=16)
    vals = []
    for _ in range(100):
        v = rng.random()
        wm.push(v)
        vals.append(v)
        window = vals[-16:]
        assert wm.median() == sorted(window)[len(window) // 2]
    assert len(wm) == 16


# --- data -------------------------------------------------------------------------

def test_lm_stream_deterministic_and_host_sharded():
    cfg = LMStreamCfg(vocab_size=64, seq_len=16, global_batch=8)
    a = LMStream(cfg, host_id=0, n_hosts=2)
    b = LMStream(cfg, host_id=1, n_hosts=2)
    x1, x2 = a.batch(3), a.batch(3)
    np.testing.assert_array_equal(np.asarray(x1["tokens"]),
                                  np.asarray(x2["tokens"]))   # pure in step
    y = b.batch(3)
    assert not np.array_equal(np.asarray(x1["tokens"]),
                              np.asarray(y["tokens"]))        # host disjoint
    assert x1["tokens"].shape == (4, 16)
    # targets are next-token shifted
    np.testing.assert_array_equal(np.asarray(x1["tokens"][:, 1:]),
                                  np.asarray(x1["targets"][:, :-1]))


def test_image_stream_learnable_structure():
    cfg = ImageStreamCfg(num_classes=4, hw=8, global_batch=16, noise=0.1)
    s = ImageStream(cfg)
    b = s.batch(0)
    assert b["images"].shape == (16, 3, 8, 8)
    # images of the same class are closer than different classes
    img, lab = np.asarray(b["images"]), np.asarray(b["labels"])
    same, diff = [], []
    for i in range(8):
        for j in range(i + 1, 8):
            d = np.linalg.norm(img[i] - img[j])
            (same if lab[i] == lab[j] else diff).append(d)
    if same and diff:
        assert np.mean(same) < np.mean(diff)


# --- train loop ---------------------------------------------------------------------

def test_train_loop_restart_resumes_from_checkpoint():
    cfg = get_config("tinyllama-1.1b").reduced().replace(n_layers=2)
    api = build_model(cfg)
    params = api.init(KEY)
    opt = make_optimizer("adamw", warmup_cosine(3e-3, 2, 40), clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              donate=False)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=4, branching=2))
    with tempfile.TemporaryDirectory() as d:
        res = run(step_fn, params, opt_state, {}, data,
                  TrainLoopCfg(total_steps=40, ckpt_dir=d, ckpt_every=10,
                               log_every=10, fail_at_step=25))
        assert res.restarts == 1
        assert res.step == 40
        assert res.history[-1]["loss"] < res.history[0]["loss"]


def test_train_loop_gives_up_after_max_restarts():
    class AlwaysFails:
        def batch(self, step):
            raise SimulatedFailure("boom")
    step_fn = lambda *a: a                     # never reached
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(SimulatedFailure):
            run(step_fn, {}, {}, {}, AlwaysFails(),
                TrainLoopCfg(total_steps=5, ckpt_dir=d, max_restarts=2,
                             fail_at_step=-1))


# --- serving -----------------------------------------------------------------------

def test_engine_greedy_decode_matches_manual():
    cfg = get_config("tinyllama-1.1b").reduced().replace(n_layers=2)
    api = build_model(cfg)
    params = api.init(KEY)
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32))
    reqs = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4)]
    done = eng.run(reqs)
    assert len(done[0].out) == 4
    # manual single-slot reference
    cache = api.init_cache(2, 32)
    toks = [1, 2, 3]
    logits = None
    for pos, t in enumerate(toks):
        vec = jnp.array([t, 0], jnp.int32)
        logits, cache = api.decode_step(params, cache, vec, jnp.int32(pos))
    first = int(jnp.argmax(logits[0]))
    assert done[0].out[0] == first
