"""Beyond-paper §Perf lever: ASI/PowerSGD-compressed DP gradient all-reduce.

Lowers two shard_map'd gradient syncs over a data axis and parses the
collective bytes out of the compiled per-device HLO:

  dense      — pmean of every gradient leaf (the standard DP step)
  compressed — rank-r subspace-iteration factors all-reduced instead
               (repro/parallel/collectives.py), small leaves stay dense

Reported: per-device collective bytes and the wire-compression ratio for a
TinyLlama-1.1B-shaped gradient set.  Correctness of the compressed sync is
covered by tests/test_distributed.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.launch.roofline import collective_bytes
from repro.parallel import collectives as C

RANK = 8


def _grad_set(cfg):
    d, ff, hd, h, kv = (cfg.d_model, cfg.d_ff, cfg.hd, cfg.n_heads,
                        cfg.n_kv_heads)
    shapes = {
        "wq": (d, h * hd), "wk": (d, kv * hd), "wv": (d, kv * hd),
        "wo": (h * hd, d), "gate": (d, ff), "up": (d, ff), "down": (ff, d),
    }
    return {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}


def lower_both(n_workers: int = 8):
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_mesh
    cfg = get_config("tinyllama-1.1b")
    grads = _grad_set(cfg)
    mesh = make_mesh((n_workers,), ("data",))
    states = C.init_states_for(grads, jax.random.PRNGKey(0), RANK)
    # per-worker distinct gradients (leading worker dim) so XLA cannot fold
    # the all-reduce of a replicated value away
    stacked = jax.tree.map(
        lambda g: jnp.zeros((n_workers,) + g.shape, g.dtype), grads)

    def dense(g):
        return jax.tree.map(lambda x: C.dense_psum(x[0], "data"), g)

    def compressed(g, st):
        local = jax.tree.map(lambda x: x[0], g)
        out, _ = C.compressed_psum_tree(local, st, "data")
        return out

    d_hlo = jax.jit(shard_map(
        dense, mesh=mesh, in_specs=(P("data"),),
        out_specs=P())).lower(stacked).compile().as_text()
    c_hlo = jax.jit(shard_map(
        compressed, mesh=mesh,
        in_specs=(P("data"), P()), out_specs=P())).lower(
            stacked, states).compile().as_text()
    return collective_bytes(d_hlo), collective_bytes(c_hlo), grads


def run(verbose=True):
    dense, comp, grads = lower_both()
    analytic_dense = sum(C.wire_bytes_dense(g.shape)
                         for g in jax.tree.leaves(grads))
    analytic_comp = sum(C.wire_bytes_compressed(g.shape, RANK)
                        for g in jax.tree.leaves(grads))
    out = {
        "dense_hlo_bytes": dense.total_bytes,
        "compressed_hlo_bytes": comp.total_bytes,
        "hlo_ratio": dense.total_bytes / max(comp.total_bytes, 1),
        "analytic_ratio": analytic_dense / analytic_comp,
    }
    if verbose:
        print(f"dense sync:      {dense.total_bytes/1e6:8.1f} MB on the wire "
              f"({dense.by_kind})")
        print(f"compressed sync: {comp.total_bytes/1e6:8.1f} MB on the wire "
              f"({comp.by_kind})")
        print(f"wire reduction:  {out['hlo_ratio']:.1f}x (analytic "
              f"{out['analytic_ratio']:.1f}x at rank {RANK})")
    assert out["hlo_ratio"] > 10
    return out


if __name__ == "__main__":
    run()
