"""Cross-checks of the analytic FLOPs/HBM models against XLA cost analysis
on small UNROLLED configs (where HloCostAnalysis is trustworthy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import get_config
from repro.launch import flops_model
from repro.models import build_model


def _cost_flops(cfg, b, s):
    api = build_model(cfg)
    params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}

    def fwd_loss(p, bt):
        return api.loss(p, bt)[0]

    compiled = jax.jit(fwd_loss).lower(params, batch).compile()
    c = flops_model.cost_analysis_dict(compiled)
    return float(c.get("flops", 0.0))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-moe-3b-a800m",
                                  "mamba2-130m"])
def test_forward_flops_matches_xla_within_2x(arch):
    """Analytic forward-FLOPs within [0.5x, 2x] of XLA's count on a small
    unrolled config (XLA counts transcendentals/elementwise that we skip;
    we count masked attention blocks it may fold)."""
    cfg = get_config(arch).reduced().replace(scan_unroll=True, remat="none",
                                             attn_chunk=64)
    b, s = 2, 64
    xla = _cost_flops(cfg, b, s)
    ours = flops_model.forward_flops(cfg, b, s)
    assert xla > 0
    ratio = ours / xla
    assert 0.4 < ratio < 2.5, (arch, ours, xla, ratio)


def test_train_cell_flops_exceed_forward():
    cfg = get_config("tinyllama-1.1b")
    shape = ShapeCfg("train_4k", 4096, 256, "train")
    fwd = flops_model.forward_flops(cfg, 256, 4096)
    total = flops_model.cell_flops(cfg, shape, "none")
    assert total > 2.5 * fwd            # fwd + remat + bwd


def test_asi_train_cheaper_than_vanilla_train():
    """The paper's headline: fine-tuning with ASI costs fewer FLOPs than
    vanilla fine-tuning of the same tail (and far less than full training)."""
    cfg = get_config("tinyllama-1.1b").replace(asi_last_k=2)
    shape = ShapeCfg("train_4k", 4096, 256, "train")
    asi = flops_model.cell_flops(cfg.replace(compress="asi"), shape, "asi")
    vanilla = flops_model.cell_flops(cfg, shape, "none")
    assert asi < 0.6 * vanilla


def test_decode_flops_scale_with_cache():
    cfg = get_config("internlm2-20b")
    d32 = flops_model.cell_flops(cfg, ShapeCfg("d", 32768, 128, "decode"))
    d8 = flops_model.cell_flops(cfg, ShapeCfg("d", 8192, 128, "decode"))
    assert d32 > d8                      # attention term grows with cache
    assert d32 < 4 * d8                  # but projections dominate


def test_swa_decode_cheaper_than_full():
    cfg = get_config("h2o-danube-3-4b")
    swa = flops_model.cell_flops(cfg, ShapeCfg("d", 524288, 1, "decode"))
    full = flops_model.cell_flops(cfg.replace(sliding_window=0),
                                  ShapeCfg("d", 524288, 1, "decode"))
    assert swa < 0.5 * full


def test_hbm_model_orders():
    """Decode must be far more memory-bound than compute-bound (weights are
    read once per generated token)."""
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    cfg = get_config("internlm2-20b")
    shape = ShapeCfg("decode_32k", 32768, 128, "decode")
    fl = flops_model.cell_flops(cfg, shape)
    by = flops_model.cell_hbm_bytes(cfg, shape)
    assert (by / HBM_BW) > 3 * (fl / PEAK_FLOPS)
    # training flips: compute term within 100x of memory term
    shape_t = ShapeCfg("train_4k", 4096, 256, "train")
    fl_t = flops_model.cell_flops(cfg, shape_t)
    by_t = flops_model.cell_hbm_bytes(cfg, shape_t)
    assert (fl_t / PEAK_FLOPS) > 0.5 * (by_t / HBM_BW)


def test_encdec_and_vlm_supported():
    for arch in ("whisper-medium", "internvl2-1b"):
        cfg = get_config(arch)
        shape = ShapeCfg("train_4k", 4096, 256, "train")
        assert flops_model.cell_flops(cfg, shape) > 0
        assert flops_model.cell_hbm_bytes(cfg, shape) > 0
