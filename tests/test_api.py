"""The embeddable API: CLI <-> API parity, the Session lifecycle, the shared
arch resolver, the ``eval_shape``-safe init hook, and the deprecation shims.

Parity contract (DESIGN.md §9): each launcher's ``main()`` is a thin
argparse shim over ``repro.api`` — running it must produce exactly the same
metrics/stats/report as making the equivalent API calls yourself.
"""
import json
import warnings

import jax
import pytest

from repro.api import Session, demo_requests, parse_mesh, resolve_arch
from repro.api import analyze as api_analyze
from repro.checkpoint import checkpointer
from repro.configs.registry import get_config
from repro.launch import adapt as adapt_cli
from repro.launch import dryrun as dryrun_cli
from repro.launch import serve as serve_cli
from repro.launch import train as train_cli
from repro.models import build_model

ARCH = "tinyllama-1.1b"


def _main(mod, argv):
    """Run a launcher main() with the programmatic-use warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return mod.main(argv)


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all((x == y).all() for x, y in zip(la, lb))


# --------------------------------------------------------------------------
# shared resolver (satellite: one normalization for all four CLIs)
# --------------------------------------------------------------------------

def test_resolve_arch_spellings():
    assert resolve_arch("tinyllama_1_1b") == "tinyllama-1.1b"
    assert resolve_arch("phi3_mini_3_8b") == "phi3-mini-3.8b"
    assert resolve_arch("phi3-mini-3.8b") == "phi3-mini-3.8b"
    assert resolve_arch("nonexistent") == "nonexistent"  # caller owns error


@pytest.mark.parametrize("mod", [serve_cli, train_cli, adapt_cli, dryrun_cli],
                         ids=["serve", "train", "adapt", "dryrun"])
def test_every_cli_accepts_underscore_and_config_alias(mod):
    extra = (["--mem-budget-mb", "1"] if mod is adapt_cli else [])
    ap = mod.build_parser()
    assert ap.parse_args(["--config", "tinyllama_1_1b"] + extra).arch == ARCH
    assert ap.parse_args(["--arch", ARCH] + extra).arch == ARCH


def test_from_config_rejects_unknown_arch():
    with pytest.raises(ValueError, match="unknown arch"):
        Session.from_config("nonexistent")


def test_parse_mesh():
    assert parse_mesh("2,4") == (2, 4)
    assert parse_mesh(None) is None
    assert parse_mesh((1, 2)) == (1, 2)
    with pytest.raises(ValueError, match="two comma-separated"):
        parse_mesh("2,4,8")


# --------------------------------------------------------------------------
# eval_shape-safe init hook (satellite: dryrun no longer rebuilds the model)
# --------------------------------------------------------------------------

def test_model_api_init_struct_matches_real_init():
    api = build_model(get_config(ARCH).reduced())
    struct = api.init_struct()
    real = api.init(jax.random.PRNGKey(0))
    fs = jax.tree_util.tree_flatten_with_path(struct)
    fr = jax.tree_util.tree_flatten_with_path(real)
    assert fs[1] == fr[1]                       # same treedef
    for (ps, ls), (pr, lr) in zip(fs[0], fr[0]):
        assert ps == pr and ls.shape == lr.shape and ls.dtype == lr.dtype
        assert isinstance(ls, jax.ShapeDtypeStruct)   # never materialized


# --------------------------------------------------------------------------
# CLI <-> API parity
# --------------------------------------------------------------------------

def test_serve_cli_api_parity(capsys):
    done_cli = _main(serve_cli, ["--config", "tinyllama_1_1b",
                                 "--requests", "3", "--max-new", "4",
                                 "--max-batch", "2", "--max-len", "32"])
    stats_cli = json.loads(capsys.readouterr().out.splitlines()[-1])

    sess = Session.from_config(ARCH, reduced=True, seed=0)
    server = sess.server(max_batch=2, max_len=32)
    done_api = server.run(demo_requests(3, 4))

    assert {r.uid: r.out for r in done_api} == {r.uid: r.out for r in done_cli}
    sd = server.stats_dict()
    for k in ("engine", "requests", "generated_tokens", "decode_steps"):
        assert sd[k] == stats_cli[k], k


def test_train_cli_api_parity(tmp_path, capsys):
    _main(train_cli, ["--arch", "tinyllama_1_1b", "--reduced",
                      "--steps", "4", "--seq-len", "16", "--batch", "4",
                      "--compress", "asi", "--kernel-backend", "reference",
                      "--ckpt-dir", str(tmp_path / "cli")])
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    cli_summary = lines[-1]
    cli_logs = [l for l in lines if "step" in l]

    sess = Session.from_config(ARCH, reduced=True, seed=0, compress="asi",
                               kernel_backend="reference")
    trainer = sess.trainer(steps=4, seq_len=16, batch=4,
                           ckpt_dir=str(tmp_path / "api"))
    res = trainer.fit()

    assert trainer.summary(res) == cli_summary
    api_logs = [{"step": h["step"],
                 **{k: round(v, 4) for k, v in h.items() if k != "step"}}
                for h in res.history]
    assert api_logs == cli_logs
    assert sess.step == 4                      # state flowed back


def test_train_cli_flag_validation():
    for argv, msg in [
            (["--arch", ARCH, "--grad-accum", "0"], "must be >= 1"),
            (["--arch", ARCH, "--batch", "3", "--grad-accum", "2"],
             "must divide by"),
            (["--arch", ARCH, "--mesh", "2,4"], "requires --layout")]:
        with pytest.raises(SystemExit):        # argparse .error() exit 2
            _main(train_cli, argv)


def test_adapt_cli_api_parity(tmp_path, capsys):
    common = dict(mem_budget_mb=0.05, steps=4, adapt_every=2, batch=2,
                  seq_len=16)
    report_cli = _main(adapt_cli, [
        "--config", "tinyllama_1_1b", "--reduced", "--mem-budget-mb", "0.05",
        "--steps", "4", "--adapt-every", "2", "--batch", "2",
        "--seq-len", "16", "--requests", "4", "--max-new", "4",
        "--kernel-backend", "reference", "--ckpt-dir", str(tmp_path / "cli")])
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    plan_line = next(l for l in lines if "plan" in l)

    sess = Session.from_config(ARCH, reduced=True, seed=0, compress="asi",
                               kernel_backend="reference")
    adapter = sess.adapter(**common)
    assert adapter.plan_report() == plan_line
    report_api = adapter.run(demo_requests(4, 4))

    assert report_api.adapt_losses == report_cli.adapt_losses
    assert report_api.probe_losses == report_cli.probe_losses
    s_api, s_cli = report_api.summary(), report_cli.summary()
    for k in ("retired", "bursts", "adapt_steps", "adapt_loss_first",
              "adapt_loss_last", "probe_drift"):
        assert s_api[k] == s_cli[k], k
    # the CLI checkpointed through Session.save: provenance meta restores
    restored = Session.load(str(tmp_path / "cli"))
    assert restored.step == report_cli.steps
    assert restored.rank_plan == {k: int(v) for k, v
                                  in adapter.plan.rank_plan.items()}


def test_dryrun_cli_api_parity(capsys):
    argv = ["--arch", "tinyllama-1.1b", "--shape", "train_4k", "--reduced",
            "--mesh", "1,1:data,model", "--compress", "asi"]
    with pytest.raises(SystemExit) as exc:
        _main(dryrun_cli, argv)
    assert exc.value.code == 0
    out = capsys.readouterr().out
    cli_res = json.loads(next(l for l in out.splitlines()
                              if l.startswith("{")))

    api_res = api_analyze.run_cell(
        ARCH, "train_4k", reduced=True, compress="asi",
        mesh_override=((1, 1), ("data", "model")), verbose=False)
    skip = {"t_lower_s", "t_compile_s"}        # wall-clock, not parity
    for k, v in cli_res.items():
        if k not in skip:
            assert api_res[k] == v, k
    assert api_res["status"] == "ok"
    assert "activation_ledger" in api_res


# --------------------------------------------------------------------------
# Session lifecycle: fit -> save -> restore -> serve -> adapt -> swap
# --------------------------------------------------------------------------

def test_session_lifecycle(tmp_path):
    sess = Session.from_config("tinyllama_1_1b", reduced=True, seed=0,
                               compress="asi", kernel_backend="reference")
    trainer = sess.trainer(steps=3, seq_len=16, batch=4,
                           ckpt_dir=str(tmp_path / "loop"), ckpt_every=2)
    res = trainer.fit()
    assert res.step == 3 and sess.step == 3

    sess.save(str(tmp_path / "final"))
    restored = Session.load(str(tmp_path / "final"))
    assert restored.step == 3
    assert restored.cfg == sess.cfg            # provenance round-trips
    assert _tree_equal(restored.params, sess.params)
    assert _tree_equal(restored.asi_state, sess.asi_state)

    server = restored.server(max_batch=2, max_len=32)
    adapter = restored.adapter(mem_budget_mb=0.05, steps=2, batch=2,
                               seq_len=16)
    done = server.run(demo_requests(3, max_new=4),
                      on_retire=adapter.observe)
    assert len(done) == 3 and all(r.done for r in done)
    assert len(adapter.replay) == 3

    before = restored.params
    swapped = adapter.step(2)                  # plan -> ranks -> 2 bursts
    server.swap_params(swapped)
    assert swapped is restored.params and swapped is not before
    assert server.engine.params is swapped     # live for the next decode
    assert len(adapter.report.adapt_losses) == 2
    assert adapter.report.retired == 3         # pre-DS observes still count
    # probe baseline recorded BEFORE the first burst, then once after it
    assert len(adapter.report.probe_losses) == 2
    assert adapter.report.probe_drift is not None

    again = server.run(demo_requests(2, max_new=4, start_uid=10))
    assert all(r.done for r in again)          # serving survives the swap

    # load-time overrides of session-level fields replace the meta values
    reseeded = Session.load(str(tmp_path / "final"), seed=1)
    assert reseeded.seed == 1 and reseeded.step == 3


def test_trainer_never_donates_under_live_server(tmp_path):
    """Donated params a live engine still references are a use-after-free on
    accelerators; a session with an attached server must train donate-free."""
    sess = Session.from_config(ARCH, reduced=True, seed=0)
    server = sess.server(max_batch=2, max_len=32)
    tr = sess.trainer(steps=1, seq_len=16, batch=2, ckpt_dir=str(tmp_path))
    tr.fit()
    assert tr._donated is False
    done = server.run(demo_requests(1, 2))
    assert done[0].done                        # engine unharmed by fit()

    server.close()                             # deterministic detach
    tr3 = sess.trainer(steps=1, seq_len=16, batch=2,
                       ckpt_dir=str(tmp_path / "after_close"))
    tr3.fit()
    assert tr3._donated is True                # donation restored

    solo = Session.from_config(ARCH, reduced=True, seed=0)
    tr2 = solo.trainer(steps=1, seq_len=16, batch=2,
                       ckpt_dir=str(tmp_path / "solo"))
    tr2.fit()
    assert tr2._donated is True                # no server -> keep donation


def test_analyze_without_devices_has_actionable_error():
    sess = Session.from_config(ARCH, reduced=True)
    with pytest.raises(ValueError, match="mesh_override"):
        sess.analyze("train_4k")               # 1 CPU device, no override


def test_session_analyze_defaults_to_reduced_shape():
    sess = Session.from_config(ARCH, reduced=True, compress="asi",
                               scan_unroll=True)
    res = sess.analyze("train_4k",
                       mesh_override=((1, 1), ("data", "model")))
    ref = api_analyze.run_cell(ARCH, "train_4k", reduced=True,
                               compress="asi",
                               mesh_override=((1, 1), ("data", "model")),
                               verbose=False)
    for k in ("model_flops", "params_total", "flops_per_device", "status"):
        assert res[k] == ref[k], k


def test_trainer_requires_no_manual_optimizer():
    sess = Session.from_config(ARCH, reduced=True)
    with pytest.raises(ValueError, match="no optimizer attached"):
        sess.train_step()


def test_adapter_requires_asi_session():
    sess = Session.from_config(ARCH, reduced=True)      # compress="none"
    with pytest.raises(ValueError, match="ASI session"):
        sess.adapter(mem_budget_mb=1.0)


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mod", [serve_cli, train_cli, adapt_cli, dryrun_cli],
                         ids=["serve", "train", "adapt", "dryrun"])
def test_programmatic_main_warns(mod):
    with pytest.warns(DeprecationWarning, match="repro.api.Session"):
        with pytest.raises(SystemExit):        # bad argv: parse error after
            mod.main(["--arch", "nonexistent"])


def test_moved_helpers_warn_and_delegate():
    with pytest.warns(DeprecationWarning, match="repro.api.data_source"):
        fn = train_cli.build_data
    assert callable(fn)
    with pytest.warns(DeprecationWarning, match="repro.api.analyze"):
        rc = dryrun_cli.run_cell
    assert rc is api_analyze.run_cell
    with pytest.raises(AttributeError):
        dryrun_cli.not_a_thing                 # noqa: B018
