"""Mamba2 (SSD — state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm (Dao & Gu 2024): sequential
scan over chunks carrying the (B, H, P, N) state; within a chunk everything is
matmuls (quadratic in the chunk length only), which is the TPU/MXU-friendly
formulation and exactly the structure of the Pallas kernel in
``repro/kernels/ssd_scan.py``.  Decode is the O(1) state recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import initializer, rms_norm
from repro.parallel.sharding import logical_shard

Array = jax.Array


def mamba_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, din, h, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state
    conv_ch = din + 2 * n                       # x, B, C share the causal conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * din + 2 * n + h             # z, x, B, C, dt
    return {
        "in_proj": initializer(k1, (d, d_in_proj), dtype),
        "conv_w": initializer(k2, (cfg.ssm_conv_width, conv_ch), dtype, 0.1),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),              # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((din,), dtype),
        "out_proj": initializer(k4, (din, d), dtype),
    }


def _causal_conv(xbc: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv over (B, S, C); state = last width-1 inputs."""
    width = w.shape[0]
    w = w.astype(xbc.dtype)
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)               # (B, S+w-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(width)) \
        + b.astype(xbc.dtype)
    new_state = xp[:, -(width - 1):]
    return out, new_state


def ssd_chunked(x: Array, dt: Array, a: Array, b: Array, c: Array,
                chunk: int, h0: Array | None = None):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H), a (H,) negative, b/c (B,S,N)  [single group].
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:          # largest divisor of S <= requested chunk
        chunk -= 1
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)
    xc, dtc, bc, cc = (jnp.moveaxis(t, 1, 0) for t in (xc, dtc, bc, cc))

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def chunk_step(hstate, xs):
        xq, dtq, bq, cq = xs                    # (B,chunk,H,P) etc.
        da = dtq * a                            # (B,chunk,H)  log-decay per step
        seg = jnp.cumsum(da, axis=1)            # within-chunk cumulative decay
        # intra-chunk:  y_q = Σ_{j<=q} (C_q·B_j) exp(seg_q - seg_j) dt_j x_j
        att = jnp.einsum("bqn,bjn->bqj", cq, bq,
                         preferred_element_type=jnp.float32)
        decay = seg[:, :, None, :] - seg[:, None, :, :]       # (B,q,j,H)
        mask = jnp.tril(jnp.ones((xq.shape[1], xq.shape[1]), bool))
        l = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        w = att[..., None] * l * dtq[:, None, :, :]           # (B,q,j,H)
        y_intra = jnp.einsum("bqjh,bjhp->bqhp", w,
                             xq.astype(jnp.float32))
        # inter-chunk:  y += C_q · h_in · exp(seg_q)
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cq.astype(jnp.float32),
                             hstate, jnp.exp(seg))
        # state update:  h_out = exp(Σ da) h_in + Σ_j exp(seg_end - seg_j) dt_j B_j x_jᵀ
        dec_end = jnp.exp(seg[:, -1:, :] - seg)               # (B,chunk,H)
        contrib = jnp.einsum("bjh,bjn,bjhp->bhpn",
                             dec_end * dtq, bq.astype(jnp.float32),
                             xq.astype(jnp.float32))
        h_out = hstate * jnp.exp(seg[:, -1])[:, :, None, None] + contrib
        return h_out, (y_intra + y_inter).astype(x.dtype)

    h_final, yc = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, P)
    return y, h_final


def mamba_forward(params: dict, u: Array, cfg: ModelConfig,
                  state: dict | None = None, asi_state: dict | None = None):
    """Full-sequence Mamba2 block.  u (B,S,d).

    Returns (y, new_state, new_asi_state).  ASI wraps the in/out projections
    (the SSD scan itself keeps O(1) state, not per-token activations — see
    DESIGN.md §Arch-applicability)."""
    from repro.core.compressed_linear import (LinearCompressionCfg,
                                              asi_linear)
    B, S, d = u.shape
    din, h, n, p = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    new_asi: dict = {}
    # in_proj's fused zxbcdt output shards with the SSD heads under TP;
    # out_proj emits the replicated d_model dim (out_axis=None below)
    ccfg = LinearCompressionCfg(rank=cfg.asi_rank, backend=cfg.kernel_backend,
                                out_axis="heads")
    if asi_state is not None and "in_proj" in asi_state:
        zxbcdt, ns = asi_linear(ccfg, u, params["in_proj"], None,
                                asi_state["in_proj"])
        new_asi["in_proj"] = ns
    else:
        zxbcdt = u @ params["in_proj"].astype(u.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)  # repro-lint: disable=residual-audit — the gate branch z feeds the output silu-mul; its vjp keeps z, inherent to mamba gating
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(u.dtype)
    x, b, c = jnp.split(xbc, [din, din + n], axis=-1)
    x = x.reshape(B, S, h, p)
    x = logical_shard(x, "batch", None, "heads", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    h0 = state["ssm"] if state is not None else None
    y, h_final = ssd_chunked(x, dt, a, b, c, cfg.ssm_chunk, h0)
    y = y + params["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, din).astype(u.dtype)  # repro-lint: disable=residual-audit — SSD scan output entering the gate-mul; kept by that mul's vjp, not by a matmul site
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)  # repro-lint: disable=residual-audit — gate-mul vjp keeps both branches; inherent to mamba gating
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    if asi_state is not None and "out_proj" in asi_state:
        # out_proj's output dim is d_model — replicated under TP
        out_ccfg = LinearCompressionCfg(rank=cfg.asi_rank,
                                        backend=cfg.kernel_backend,
                                        out_axis=None)
        out, ns = asi_linear(out_ccfg, y, params["out_proj"], None,
                             asi_state["out_proj"])
        new_asi["out_proj"] = ns
    else:
        out = y @ params["out_proj"].astype(y.dtype)
    new_state = {"ssm": h_final, "conv": new_conv}
    return out, new_state, (new_asi or None)


def mamba_decode(params: dict, u: Array, state: dict, cfg: ModelConfig):
    """One-token decode.  u (B,1,d); state {'ssm': (B,H,P,N), 'conv': ...}."""
    B = u.shape[0]
    din, h, n, p = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = u[:, 0] @ params["in_proj"].astype(u.dtype)                   # (B, ·)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    # conv ring: state['conv'] (B, w-1, C) holds previous inputs
    w = params["conv_w"]
    width = w.shape[0]
    hist = state["conv"]
    full = jnp.concatenate([hist, xbc[:, None]], axis=1)   # (B, w, C)
    out = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                     w.astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(out).astype(u.dtype)
    new_conv = full[:, 1:]
    x, b, c = jnp.split(xbc, [din, din + n], axis=-1)
    x = x.reshape(B, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * a)                                   # (B,H)
    hs = state["ssm"]                                      # (B,H,P,N)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dt, b.astype(jnp.float32),
                         x.astype(jnp.float32))
    hs = hs * da[:, :, None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), hs)
    y = y + params["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, din).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(y.dtype))[:, None]
    return out, {"ssm": hs, "conv": new_conv}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    din, h, n, p = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = din + 2 * n
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }
