"""Dry-run analysis as data: lower + compile one (config x input-shape) cell
and report memory/cost analysis, roofline terms, and the activation ledger.

This is the body of the old ``launch/dryrun.py`` hoisted behind the
embeddable API: ``analyze_cell`` consumes an already-built ``Session`` (one
``ModelAPI``, one ``eval_shape`` of its params — the model is never rebuilt
for parameter accounting), and ``run_cell`` keeps the historical
arch-name-first signature for the CLI shim and sweep scripts.

``.lower().compile()`` runs the full GSPMD partitioner + XLA pipeline for
the per-device program; sharding mismatches, non-divisible dims, and
unsupported collectives all fail HERE (and are therefore bugs in our
partition rules, not latent cluster incidents).
"""
from __future__ import annotations

import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, ModelConfig, ShapeCfg,
                                long_context_supported)
from repro.launch import flops_model
from repro.launch import roofline as rl
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import constant
from repro.parallel import partition
from repro.parallel.sharding import axis_rules, rules_for
from repro.runtime.train_loop import make_train_step
from repro.telemetry import memstats


# --------------------------------------------------------------------------
# parameter accounting for MODEL_FLOPS
# --------------------------------------------------------------------------

def _param_counts(cfg: ModelConfig, params_struct) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(params_struct)
    total = matmul = expert = 0
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if name.endswith(("embed",)) and not name.endswith("unembed"):
            continue                       # lookup, not matmul
        if "dec_pos" in name:
            continue
        matmul += n
        if cfg.n_experts and "ffn" in name and len(leaf.shape) >= 3 \
                and cfg.n_experts in leaf.shape:
            expert += n
    active = matmul - expert + (expert * cfg.experts_per_tok
                                // max(cfg.n_experts, 1))
    return {"total": total, "matmul": matmul, "active": active}


def _model_flops(cfg: ModelConfig, shape: ShapeCfg, counts: dict,
                 compress: str) -> float:
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if compress == "none":
            return 6.0 * n_active * tokens
        # fine-tune: full forward + backward only through the tail
        frac = min(cfg.asi_last_k, cfg.n_layers) / cfg.n_layers
        return (2.0 + 4.0 * frac) * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


# --------------------------------------------------------------------------
# step construction per cell kind
# --------------------------------------------------------------------------

def build_cell(session, shape: ShapeCfg, mesh, params_struct=None):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate).

    ``session`` is a ``repro.api.Session``: its (single) ``ModelAPI`` and the
    ``eval_shape``-safe ``init_struct`` hook supply every structure — the
    model is built once per cell, not once per use."""
    api = session.model
    cfg = session.cfg
    key = jax.random.PRNGKey(0)
    if params_struct is None:
        params_struct = api.init_struct(key)
    pspecs = partition.param_specs(cfg, params_struct, mesh)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    B, S = shape.global_batch, shape.seq_len

    def tok_batch():
        d = jnp.dtype(cfg.dtype)
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct((B, cfg.enc_len,
                                                    cfg.d_model), d),
                    "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "vlm":
            st = S - cfg.n_img_tokens
            return {"embeds": jax.ShapeDtypeStruct((B, cfg.n_img_tokens,
                                                    cfg.d_model), d),
                    "tokens": jax.ShapeDtypeStruct((B, st), jnp.int32),
                    "targets": jax.ShapeDtypeStruct((B, st), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, constant(1e-3), clip_norm=1.0)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        asi_struct = (jax.eval_shape(api.init_asi, key)
                      if cfg.compress != "none" else {})
        mask = None
        if cfg.compress != "none":
            mask = jax.eval_shape(api.trainable_mask, params_struct)
            mask = None  # mask arrays are tiny; skip for lowering simplicity
        fn = make_train_step(
            lambda p, b, s: api.loss(p, b, s), opt, trainable_mask=mask)
        batch_struct = tok_batch()
        args = (params_struct, opt_struct, asi_struct, batch_struct,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (ns(pspecs), ns(partition.opt_specs(cfg, opt_struct, mesh)),
                 ns(partition.asi_specs(asi_struct, mesh)),
                 ns(partition.batch_specs(cfg, batch_struct, mesh)), None)
        out_sh = (in_sh[0], in_sh[1], in_sh[2], None)
        return fn.__wrapped__, args, in_sh, out_sh, (0, 1, 2)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            def fn(params, batch):
                return encdec_lib.prefill(params, batch["frames"],
                                          batch["tokens"], cfg, S)
            batch_struct = {
                "frames": jax.ShapeDtypeStruct(
                    (B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        elif cfg.family == "vlm":
            def fn(params, batch):
                return tfm.prefill(params, batch["tokens"], cfg, S,
                                   prefix_embeds=batch["embeds"])
            batch_struct = {
                "embeds": jax.ShapeDtypeStruct(
                    (B, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.n_img_tokens),
                                               jnp.int32)}
        else:
            def fn(params, batch):
                return tfm.prefill(params, batch["tokens"], cfg, S)
            batch_struct = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        args = (params_struct, batch_struct)
        in_sh = (ns(pspecs),
                 ns(partition.batch_specs(cfg, batch_struct, mesh)))
        return fn, args, in_sh, None, ()

    # decode
    cache_struct = jax.eval_shape(partial(api.init_cache, B, S))

    def fn(params, cache, token, pos):
        return api.decode_step(params, cache, token, pos)
    args = (params_struct, cache_struct,
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    cspecs = partition.cache_specs(cfg, cache_struct, mesh)
    ba = partition.batch_axes(mesh)
    tok_spec = partition.safe_spec((B,), P(ba), mesh) \
        if hasattr(partition, "safe_spec") else P(ba)
    in_sh = (ns(pspecs), ns(cspecs),
             NamedSharding(mesh, tok_spec), None)
    out_sh = (None, in_sh[1])
    return fn, args, in_sh, out_sh, (1,)


# --------------------------------------------------------------------------
# cell runner
# --------------------------------------------------------------------------

def _ledger_report(cfg: ModelConfig, shape: ShapeCfg,
                   mem_budget_mb: float | None) -> dict:
    """Per-tail activation-memory estimate (repro.ondevice.ledger) shown
    next to the FLOPs numbers: is the paper's compressed-training regime —
    and the given ``--mem-budget-mb`` — feasible for this cell?"""
    from repro.ondevice.ledger import build_ledger
    led = build_ledger(cfg, shape.global_batch, shape.seq_len)
    rep = led.summary()
    for k in ("arch", "batch", "seq_len"):      # already in the cell result
        rep.pop(k, None)
    if mem_budget_mb is not None:
        rep["budget_mb"] = mem_budget_mb
        rep["asi_fits_budget"] = led.fits(mem_budget_mb)
        rep["vanilla_fits_budget"] = (
            led.vanilla_total_bytes <= mem_budget_mb * 2 ** 20)
        rep["rank1_floor_mb"] = round(led.min_bytes() / 2 ** 20, 4)
    return rep


def analyze_cell(session, shape, *, multi_pod: bool = False,
                 mesh_override=None, seq_shard: bool = False,
                 seq_tp: bool = False, layout: str = "tp",
                 mem_budget_mb: float | None = None,
                 verbose: bool = True) -> dict:
    """Lower + compile one cell for ``session`` and return the report dict.

    ``shape`` is a ``ShapeCfg`` or a ``SHAPES`` name; all config knobs
    (compress/remat/unroll/dtypes) are already baked into the session's
    config — derive a sibling session for variants.
    """
    cfg = session.cfg
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.name == "long_500k" and not long_context_supported(cfg):
        res = {"arch": session.arch, "shape": shape.name,
               "multi_pod": multi_pod, "status": "skipped",
               "reason": "full quadratic attention; see DESIGN.md"}
        if verbose:
            print(json.dumps(res))
        return res

    if mesh_override is not None:
        mesh = make_mesh(*mesh_override)
    else:
        needed = 512 if multi_pod else 256
        if len(jax.devices()) < needed:
            raise ValueError(
                f"the production mesh needs {needed} devices but this "
                f"process sees {len(jax.devices())}: either pass "
                "mesh_override=((D, M), ('data', 'model')) or start the "
                "process with XLA_FLAGS=--xla_force_host_platform_device_"
                "count=512 (the dryrun CLI does the latter automatically)")
        mesh = make_production_mesh(multi_pod=multi_pod)
    # the layout global only steers spec building below; restore it so an
    # embedded analyze() never leaks its layout into the caller's process
    prev_layout = partition.LAYOUT
    partition.set_layout(layout)
    try:
        return _analyze_on_mesh(session, shape, mesh, multi_pod=multi_pod,
                                seq_shard=seq_shard, seq_tp=seq_tp,
                                layout=layout, mem_budget_mb=mem_budget_mb,
                                verbose=verbose)
    finally:
        partition.set_layout(prev_layout)


def _analyze_on_mesh(session, shape, mesh, *, multi_pod, seq_shard, seq_tp,
                     layout, mem_budget_mb, verbose) -> dict:
    cfg = session.cfg
    rules = rules_for(mesh, layout)
    if seq_shard:
        rules = dict(rules, seq="data")
    if seq_tp:
        rules = dict(rules, seq_tp="model")

    # the one eval_shape of the params, reused for shardings AND accounting
    params_struct = session.model.init_struct(jax.random.PRNGKey(0))

    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(session, shape, mesh,
                                                 params_struct)
    jit_kw = dict(in_shardings=in_sh)
    if out_sh is not None:
        jit_kw["out_shardings"] = out_sh
    if donate:
        jit_kw["donate_argnums"] = donate
    with mesh:
        with axis_rules(mesh, rules):
            lowered = jax.jit(fn, **jit_kw).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = memstats.compiled_memory_stats(compiled)
    cost = {}
    try:
        cost = flops_model.cost_analysis_dict(compiled)
    except Exception as e:                                  # noqa: BLE001
        cost = {"error": str(e)}
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)

    counts = _param_counts(cfg, params_struct)
    mf = _model_flops(cfg, shape, counts, cfg.compress)
    # analytic executed-FLOPs model is the primary compute-term source: XLA's
    # cost analysis counts while bodies once (inner attention/SSD chunk loops
    # stay rolled even with the layer scan unrolled).
    analytic = flops_model.cell_flops(cfg, shape, cfg.compress)
    cost_in = {k: v for k, v in cost.items() if isinstance(v, (int, float))}
    hlo_flops = float(cost_in.get("flops", 0.0))
    cost_in["flops"] = analytic / mesh.size
    roof = rl.analyze(cost_in, hlo, mesh.size, mf)

    result = {
        "arch": session.arch, "shape": shape.name, "multi_pod": multi_pod,
        "compress": cfg.compress, "remat": cfg.remat, "fsdp": cfg.fsdp,
        "seq_tp": seq_tp, "param_dtype": cfg.param_dtype, "layout": layout,
        "kv_cache_dtype": cfg.kv_cache_dtype, "unroll": cfg.scan_unroll,
        "status": "ok", "n_devices": mesh.size,
        "mesh": dict(mesh.shape),
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "params_total": counts["total"], "params_active": counts["active"],
        "memory": mem,
        "hlo_flops_per_device": hlo_flops,
        "flops_per_device": roof.flops,
        "hbm_bytes_per_device": roof.hbm_bytes,
        "collective_bytes_per_device": roof.coll_bytes,
        "collective_by_kind": coll.by_kind,
        "collective_ops": coll.count,
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s, "dominant": roof.dominant,
        "model_flops": mf, "useful_ratio": roof.useful_ratio,
        "roofline_fraction": roof.roofline_fraction,
    }
    if shape.kind == "train":
        result["activation_ledger"] = _ledger_report(cfg, shape, mem_budget_mb)
    if verbose:
        print(json.dumps({k: v for k, v in result.items()
                          if k not in ("collective_by_kind", "memory")},
                         default=str))
        print("  memory_analysis:", mem)
        print("  collectives:", coll.by_kind)
    return result


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             compress: str = "none", remat: str | None = None,
             fsdp: bool | None = None, mesh_override=None,
             seq_shard: bool = False, seq_tp: bool = False,
             unroll: bool = True, attn_chunk: int | None = None,
             param_dtype: str | None = None, layout: str = "tp",
             kv_cache_dtype: str | None = None,
             mem_budget_mb: float | None = None,
             reduced: bool = False, verbose: bool = True) -> dict:
    """Arch-name-first wrapper around ``analyze_cell`` (the dryrun CLI /
    sweep-script surface).  ``reduced=True`` analyzes the CPU-sized config
    on the reduced shape — cheap enough for in-process tests and CI."""
    from repro.api.session import Session

    # unroll the layer scan so cost_analysis & collective counts see every
    # layer (XLA counts while bodies once)
    session = Session.from_config(
        arch, reduced=reduced, compress=compress, scan_unroll=unroll,
        remat=remat, fsdp=fsdp, attn_chunk=attn_chunk,
        param_dtype=param_dtype, kv_cache_dtype=kv_cache_dtype)
    shape = SHAPES[shape_name]
    if reduced:
        shape = shape.reduced()
    return analyze_cell(session, shape, multi_pod=multi_pod,
                        mesh_override=mesh_override, seq_shard=seq_shard,
                        seq_tp=seq_tp, layout=layout,
                        mem_budget_mb=mem_budget_mb, verbose=verbose)
