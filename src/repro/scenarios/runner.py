"""Scenario runner: streamed serve→retire→adapt→swap through ``repro.api``.

One ``run_scenario`` call plays a continual-learning workload against the
full stack — the continuous-batching engine, the replay buffer, the §3.3
budget planner, and the train-while-serve ``DeviceSession`` — exclusively
through the public ``repro.api.Session`` surface, and records benchmark
curves:

* **quality over time** — per-burst adaptation loss, tagged with the phase
  the traffic came from;
* **forgetting curves** — one *frozen* probe batch per seen phase,
  re-evaluated after every burst, so backward transfer is a computable
  series (not the single ``probe_drift`` scalar ``SessionReport`` keeps);
* **throughput** — tokens/s and decode steps per serving wave;
* **ledger checks** — the measured (eager vjp-residual) activation bytes of
  the live rank plan vs the analytic ledger and the phase's budget, with an
  **elastic budget hook**: when measured bytes drift past the threshold or
  over a shrunk per-phase budget, the §3.3 planner re-runs on *current*
  traffic (subspace re-selection) and the new rank plan is swapped into the
  live session via fresh ``init_asi_state`` shapes.

Everything a report's ``curves()`` returns is a pure function of the
scenario seed (wall-clock counters are excluded), so two runs with the same
seed must be identical — the regression oracle the scenario tests pin.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ondevice.ledger import build_ledger, measured_site_residual_bytes
from repro.scenarios.replay import make_replay
from repro.telemetry import Recorder
from repro.scenarios.streams import (BurstyTraffic, TaskSequenceStream,
                                     TaskStreamCfg, TrafficCfg,
                                     VisionPhaseStream, VisionStreamCfg)

SCENARIOS = ("domain-shift", "task-sequence", "bursty", "vision")


@dataclasses.dataclass(frozen=True)
class ScenarioCfg:
    """One scenario workload.  ``domain-shift`` is ``task-sequence`` with
    two phases; ``bursty`` is one phase at a higher arrival rate (a
    throughput workload); ``vision`` phases class prototypes through the
    convnets family (no serving engine — the paper's own vision models)."""
    scenario: str = "domain-shift"
    arch: str = "tinyllama_1_1b"
    phases: int = 2
    waves_per_phase: int = 2       # request-injection steps per phase
    rate: float = 3.0              # Poisson mean arrivals per wave
    prompt_lens: tuple = (4, 8, 12)
    max_new: int = 6
    mem_budget_mb: float = 0.05
    budget_schedule: tuple | None = None   # per-phase budgets (elastic)
    drift_threshold: float = 0.2   # measured-vs-analytic replan trigger
    steps: int = 16                # adaptation-step budget for the session
    adapt_every: int = 2
    burst_steps: int = 1
    batch: int = 2
    seq_len: int = 16
    replay_policy: str = "fifo"
    replay_size: int = 32
    rank_select: str = "knapsack"
    lr: float = 1e-2
    max_batch: int = 2
    max_len: int = 48
    seed: int = 0
    reduced: bool = True
    kernel_backend: str = "reference"

    def resolved_phases(self) -> int:
        if self.scenario == "domain-shift":
            return 2
        if self.scenario == "bursty":
            return 1
        return self.phases

    def budget_for(self, phase: int) -> float:
        if self.budget_schedule is None:
            return self.mem_budget_mb
        return float(self.budget_schedule[min(phase,
                                              len(self.budget_schedule) - 1)])


@dataclasses.dataclass
class ScenarioReport:
    scenario: str
    arch: str
    seed: int
    phases: int
    quality: list = dataclasses.field(default_factory=list)
    # str(phase) -> probe loss after each burst since the phase was seen
    probe_curves: dict = dataclasses.field(default_factory=dict)
    burst_phase: list = dataclasses.field(default_factory=list)
    waves: list = dataclasses.field(default_factory=list)
    ledger_checks: list = dataclasses.field(default_factory=list)
    replans: list = dataclasses.field(default_factory=list)

    # --- derived metrics ----------------------------------------------------

    def phase_quality(self, phase: int) -> list:
        return [q["loss"] for q in self.quality if q["phase"] == phase]

    def recovery(self, phase: int) -> float | None:
        """Within-phase improvement of the phase's own probe: first minus
        last probe loss over the bursts where ``phase`` was live traffic
        (positive = the model recovered quality after the shift)."""
        curve = self.probe_curves.get(str(phase), [])
        live = [l for l, p in zip(curve[-len(self.burst_phase):],
                                  self.burst_phase[-len(curve):])
                if p == phase]
        if len(live) < 2:
            return None
        return live[0] - live[-1]

    def forgetting(self, phase: int) -> float | None:
        """Backward transfer: final probe loss minus the phase's best probe
        loss while it was the live distribution (0 = no forgetting)."""
        curve = self.probe_curves.get(str(phase), [])
        live = [l for l, p in zip(curve[-len(self.burst_phase):],
                                  self.burst_phase[-len(curve):])
                if p == phase]
        if not live or not curve:
            return None
        return curve[-1] - min(live)

    def curves(self) -> dict:
        """The deterministic benchmark series (pure in the scenario seed):
        wall-clock throughput counters are deliberately excluded."""
        return {
            "scenario": self.scenario, "arch": self.arch, "seed": self.seed,
            "quality": self.quality,
            "probe_curves": self.probe_curves,
            "burst_phase": self.burst_phase,
            "waves": [{k: v for k, v in w.items() if k != "tokens_per_s"}
                      for w in self.waves],
            "ledger_checks": self.ledger_checks,
            "replans": self.replans,
        }

    def summary(self) -> dict:
        q = [x["loss"] for x in self.quality]
        return {
            "scenario": self.scenario, "arch": self.arch, "seed": self.seed,
            "phases": self.phases, "bursts": len(self.burst_phase),
            "requests": sum(w["requests"] for w in self.waves),
            "quality_first": q[0] if q else None,
            "quality_last": q[-1] if q else None,
            "recovery": {p: self.recovery(p) for p in range(self.phases)},
            "forgetting": {p: self.forgetting(p) for p in range(self.phases)},
            "tokens_per_s": round(float(np.mean(
                [w["tokens_per_s"] for w in self.waves])), 1)
            if self.waves else 0.0,
            "replans": len(self.replans),
        }


# ---------------------------------------------------------------------------
# measured ledger view
# ---------------------------------------------------------------------------

def measured_plan_bytes(cfg, batch: int, seq_len: int, rank_plan: dict) -> int:
    """Ground-truth activation bytes of ``rank_plan``: run every site's
    actual vjp forward rule eagerly and weigh the saved residuals (the
    measured counterpart of ``Ledger.bytes_for``)."""
    led = build_ledger(cfg, batch, seq_len, rank_plan=rank_plan)
    total = 0
    for row in led.rows:
        per_group = measured_site_residual_bytes(
            row.site.tokens, row.site.k, row.rank, compressed=True)
        total += per_group * max(row.site.groups, 1)
    return total


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

def run_scenario(telemetry: Recorder | None = None, **kw) -> ScenarioReport:
    """Run one scenario workload end to end and return its report.

    ``telemetry`` rides outside ``ScenarioCfg`` (the cfg stays a pure
    description of the workload): the recorder is threaded into the
    session so burst/replan spans and ledger-drift gauges interleave with
    the engine's request lifecycle on one timeline."""
    cfg = ScenarioCfg(**kw)
    if cfg.scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {cfg.scenario!r}; choose from "
                         f"{SCENARIOS}")
    rec = telemetry if telemetry is not None else Recorder(enabled=False)
    if cfg.scenario == "vision":
        return _run_vision(cfg, rec)
    return _run_lm(cfg, rec)


def _run_lm(cfg: ScenarioCfg, rec: Recorder) -> ScenarioReport:
    from repro.api import Session
    phases = cfg.resolved_phases()
    sess = Session.from_config(cfg.arch, reduced=cfg.reduced, seed=cfg.seed,
                               compress="asi",
                               kernel_backend=cfg.kernel_backend,
                               telemetry=rec if rec.enabled else None)
    if sess.cfg.family == "encdec":
        raise ValueError("encdec serving needs audio frames; LM scenarios "
                         "target decoder-only archs (use scenario='vision' "
                         "for the non-LM path)")
    stream = TaskSequenceStream(TaskStreamCfg(
        vocab_size=sess.cfg.vocab_size, seq_len=cfg.seq_len,
        global_batch=cfg.batch, phases=phases,
        steps_per_phase=cfg.waves_per_phase, seed=cfg.seed, branching=2))
    traffic = BurstyTraffic(
        TrafficCfg(rate=cfg.rate, prompt_lens=cfg.prompt_lens,
                   max_new_tokens=cfg.max_new, seed=cfg.seed), stream)
    replay = make_replay(cfg.replay_policy, cfg.replay_size, cfg.seq_len,
                         seed=cfg.seed)
    adapter = sess.adapter(
        mem_budget_mb=cfg.budget_for(0), steps=cfg.steps,
        adapt_every=cfg.adapt_every, burst_steps=cfg.burst_steps,
        replay_size=cfg.replay_size, batch=cfg.batch, seq_len=cfg.seq_len,
        rank_select=cfg.rank_select, lr=cfg.lr, max_batch=cfg.max_batch,
        max_len=cfg.max_len, replay=replay)
    ds = adapter.device_session()

    report = ScenarioReport(scenario=cfg.scenario, arch=sess.arch,
                            seed=cfg.seed, phases=phases)
    model = sess.model
    eval_loss = jax.jit(lambda p, b, s: model.loss(p, b, s)[0])
    probes: dict[int, dict] = {}
    state = {"phase": 0, "n_losses": len(ds.report.adapt_losses)}

    def on_burst(ds):
        new = ds.report.adapt_losses[state["n_losses"]:]
        state["n_losses"] = len(ds.report.adapt_losses)
        for loss in new:
            report.quality.append({"burst": len(report.burst_phase),
                                   "phase": state["phase"],
                                   "loss": round(float(loss), 6)})
        for p in sorted(probes):
            # probe reads happen once per burst, not per step — the sync is
            # the measurement
            report.probe_curves[str(p)].append(round(float(  # repro-lint: disable=jit-purity
                eval_loss(ds.params, probes[p], ds.asi_state)), 6))
        report.burst_phase.append(state["phase"])

    ds.on_burst = on_burst

    uid = 0
    for phase in range(phases):
        state["phase"] = phase
        probes[phase] = stream.probe_batch(phase)      # frozen on first sight
        report.probe_curves.setdefault(str(phase), [])
        replay.set_phase(phase)
        if phase > 0:
            report.ledger_checks.append(
                _elastic_check(adapter, cfg, phase, stream, report, rec))
        for wave in range(cfg.waves_per_phase):
            step = phase * cfg.waves_per_phase + wave
            reqs = traffic.arrivals(step, start_uid=uid)
            uid += len(reqs)
            row = {"wave": step, "phase": phase, "requests": len(reqs),
                   "generated_tokens": 0, "decode_steps": 0,
                   "tokens_per_s": 0.0}
            if reqs:
                adapter.run(reqs, drain_steps=False)
                s = ds.engine.last_stats
                row.update(generated_tokens=s.generated_tokens,
                           decode_steps=s.decode_steps,
                           tokens_per_s=round(s.tokens_per_s, 1))
            report.waves.append(row)
    return report


def _elastic_check(adapter, cfg: ScenarioCfg, phase: int,
                   stream: TaskSequenceStream, report: ScenarioReport,
                   rec: Recorder) -> dict:
    """The elastic budget hook: measure the live plan's actual activation
    bytes; if they exceed the phase's budget or drift past the threshold
    from the analytic ledger, re-plan on current-phase traffic."""
    budget_mb = cfg.budget_for(phase)
    mcfg = adapter.session.cfg
    with rec.span("adapt.replan_check", phase=phase, budget_mb=budget_mb):
        analytic = build_ledger(
            mcfg, adapter.batch, adapter.seq_len,
            rank_plan=adapter.plan.rank_plan).asi_total_bytes
        measured = measured_plan_bytes(mcfg, adapter.batch, adapter.seq_len,
                                       adapter.plan.rank_plan)
    drift = abs(measured - analytic) / max(analytic, 1)
    over_budget = measured > budget_mb * 2 ** 20
    rec.set_gauge("adapt.ledger.analytic_bytes", int(analytic))
    rec.set_gauge("adapt.ledger.measured_bytes", int(measured))
    rec.set_gauge("adapt.ledger.drift", float(drift))
    check = {"phase": phase, "budget_mb": budget_mb,
             "analytic_bytes": int(analytic), "measured_bytes": int(measured),
             "drift": round(drift, 4), "replanned": False}
    if over_budget or drift > cfg.drift_threshold:
        old_ranks = {k: int(v) for k, v in adapter.plan.rank_plan.items()}
        calib = [stream.batch(phase * cfg.waves_per_phase + i)
                 for i in range(adapter.calib_batches)]
        with rec.span("adapt.replan", phase=phase, budget_mb=budget_mb,
                      over_budget=over_budget, drift=round(drift, 4)):
            plan = adapter.replan(budget_mb, batches=calib)
        rec.count("adapt.replans")
        check["replanned"] = True
        report.replans.append({
            "phase": phase, "budget_mb": budget_mb,
            "planned_mb": round(plan.planned_bytes / 2 ** 20, 4),
            "rank_deltas": {k: int(plan.rank_plan[k]) - old_ranks[k]
                            for k in old_ranks
                            if int(plan.rank_plan[k]) != old_ranks[k]}})
    return check


# ---------------------------------------------------------------------------
# vision (convnets family — the paper's own models; no serving engine)
# ---------------------------------------------------------------------------

def _run_vision(cfg: ScenarioCfg, rec: Recorder) -> ScenarioReport:
    from repro.models import convnets
    from repro.optim.optimizers import make_optimizer
    ccfg = convnets.mcunet_mini(num_classes=4, compress="asi", last_k=2,
                                ranks=(4, 4, 4, 4))
    phases = cfg.phases if cfg.scenario == "vision" else 2
    batch = max(cfg.batch, 8)           # blobs need a few examples per class
    stream = VisionPhaseStream(VisionStreamCfg(
        num_classes=ccfg.num_classes, hw=ccfg.input_hw, global_batch=batch,
        phases=phases, steps_per_phase=cfg.waves_per_phase * cfg.adapt_every,
        seed=cfg.seed, noise=0.4))
    key = jax.random.PRNGKey(cfg.seed)
    params = convnets.init_params(key, ccfg)
    asi = convnets.init_asi_state(key, ccfg, batch=batch)
    opt = make_optimizer("sgdm", lambda s: 0.05, momentum=0.9)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, asi, batch_):
        def lossf(p):
            loss, (m, ns) = convnets.loss_fn(p, batch_, ccfg, asi)
            return loss, ns
        (loss, ns), g = jax.value_and_grad(lossf, has_aux=True)(params)
        params, opt_state = opt.update(g, opt_state, params, jnp.int32(0))
        return params, opt_state, ns, loss

    eval_loss = jax.jit(
        lambda p, b: convnets.loss_fn(p, b, ccfg, None)[0])

    report = ScenarioReport(scenario="vision", arch=ccfg.name, seed=cfg.seed,
                            phases=phases)
    probes: dict[int, dict] = {}
    steps_per_phase = cfg.waves_per_phase * cfg.adapt_every
    step = 0
    for phase in range(phases):
        probes[phase] = stream.probe_batch(phase)
        report.probe_curves.setdefault(str(phase), [])
        for _ in range(steps_per_phase):
            params, opt_state, asi, loss = train_step(
                params, opt_state, asi, stream.batch(step))
            report.quality.append({"burst": len(report.burst_phase),
                                   "phase": phase,
                                   "loss": round(float(loss), 6)})
            rec.count("adapt.steps")
            rec.observe("adapt.loss", report.quality[-1]["loss"])
            for p in sorted(probes):
                # the per-burst probe reading IS the measurement — syncing
                # here is deliberate, and bursts are sparse
                report.probe_curves[str(p)].append(round(float(  # repro-lint: disable=jit-purity
                    eval_loss(params, probes[p])), 6))
            report.burst_phase.append(phase)
            step += 1
    return report
