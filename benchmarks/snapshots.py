"""Recorded benchmark snapshots: ``BENCH_<name>.json`` files.

One schema shared across every benchmark, so snapshots stay diffable and a
regression is a reviewable one-line change:

* ``schema_version`` — this format (currently 1);
* ``name``           — the benchmark's registry name;
* ``git``            — ``git describe --always --dirty`` at record time;
* ``config``         — the shapes/flags the numbers were measured under;
* ``metrics``        — flat scalar headline numbers (the regression surface);
* ``series``         — optional named numeric curves (quality over time,
  forgetting curves) for benchmarks whose output is a trajectory.

``validate_snapshot`` is the same check ``tests/test_snapshots.py`` runs
over every checked-in file — a malformed snapshot fails tier-1, not a
downstream consumer.
"""
from __future__ import annotations

import json
import os
import subprocess

SCHEMA_VERSION = 1
SNAPSHOT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "snapshots")
_SCALAR = (int, float, str, bool)


def git_describe(cwd: str | None = None) -> str:
    try:
        p = subprocess.run(["git", "describe", "--always", "--dirty"],
                           capture_output=True, text=True, timeout=30,
                           cwd=cwd or os.path.dirname(SNAPSHOT_DIR))
        out = p.stdout.strip()
        return out if p.returncode == 0 and out else "unknown"
    except OSError:
        return "unknown"


def snapshot_path(name: str, directory: str | None = None) -> str:
    return os.path.join(directory or SNAPSHOT_DIR, f"BENCH_{name}.json")


def validate_snapshot(snap: dict, where: str = "snapshot") -> list[str]:
    """Schema offences as strings (empty = valid)."""
    errors = []
    if not isinstance(snap, dict):
        return [f"{where}: not a JSON object"]
    if snap.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"{where}: schema_version "
                      f"{snap.get('schema_version')!r} != {SCHEMA_VERSION}")
    for key, typ in (("name", str), ("git", str), ("config", dict),
                     ("metrics", dict)):
        if not isinstance(snap.get(key), typ):
            errors.append(f"{where}: {key!r} missing or not {typ.__name__}")
    metrics = snap.get("metrics")
    if isinstance(metrics, dict):
        if not metrics:
            errors.append(f"{where}: metrics is empty")
        for k, v in metrics.items():
            if not isinstance(v, _SCALAR):
                errors.append(f"{where}: metrics[{k!r}] is "
                              f"{type(v).__name__}, want scalar")
    series = snap.get("series", {})
    if not isinstance(series, dict):
        errors.append(f"{where}: series is not a dict")
    else:
        for k, v in series.items():
            if not (isinstance(v, list)
                    and all(isinstance(x, (int, float)) for x in v)):
                errors.append(f"{where}: series[{k!r}] is not a numeric list")
    extra = set(snap) - {"schema_version", "name", "git", "config",
                         "metrics", "series"}
    if extra:
        errors.append(f"{where}: unknown keys {sorted(extra)}")
    return errors


def write_snapshot(name: str, config: dict, metrics: dict,
                   series: dict | None = None,
                   directory: str | None = None) -> str:
    snap = {"schema_version": SCHEMA_VERSION, "name": name,
            "git": git_describe(), "config": config, "metrics": metrics}
    if series:
        snap["series"] = series
    errors = validate_snapshot(snap, where=name)
    if errors:
        raise ValueError("refusing to write malformed snapshot:\n"
                         + "\n".join(errors))
    path = snapshot_path(name, directory)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_snapshot(name: str, directory: str | None = None) -> dict:
    with open(snapshot_path(name, directory)) as f:
        return json.load(f)
