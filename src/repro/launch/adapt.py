"""On-device adaptation launcher: budget-driven train-while-serve.

The paper's deployment loop as one command — ledger feasibility, §3.3
calibration + budget search, then a ``DeviceSession`` that serves decode
traffic with the continuous-batching engine while running memory-budgeted
ASI fine-tuning steps from a replay buffer of retired requests:

  PYTHONPATH=src python -m repro.launch.adapt --arch tinyllama-1.1b \
      --reduced --mem-budget-mb 0.05 --steps 10 --adapt-every 2 \
      --requests 8 --max-new 8

Output is JSON lines: the analytical ledger (per-layer vanilla vs compressed
bytes), the plan (per-layer ε/rank under ``--mem-budget-mb``), then serving
and adaptation counters.  The adapted weights are checkpointed via the usual
atomic checkpointer.  ``--config tinyllama_1_1b``-style spellings are
accepted as an ``--arch`` alias (underscores normalize to the registry ids).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.checkpoint import checkpointer
from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.ondevice.ledger import build_ledger
from repro.ondevice.planner import build_plan
from repro.ondevice.session import DeviceSession, SessionCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.serve_loop import Request, ServeCfg
from repro.runtime.train_loop import make_train_step


def _normalize_arch(name: str) -> str:
    """Accept ``tinyllama_1_1b``-style spellings for registry ids."""
    canon = {a.replace("-", "_").replace(".", "_"): a for a in ARCHS}
    return canon.get(name.replace("-", "_").replace(".", "_"), name)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        epilog="Full flag matrix: README.md; subsystem design: DESIGN.md §8")
    ap.add_argument("--arch", "--config", dest="arch", required=True,
                    help=f"architecture ({', '.join(ARCHS)}; underscore "
                         "spellings accepted)")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-sized config (--no-reduced = full arch)")
    ap.add_argument("--mem-budget-mb", type=float, required=True,
                    help="activation-memory budget for the fine-tuned tail; "
                         "the planner chooses per-layer ranks under it")
    ap.add_argument("--steps", type=int, default=10,
                    help="total adaptation steps for the session")
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="retired requests per adaptation burst")
    ap.add_argument("--burst-steps", type=int, default=1,
                    help="train steps per burst")
    ap.add_argument("--replay-size", type=int, default=64,
                    help="replay-buffer capacity (retired token streams)")
    ap.add_argument("--batch", type=int, default=2,
                    help="adaptation batch size (fixed shape, no recompiles)")
    ap.add_argument("--seq-len", type=int, default=32,
                    help="adaptation sequence length (fixed shape)")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="calibration batches for the §3.3 perplexity table")
    ap.add_argument("--rank-select", default="knapsack",
                    choices=("knapsack", "backtracking"),
                    help="budget search: quantized DP or paper backtracking")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--kernel-backend", default="auto",
                    choices=("auto", "pallas", "reference"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_adapt_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    arch = _normalize_arch(args.arch)
    if arch not in ARCHS:
        raise SystemExit(f"unknown arch {args.arch!r}; choose from {ARCHS}")
    cfg = get_config(arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(compress="asi", kernel_backend=args.kernel_backend)
    if cfg.family == "encdec":
        raise SystemExit("encdec serving needs audio frames; on-device "
                         "adaptation currently targets decoder-only archs")

    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)

    # --- ledger: budget feasibility before anything trains ----------------
    ledger = build_ledger(cfg, args.batch, args.seq_len)
    print(json.dumps({"ledger": ledger.summary(),
                      "budget_mb": args.mem_budget_mb,
                      "vanilla_fits": (ledger.vanilla_total_bytes
                                       <= args.mem_budget_mb * 2 ** 20),
                      "rank1_floor_mb": round(ledger.min_bytes() / 2**20, 4)}))

    # --- planner: calibration + §3.3 budget search ------------------------
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size,
                                seq_len=args.seq_len,
                                global_batch=args.batch, seed=args.seed,
                                branching=2))
    calib = [data.batch(s) for s in range(args.calib_batches)]
    plan = build_plan(api, cfg, params, args.mem_budget_mb, calib,
                      batch_size=args.batch, seq_len=args.seq_len,
                      method=args.rank_select, seed=args.seed)
    planned_ok = ledger.bytes_for(plan.rank_plan) <= plan.budget_bytes
    print(json.dumps({"plan": plan.summary(),
                      "plan_respects_ledger_budget": planned_ok}))
    if not planned_ok:
        raise SystemExit("planner produced a plan the ledger prices over "
                         "budget — this is a bug, not a user error")

    # --- session: train-while-serve ---------------------------------------
    asi_state = api.init_asi(key, rank_plan=plan.rank_plan)
    opt_name = cfg.optimizer if cfg.optimizer != "adafactor" else "adamw"
    if opt_name != cfg.optimizer:
        print(json.dumps({"optimizer_substitution": {
            "configured": cfg.optimizer, "used": opt_name,
            "reason": "adafactor is not mask-aware for frozen backbones"}}))
    opt = make_optimizer(
        opt_name,
        warmup_cosine(args.lr, max(args.steps // 5, 1), max(args.steps, 2)),
        clip_norm=2.0)
    opt_state = opt.init(params)
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=api.trainable_mask(params),
                              donate=False,          # engine shares params
                              kernel_backend=cfg.kernel_backend)
    session = DeviceSession(
        api, params, step_fn, opt_state, asi_state,
        ServeCfg(max_batch=args.max_batch, max_len=args.max_len,
                 temperature=args.temperature),
        SessionCfg(adapt_every=args.adapt_every,
                   burst_steps=args.burst_steps, total_steps=args.steps,
                   batch_size=args.batch, seq_len=args.seq_len,
                   replay_size=args.replay_size),
        probe_batch=data.batch(10_000), seed=args.seed)
    requests = [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(5)],
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]
    report = session.run(requests)

    s = report.serve_stats
    print(json.dumps({"serving": {
        "requests": s.requests, "generated_tokens": s.generated_tokens,
        "decode_steps": s.decode_steps,
        "tokens_per_s": round(s.tokens_per_s, 1),
        "ttft_mean_s": round(s.ttft_mean_s, 4)}}))
    print(json.dumps({"adaptation": report.summary()}))

    checkpointer.save(args.ckpt_dir, report.steps,
                      {"params": session.params, "opt": session.opt_state,
                       "asi": session.asi_state},
                      meta={"arch": arch, "optimizer": opt_name,
                            "plan": plan.summary()})
    print(json.dumps({"ckpt_dir": args.ckpt_dir, "ckpt_step": report.steps}))
    return report


if __name__ == "__main__":
    main()
