"""ASI-compressed linear layers via ``jax.custom_vjp``.

The trick: the *residuals* saved between forward and backward are the low-rank
factors (P̂, Q) instead of the full activation X, so XLA genuinely frees X
after the forward dot — this is the paper's activation-memory reduction,
realized natively in JAX.  The forward output is EXACT (compression only
changes what is stored); ∂L/∂x is EXACT (eq. 2 needs only W); ∂L/∂W is the
paper's low-rank estimate  Q·(P̂ᵀ·g)  (eq. 15's matrix analogue).

Variants:
  * ``asi_linear``          — warm-started subspace iteration (the paper).
  * ``hosvd_linear``        — fixed-rank truncated-SVD storage (HOSVD_ε
                              baseline with ranks frozen for jit).
  * ``grouped_asi_linear``  — per-expert version for MoE (factors stacked on a
                              leading expert dim, vmapped iteration).

All return ``(y, new_state)`` so the warm-start state threads functionally
through the training step.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.asi import MatrixASIState, matrix_asi_step, orthonormalize

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LinearCompressionCfg:
    rank: int
    precision: jax.lax.Precision = jax.lax.Precision.DEFAULT


def _flatten(x: Array) -> Array:
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# ASI linear
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array, b: Array | None,
               state: MatrixASIState):
    """y = x @ w (+ b);  stores only rank-``cfg.rank`` factors of x for bwd."""
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    if b is not None:
        y = y + b.astype(y.dtype)
    _, _, new_state = matrix_asi_step(_flatten(x), state)
    return y, new_state


def _asi_linear_fwd(cfg, x, w, b, state):
    x2d = _flatten(x)
    p_hat, q, new_state = matrix_asi_step(x2d, state)
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    if b is not None:
        y = y + b.astype(y.dtype)
    # Residuals: compressed factors only — X itself is NOT saved.
    res = (p_hat, q, w, x.shape, b is not None)
    return (y, new_state), res


def _asi_linear_bwd(cfg, res, cts):
    g_y, _ = cts                                   # cotangent on new_state unused
    p_hat, q, w, x_shape, has_b = res
    g2d = g_y.reshape(-1, g_y.shape[-1])
    # ∂L/∂x — exact, uses only W (paper eq. 2).
    g_x = (g2d @ w.T.astype(g2d.dtype)).reshape(x_shape)
    # ∂L/∂W — low-rank contraction:  Q · (P̂ᵀ g)   ~ 2Mr(N) + 2Kr(N) FLOPs.
    g_w = q.astype(g2d.dtype) @ (p_hat.astype(g2d.dtype).T @ g2d)
    g_b = g2d.sum(axis=0) if has_b else None
    # state is an input we do not differentiate through: zero cotangent.
    g_state = jax.tree.map(jnp.zeros_like, MatrixASIState(q=q))
    return g_x, g_w.astype(w.dtype), g_b, g_state


asi_linear.defvjp(_asi_linear_fwd, _asi_linear_bwd)


# ---------------------------------------------------------------------------
# HOSVD (fixed-rank truncated SVD) linear — the baseline, jit-friendly.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def hosvd_linear(cfg: LinearCompressionCfg, x: Array, w: Array, b: Array | None):
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    return y + b.astype(y.dtype) if b is not None else y


def _hosvd_linear_fwd(cfg, x, w, b):
    x2d = _flatten(x).astype(jnp.float32)
    # Full SVD every step — this is exactly the overhead ASI removes (eq. 11).
    u, s, vt = jnp.linalg.svd(x2d, full_matrices=False)
    r = min(cfg.rank, s.shape[0])
    p_hat = u[:, :r].astype(x.dtype)
    q = (vt[:r, :].T * s[:r]).astype(x.dtype)
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y, (p_hat, q, w, x.shape, b is not None)


def _hosvd_linear_bwd(cfg, res, g_y):
    p_hat, q, w, x_shape, has_b = res
    g2d = g_y.reshape(-1, g_y.shape[-1])
    g_x = (g2d @ w.T.astype(g2d.dtype)).reshape(x_shape)
    g_w = q.astype(g2d.dtype) @ (p_hat.astype(g2d.dtype).T @ g2d)
    g_b = g2d.sum(axis=0) if has_b else None
    return g_x, g_w.astype(w.dtype), g_b


hosvd_linear.defvjp(_hosvd_linear_fwd, _hosvd_linear_bwd)


# ---------------------------------------------------------------------------
# Grouped (per-expert) ASI linear for MoE.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GroupedASIState:
    q: Array      # (E, K, r)

    @staticmethod
    def init(key: Array, n_groups: int, k: int, rank: int,
             dtype=jnp.float32) -> "GroupedASIState":
        q = jax.random.normal(key, (n_groups, k, rank), jnp.float32).astype(dtype)
        return GroupedASIState(q=q)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def grouped_asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array,
                       state: GroupedASIState):
    """x (E, T, K) @ w (E, K, N) -> (E, T, N), ASI per expert."""
    y = jnp.einsum("etk,ekn->etn", x, w.astype(x.dtype))
    new_q = _grouped_iterate(x, state.q)
    return y, GroupedASIState(q=new_q)


def _grouped_iterate(x, q_prev):
    def one(xe, qe):
        p = orthonormalize(xe @ qe)
        return xe.T @ p
    return jax.vmap(one)(x, q_prev)


def _grouped_fwd(cfg, x, w, state):
    def one(xe, qe):
        p = orthonormalize(xe @ qe)
        return p, xe.T @ p
    p_hat, q = jax.vmap(one)(x, state.q)
    y = jnp.einsum("etk,ekn->etn", x, w.astype(x.dtype))
    return (y, GroupedASIState(q=q)), (p_hat, q, w)


def _grouped_bwd(cfg, res, cts):
    g_y, _ = cts
    p_hat, q, w = res
    g_x = jnp.einsum("etn,ekn->etk", g_y, w.astype(g_y.dtype))
    # per-expert low-rank weight grad: Q_e (K,r) @ (P̂_eᵀ g_e) (r,N)
    g_w = jnp.einsum("ekr,etr,etn->ekn", q.astype(g_y.dtype),
                     p_hat.astype(g_y.dtype), g_y)
    g_state = GroupedASIState(q=jnp.zeros_like(q))
    return g_x, g_w.astype(w.dtype), g_state


grouped_asi_linear.defvjp(_grouped_fwd, _grouped_bwd)


# ---------------------------------------------------------------------------
# Plain dense reference (same signature family, for A/B in the trainer).
# ---------------------------------------------------------------------------

def dense_linear(x: Array, w: Array, b: Array | None = None) -> Array:
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    return y + b.astype(y.dtype) if b is not None else y
