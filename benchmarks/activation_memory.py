"""Paper headline table: activation memory of vanilla / HOSVD_ε / ASI-shortcut
training, priced by the on-device ledger on paper shapes.

For each architecture the ledger enumerates the fine-tuned tail's compressed
sites at the paper's TinyLlama fine-tuning shape (B=8, S≤512, rank 20 —
Table 4) and reports total and per-site activation bytes.  The target is the
paper's up-to-120.09x regime: on TinyLlama's down-projection
(M=4096 tokens, K=5632) the ledger gives (M·K)/((M+K)·r) ≈ 118x at rank 20.
HOSVD_ε stores the same factors at equal rank, so its memory column matches
ASI — the column that separates them is per-step decomposition FLOPs (full
SVD vs one warm-started subspace iteration), also reported.

Measured cross-checks:
  * per-site ground truth — materialize one site's vjp residuals eagerly and
    weigh them (``ledger.measured_site_residual_bytes``); the gate asserts
    the analytical/measured gap stays ≤ 20% for both vanilla and ASI;
  * whole-step — compile the reduced-config training step and read XLA's
    ``memory_analysis()`` temp bytes for compress none vs asi (reported,
    backend-dependent).

Run:  PYTHONPATH=src python -m benchmarks.activation_memory
"""
from __future__ import annotations

from repro.configs.registry import get_config
from repro.ondevice.ledger import (BYTES_PER_ELEM, build_ledger,
                                   measured_site_residual_bytes,
                                   measured_step_memory,
                                   site_compressed_elems, site_vanilla_elems)

# the paper's LLM fine-tuning shape (Table 4): B=8, S=512, rank 20
B, S, RANK = 8, 512, 20

ARCHS = ("tinyllama-1.1b", "phi3-mini-3.8b", "mamba2-130m",
         "granite-moe-3b-a800m")


def table_rows() -> list[dict]:
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch).replace(compress="asi", asi_rank=RANK)
        led = build_ledger(cfg, B, S)
        best = max(led.rows, key=lambda r: r.reduction)
        rows.append({
            "arch": arch, "n_sites": len(led.rows),
            "vanilla_mb": led.vanilla_total_bytes / 2 ** 20,
            "hosvd_mb": led.asi_total_bytes / 2 ** 20,   # same factor storage
            "asi_mb": led.asi_total_bytes / 2 ** 20,
            "mem_ratio": led.reduction,
            "best_site": best.site.name,
            "best_site_ratio": best.reduction,
            "hosvd_over_asi_overhead": (
                sum(r.hosvd_overhead_flops for r in led.rows)
                / max(sum(r.asi_overhead_flops for r in led.rows), 1)),
        })
    return rows


def measured_gap() -> dict:
    """Analytical (ledger helpers) vs measured bytes for the paper's largest
    TinyLlama site (down-projection, M=B·S tokens, K=d_ff)."""
    from repro.ondevice.ledger import SiteSpec
    cfg = get_config("tinyllama-1.1b")
    m, k = B * S, cfg.d_ff
    site = SiteSpec("ffn/down", "matrix", k=k, n=cfg.d_model, tokens=m)
    ana_asi = site_compressed_elems(site, RANK) * BYTES_PER_ELEM
    ana_van = site_vanilla_elems(site) * BYTES_PER_ELEM
    meas_asi = measured_site_residual_bytes(m, k, RANK, compressed=True)
    meas_van = measured_site_residual_bytes(m, k, RANK, compressed=False)
    return {
        "site": "down_proj(M=4096,K=5632)",
        "analytical_asi_bytes": ana_asi, "measured_asi_bytes": meas_asi,
        "gap_asi": abs(ana_asi - meas_asi) / max(meas_asi, 1),
        "analytical_vanilla_bytes": ana_van,
        "measured_vanilla_bytes": meas_van,
        "gap_vanilla": abs(ana_van - meas_van) / max(meas_van, 1),
        "measured_ratio": meas_van / max(meas_asi, 1),
    }


def compiled_step_memory() -> dict | None:
    """XLA memory_analysis of the actual (reduced, CPU-compilable) training
    step, compress none vs asi — reported, not gated (temp accounting is
    backend-dependent and includes non-activation workspace)."""
    base = get_config("tinyllama-1.1b").reduced()
    out = {}
    for compress in ("none", "asi"):
        mem = measured_step_memory(
            base.replace(compress=compress, kernel_backend="reference"),
            2, 32)
        if mem is None:
            return None
        out[compress] = mem.get("temp_size_in_bytes")
    if not all(out.values()):
        return None
    out["temp_ratio"] = out["none"] / out["asi"]
    return out


def run(verbose: bool = True) -> dict:
    rows = table_rows()
    gap = measured_gap()
    step_mem = compiled_step_memory()
    if verbose:
        print(f"{'arch':22s} {'sites':>5s} {'van MB':>9s} {'HOSVD MB':>9s} "
              f"{'ASI MB':>7s} {'ratio':>7s} {'best site ratio':>16s}")
        for r in rows:
            print(f"{r['arch']:22s} {r['n_sites']:5d} "
                  f"{r['vanilla_mb']:9.1f} {r['hosvd_mb']:9.2f} "
                  f"{r['asi_mb']:7.2f} {r['mem_ratio']:6.1f}x "
                  f"{r['best_site_ratio']:9.1f}x ({r['best_site']})")
        print(f"measured gap ({gap['site']}): "
              f"asi {gap['gap_asi']*100:.1f}%  vanilla "
              f"{gap['gap_vanilla']*100:.1f}%  measured ratio "
              f"{gap['measured_ratio']:.0f}x")
        if step_mem:
            print(f"compiled step temp bytes none/asi: "
                  f"{step_mem['temp_ratio']:.2f}x")
    max_ratio = max(r["best_site_ratio"] for r in rows)
    # acceptance gates: the paper's >=50x regime on at least one paper shape,
    # with analytical/measured agreement where measurement is available
    assert max_ratio >= 50.0, max_ratio
    assert gap["gap_asi"] <= 0.20 and gap["gap_vanilla"] <= 0.20, gap
    assert gap["measured_ratio"] >= 50.0, gap
    return {"rows": rows, "max_site_ratio": max_ratio, "measured_gap": gap,
            "compiled_step": step_mem}


if __name__ == "__main__":
    run()
