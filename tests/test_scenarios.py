"""Continual-learning scenario harness tests: replay-policy properties,
SessionReport counter edges, stream purity, the elastic-budget replan hook,
cross-family scenario smokes through the public API, and the launch CLI."""
import json

import numpy as np
import pytest

from repro.ondevice.session import ReplayBuffer, SessionReport
from repro.scenarios import (REPLAY_POLICIES, ReservoirReplay,
                             StratifiedReplay, TaskSequenceStream,
                             TaskStreamCfg, TrafficCfg, BurstyTraffic,
                             make_replay, run_scenario)

SEQ = 8


def _fill(buf, n, length=6, phase_every=None):
    for i in range(n):
        if phase_every and i % phase_every == 0:
            buf.set_phase(i // phase_every)
        buf.add([1 + (i + j) % 37 for j in range(length)])


# --------------------------------------------------------------------------
# replay policies: deterministic unit tests
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(REPLAY_POLICIES))
def test_replay_capacity_and_shape(policy):
    buf = make_replay(policy, capacity=8, seq_len=SEQ, seed=0)
    for n in (1, 4, 8, 30):
        _fill(buf, n, phase_every=10)
        assert len(buf) <= 8
        batch = buf.sample_batch(5)
        assert batch["tokens"].shape == (5, SEQ)
        assert batch["targets"].shape == (5, SEQ)
        # next-token alignment survives tiling
        np.testing.assert_array_equal(np.asarray(batch["tokens"])[:, 1:],
                                      np.asarray(batch["targets"])[:, :-1])


@pytest.mark.parametrize("policy", sorted(REPLAY_POLICIES))
def test_replay_deterministic_under_seed(policy):
    a = make_replay(policy, 8, SEQ, seed=3)
    b = make_replay(policy, 8, SEQ, seed=3)
    _fill(a, 20, phase_every=7)
    _fill(b, 20, phase_every=7)
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(a.sample_batch(4)["tokens"]),
                                      np.asarray(b.sample_batch(4)["tokens"]))


def test_fifo_evicts_in_add_order():
    buf = make_replay("fifo", 4, SEQ)
    for i in range(10):
        buf.add([i, i, i])
    assert [row[0] for row in buf._rows()] == [6, 7, 8, 9]


def test_replay_empty_raises_and_unknown_policy():
    with pytest.raises(ValueError, match="empty"):
        make_replay("reservoir", 4, SEQ).sample_batch(2)
    with pytest.raises(ValueError, match="unknown replay policy"):
        make_replay("lru", 4, SEQ)


def test_stratified_balances_phases():
    buf = StratifiedReplay(capacity=8, seq_len=SEQ)
    buf.set_phase(0)
    _fill(buf, 20)
    buf.set_phase(1)
    _fill(buf, 20)
    sizes = {p: len(d) for p, d in buf._by_phase.items()}
    assert sum(sizes.values()) <= 8
    assert sizes[0] == sizes[1] == 4     # even split across seen phases
    # round-robin sampling touches both phases
    buf._rng = np.random.default_rng(0)
    idx = buf._select_indices(6)
    assert any(i < 4 for i in idx) and any(i >= 4 for i in idx)


def test_reservoir_keeps_early_streams():
    """Uniform-over-history: with 4x overfill, some pre-capacity streams
    survive (FIFO would have flushed all of them)."""
    buf = ReservoirReplay(capacity=16, seq_len=SEQ, seed=0)
    for i in range(64):
        buf.add([i, i])
    firsts = {row[0] for row in buf._rows()}
    assert len(firsts & set(range(16))) > 0
    assert len(buf) == 16


# --------------------------------------------------------------------------
# replay policies: hypothesis properties
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                      # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    SETTINGS = dict(max_examples=25, deadline=None)

    @given(policy=st.sampled_from(sorted(REPLAY_POLICIES)),
           capacity=st.integers(1, 16), n_add=st.integers(0, 48),
           batch=st.integers(1, 6), seed=st.integers(0, 2 ** 16))
    @settings(**SETTINGS)
    def test_prop_capacity_never_exceeded(policy, capacity, n_add, batch,
                                          seed):
        buf = make_replay(policy, capacity, SEQ, seed=seed)
        rng = np.random.default_rng(seed)
        for i in range(n_add):
            buf.set_phase(int(rng.integers(0, 3)))
            buf.add(list(rng.integers(0, 99, size=int(rng.integers(2, 12)))))
            assert len(buf) <= capacity
        if n_add:
            b = buf.sample_batch(batch)
            assert b["tokens"].shape == (batch, SEQ)

    @given(policy=st.sampled_from(sorted(REPLAY_POLICIES)),
           seed=st.integers(0, 2 ** 16), n_add=st.integers(1, 30))
    @settings(**SETTINGS)
    def test_prop_sampling_deterministic(policy, seed, n_add):
        bufs = [make_replay(policy, 8, SEQ, seed=seed) for _ in range(2)]
        for buf in bufs:
            _fill(buf, n_add, phase_every=5)
        a = np.asarray(bufs[0].sample_batch(3)["tokens"])
        b = np.asarray(bufs[1].sample_batch(3)["tokens"])
        np.testing.assert_array_equal(a, b)

    @given(capacity=st.integers(1, 12), n_add=st.integers(0, 40))
    @settings(**SETTINGS)
    def test_prop_fifo_add_order_eviction(capacity, n_add):
        buf = make_replay("fifo", capacity, SEQ)
        for i in range(n_add):
            buf.add([i, i])
        kept = [row[0] for row in buf._rows()]
        assert kept == list(range(max(0, n_add - capacity), n_add))


# --------------------------------------------------------------------------
# SessionReport counter edges (golden)
# --------------------------------------------------------------------------

def test_report_probe_drift_edges():
    rep = SessionReport(serve_stats=None, adapt_losses=[], probe_losses=[])
    assert rep.probe_drift is None                       # 0 entries
    rep.probe_losses.append(2.5)
    assert rep.probe_drift is None                       # 1 entry: no drift
    rep.probe_losses.append(2.0)
    assert rep.probe_drift == pytest.approx(-0.5)


def test_report_summary_empty_history():
    rep = SessionReport(serve_stats=None, adapt_losses=[], probe_losses=[])
    s = rep.summary()
    assert s["adapt_loss_first"] is None
    assert s["adapt_loss_last"] is None
    assert s["probe_loss_before"] is None
    assert s["probe_loss_after"] is None
    assert s["probe_drift"] is None
    assert s["retired"] == 0 and s["bursts"] == 0 and s["adapt_steps"] == 0
    assert s["tokens_per_s"] == 0.0      # no serve stats recorded yet


# --------------------------------------------------------------------------
# streams: purity in (seed, step)
# --------------------------------------------------------------------------

def test_task_stream_phase_tables_differ_but_are_stable():
    cfg = TaskStreamCfg(vocab_size=64, seq_len=8, global_batch=2, phases=3,
                        steps_per_phase=2, seed=5)
    s1, s2 = TaskSequenceStream(cfg), TaskSequenceStream(cfg)
    assert not np.array_equal(s1.table(0), s1.table(1))
    for p in range(3):
        np.testing.assert_array_equal(s1.table(p), s2.table(p))
        np.testing.assert_array_equal(
            np.asarray(s1.probe_batch(p)["tokens"]),
            np.asarray(s2.probe_batch(p)["tokens"]))
    assert [s1.phase_of(s) for s in (0, 1, 2, 3, 4, 99)] == [0, 0, 1, 1, 2, 2]


def test_bursty_traffic_pure_and_phase_consistent():
    stream = TaskSequenceStream(TaskStreamCfg(
        vocab_size=64, seq_len=8, global_batch=2, phases=2,
        steps_per_phase=2, seed=1))
    tr = BurstyTraffic(TrafficCfg(rate=4.0, seed=1), stream)
    a = tr.arrivals(3, start_uid=7)
    b = tr.arrivals(3, start_uid=7)
    assert [(r.uid, r.prompt, r.max_new_tokens) for r in a] \
        == [(r.uid, r.prompt, r.max_new_tokens) for r in b]
    # prompts roll the phase table: every transition must exist in it
    table = stream.table(stream.phase_of(3))
    for r in a:
        for t0, t1 in zip(r.prompt, r.prompt[1:]):
            assert t1 in table[t0]


# --------------------------------------------------------------------------
# scenarios end to end (public API only)
# --------------------------------------------------------------------------

SMOKE = dict(scenario="domain-shift", arch="tinyllama_1_1b", reduced=True,
             seed=0, mem_budget_mb=0.05, waves_per_phase=3, rate=4.0,
             steps=32, adapt_every=2, burst_steps=2, batch=2, seq_len=16,
             prompt_lens=(10, 14), max_new=4, lr=0.01,
             replay_policy="fifo", replay_size=32)


@pytest.fixture(scope="module")
def shift_report():
    return run_scenario(**SMOKE)


def test_domain_shift_records_full_curves(shift_report):
    r = shift_report
    assert r.phases == 2 and r.burst_phase and 1 in r.burst_phase
    n_bursts = len(r.burst_phase)
    # phase-0 probe measured after every burst; phase-1 probe only once seen
    assert len(r.probe_curves["0"]) == n_bursts
    assert 0 < len(r.probe_curves["1"]) <= n_bursts
    assert len(r.quality) >= n_bursts           # burst_steps losses per burst
    assert all(w["requests"] >= 0 for w in r.waves)
    assert r.ledger_checks and r.ledger_checks[0]["measured_bytes"] > 0


def test_domain_shift_quality_recovers(shift_report):
    """After the transition-table swap the phase-1 probe improves while
    phase-1 traffic is live, and phase-0 forgetting stays loosely bounded."""
    r = shift_report
    assert r.recovery(1) is not None and r.recovery(1) > 0
    assert r.forgetting(0) is not None and r.forgetting(0) < 3.0


def test_domain_shift_bit_reproducible(shift_report):
    """Same seed, same public-API call => identical deterministic curves."""
    again = run_scenario(**SMOKE)
    assert shift_report.curves() == again.curves()
    # and the curves round-trip through JSON (the CLI writes them)
    assert json.loads(json.dumps(again.curves())) == shift_report.curves()


def test_scenario_rejects_unknown():
    with pytest.raises(ValueError, match="unknown scenario"):
        run_scenario(scenario="chaos-monkey")


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "mamba2-130m"])
def test_scenario_cross_family_smoke(arch):
    """MoE and SSM families run the same streamed scenario through the same
    public API (tiny shapes, one wave per phase)."""
    r = run_scenario(scenario="task-sequence", arch=arch, reduced=True,
                     seed=1, mem_budget_mb=0.2, phases=2, waves_per_phase=1,
                     rate=4.0, steps=8, adapt_every=2, batch=2, seq_len=16,
                     max_new=4, replay_policy="reservoir")
    assert len(r.waves) == 2
    assert sum(w["requests"] for w in r.waves) > 0
    assert r.burst_phase, "no adaptation burst fired"
    assert set(r.probe_curves) <= {"0", "1"} and r.probe_curves["0"]


def test_scenario_vision_family():
    """The convnets family phases class prototypes (no serving engine)."""
    r = run_scenario(scenario="vision", seed=0, phases=2, waves_per_phase=2,
                     adapt_every=2, batch=8)
    assert r.arch.startswith("mcunet")
    n = len(r.burst_phase)
    assert n == 8 and len(r.probe_curves["0"]) == n
    assert r.recovery(1) is not None
    # learning happened in phase 0 at all
    p0 = r.probe_curves["0"]
    assert p0[-1] == p0[-1]                     # finite
    assert r.quality[0]["loss"] != r.quality[-1]["loss"]
    # determinism holds on the vision path too
    again = run_scenario(scenario="vision", seed=0, phases=2,
                         waves_per_phase=2, adapt_every=2, batch=8)
    assert again.curves() == r.curves()


def test_elastic_budget_replans_midstream():
    """A negative drift threshold forces the elastic hook: the planner
    re-runs on current-phase traffic at the phase boundary and the session
    keeps adapting under the swapped rank plan."""
    r = run_scenario(scenario="domain-shift", arch="tinyllama_1_1b",
                     reduced=True, seed=0, mem_budget_mb=0.05,
                     budget_schedule=(0.05, 0.045), drift_threshold=-1.0,
                     waves_per_phase=2, rate=4.0, steps=16, adapt_every=2,
                     batch=2, seq_len=16)
    assert len(r.replans) == 1
    assert r.ledger_checks[0]["replanned"] is True
    assert r.ledger_checks[0]["budget_mb"] == pytest.approx(0.045)
    assert r.replans[0]["planned_mb"] <= 0.045
    # adaptation continued after the swap: bursts recorded in phase 1
    assert 1 in r.burst_phase


# --------------------------------------------------------------------------
# launch CLI
# --------------------------------------------------------------------------

def test_scenarios_cli(tmp_path, capsys):
    from repro.launch import scenarios as cli
    out_path = tmp_path / "curves.json"
    with pytest.deprecated_call():
        cli.main(["--arch", "tinyllama-1.1b", "--reduced",
                  "--scenario", "domain-shift", "--waves-per-phase", "1",
                  "--rate", "4.0", "--steps", "8", "--seq-len", "16",
                  "--mem-budget-mb", "0.05", "--out", str(out_path)])
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    keys = [next(iter(l)) for l in lines]
    assert keys == ["config", "summary", "out"]
    curves = json.loads(out_path.read_text())
    assert curves["scenario"] == "domain-shift"
    assert "probe_curves" in curves and "quality" in curves
    assert all("tokens_per_s" not in w for w in curves["waves"])
