"""Budget-driven rank planner (paper §3.3 wired into the training stack).

Pipeline:

1. **Capture** — run the real loss on a few calibration batches inside a
   ``core.calibration.capture_sites`` context: one ``jax.vjp`` per batch
   yields, for every compressed site in the fine-tuned tail, the exact input
   activation and the exact output cotangent (ASI keeps ∂L/∂x exact, so the
   cotangents are unpolluted by the compression; see calibration.py).
   Batches are concatenated along the token axis.

2. **Perplexity table** — ``rank_selection.estimate_perplexity`` sweeps the
   ε grid and records per-site gradient perplexity ‖dW − ≈dW‖_F, candidate
   ranks, and memory.  The memory column is then re-priced for the
   *adaptation* batch shape via the ledger (calibration and adaptation may
   legitimately use different token counts; ranks transfer, bytes do not).

3. **Budget search** — ``select_ranks_knapsack`` (default; polynomial) or
   the paper-faithful ``select_ranks_backtracking`` picks one ε per site
   minimizing total perplexity s.t. total factor bytes ≤ ``--mem-budget-mb``.

The result is an ``AdaptPlan``: per-site ε / rank / bytes, the
``LinearCompressionCfg`` per site, and ``rank_plan`` — the dict
``init_asi_state`` consumes, which is how the choice physically reaches
``make_train_step`` (ASI's compute rank is the warm-start state's column
count).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import calibration
from repro.core.compressed_linear import LinearCompressionCfg
from repro.core.rank_selection import (DEFAULT_EPS_GRID, LayerCalibration,
                                       estimate_perplexity,
                                       select_ranks_backtracking,
                                       select_ranks_knapsack)
from repro.ondevice import ledger as ledger_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# calibration capture
# ---------------------------------------------------------------------------

def _probe(api, asi_state, batch, params, taps):
    """Loss with tapped site outputs; returns (loss, recorded activations)."""
    with calibration.capture_sites(taps) as cap:
        loss, _ = api.loss(params, batch, asi_state)
        xs = [s.x for s in cap.sites]
    return loss, xs


def capture_calibration(api, cfg: ModelConfig, params, asi_state,
                        batches: Sequence[dict]) -> list[LayerCalibration]:
    """Exact (activation, grad_out) pairs for every tail site, site order =
    forward-trace order = ``ledger.iter_asi_sites`` order (asserted by the
    caller against the ledger's shapes)."""
    if cfg.compress == "none" or not asi_state:
        raise ValueError("calibration needs an ASI-compressed model "
                         "(cfg.compress='asi' and a non-empty asi_state)")
    acts: list[list[np.ndarray]] = []
    grads: list[list[np.ndarray]] = []
    for batch in batches:
        # discovery pass: site output shapes -> tap zeros
        with calibration.capture_sites() as cap:
            jax.eval_shape(lambda p: api.loss(p, batch, asi_state)[0], params)
        taps = [jnp.zeros(s.y_shape, jnp.float32) for s in cap.sites]
        # probe pass: one vjp -> activations (aux) + per-site cotangents
        loss, vjp, xs = jax.vjp(
            partial(_probe, api, asi_state, batch), params, taps,
            has_aux=True)
        del loss
        _, g_taps = vjp(jnp.float32(1.0))
        if not acts:
            acts = [[] for _ in xs]
            grads = [[] for _ in xs]
        for i, (x, g) in enumerate(zip(xs, g_taps)):
            acts[i].append(np.asarray(x, np.float32))
            grads[i].append(np.asarray(g, np.float32))
    out = []
    for i in range(len(acts)):
        # concat calibration batches along the token axis; grouped (E, T, K)
        # sites flatten experts into tokens (the grouped state shares one
        # rank across experts, so a shared subspace estimate is what we want)
        a = np.concatenate(acts[i], axis=-2).reshape(-1, acts[i][0].shape[-1])
        g = np.concatenate(grads[i], axis=-2).reshape(-1, grads[i][0].shape[-1])
        out.append(LayerCalibration(name=f"site{i}", activation=a, grad_out=g,
                                    kind="linear"))
    return out


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdaptPlan:
    arch: str
    budget_mb: float
    method: str
    sites: tuple                         # ledger SiteSpecs, forward order
    eps: dict                            # site name -> chosen ε
    rank_plan: dict                      # site name -> rank (init_asi_state)
    perplexity: dict                     # site name -> gradient perplexity
    planned_bytes: int
    vanilla_bytes: int

    @property
    def budget_bytes(self) -> int:
        return int(self.budget_mb * 2 ** 20)

    @property
    def within_budget(self) -> bool:
        return self.planned_bytes <= self.budget_bytes

    def compression_cfgs(self, backend: str = "auto") -> dict:
        """Per-site LinearCompressionCfg — the concrete per-layer config the
        training step runs under (rank from the plan)."""
        return {s.name: LinearCompressionCfg(rank=self.rank_plan[s.name],
                                             backend=backend)
                for s in self.sites}

    def summary(self) -> dict:
        return {
            "arch": self.arch, "method": self.method,
            "budget_mb": self.budget_mb,
            "planned_mb": round(self.planned_bytes / 2 ** 20, 4),
            "vanilla_mb": round(self.vanilla_bytes / 2 ** 20, 2),
            "reduction": round(self.vanilla_bytes
                               / max(self.planned_bytes, 1), 1),
            "within_budget": self.within_budget,
            "ranks": {k: int(v) for k, v in self.rank_plan.items()},
        }


def build_plan(api, cfg: ModelConfig, params, budget_mb: float,
               batches: Sequence[dict], *, batch_size: int, seq_len: int,
               method: str = "knapsack",
               eps_grid: Sequence[float] = DEFAULT_EPS_GRID,
               seed: int = 0) -> AdaptPlan:
    """Capture calibration on ``batches`` and choose per-site ranks for the
    adaptation shape (``batch_size`` x ``seq_len``) under ``budget_mb``."""
    led = ledger_lib.build_ledger(cfg, batch_size, seq_len)
    sites = tuple(r.site for r in led.rows)
    if led.min_bytes() > budget_mb * 2 ** 20:
        raise ValueError(
            f"--mem-budget-mb {budget_mb:g} infeasible: rank-1 factors alone "
            f"need {led.min_bytes() / 2**20:.3f} MB for {len(sites)} sites "
            f"(ledger floor)")

    asi_state = api.init_asi(jax.random.PRNGKey(seed))
    layers = capture_calibration(api, cfg, params, asi_state, batches)
    if len(layers) != len(sites):
        raise AssertionError(
            f"capture saw {len(layers)} sites, ledger enumerates "
            f"{len(sites)} — site enumeration out of sync with the model")
    for ly, site in zip(layers, sites):
        if ly.activation.shape[-1] != site.k:
            raise AssertionError(
                f"site {site.name}: captured activation width "
                f"{ly.activation.shape[-1]} != ledger K {site.k}")
        ly.name = site.name

    table = estimate_perplexity(layers, eps_grid)
    # Re-price memory for the adaptation shape: ranks transfer from the
    # calibration activations, byte counts must use the training (B, S).
    # Calibration concatenates batches along tokens, so its candidate ranks
    # can exceed the adaptation shape's token count — clamp to the rank the
    # subspace iteration can actually sustain at (B, S) (orthonormalizing an
    # (M, r) factor with r > M collapses to M columns).
    def _adapt_rank(site, r):
        return min(max(int(r), 1), site.tokens, site.k)

    n, e = table.perplexity.shape
    memory = np.zeros((n, e))
    for i, site in enumerate(sites):
        for j in range(e):
            r = _adapt_rank(site, table.ranks[i, j, 0])
            memory[i, j] = (ledger_lib.site_compressed_elems(site, r)
                            * ledger_lib.BYTES_PER_ELEM)

    budget_bytes = budget_mb * 2 ** 20
    grid_floor = float(memory.min(axis=1).sum())
    if grid_floor > budget_bytes:
        raise ValueError(
            f"--mem-budget-mb {budget_mb:g} infeasible under the ε grid "
            f"{tuple(eps_grid)}: the smallest-rank candidates already need "
            f"{grid_floor / 2**20:.4f} MB — lower the grid's minimum ε or "
            f"raise the budget")
    if method == "backtracking":
        choice = select_ranks_backtracking(table.perplexity, memory,
                                           budget_bytes)
    elif method == "knapsack":
        choice = select_ranks_knapsack(table.perplexity, memory, budget_bytes)
    else:
        raise ValueError(f"unknown rank-selection method {method!r}")

    rank_plan, eps, perp = {}, {}, {}
    planned = 0
    for i, site in enumerate(sites):
        j = choice[i]
        rank_plan[site.name] = _adapt_rank(site, table.ranks[i, j, 0])
        eps[site.name] = float(table.eps_grid[j])
        perp[site.name] = float(table.perplexity[i, j])
        planned += int(memory[i, j])
    return AdaptPlan(arch=cfg.name, budget_mb=budget_mb, method=method,
                     sites=sites, eps=eps, rank_plan=rank_plan,
                     perplexity=perp, planned_bytes=planned,
                     vanilla_bytes=led.vanilla_total_bytes)
