"""Shared CLI/config resolution helpers for the embeddable API.

Every launcher used to re-implement these three things slightly differently
(adapt accepted ``tinyllama_1_1b`` spellings, the others rejected them; only
train parsed ``--mesh``; only adapt had a ``--config`` alias).  They live
here once, and the four ``repro.launch`` shims plus ``Session.from_config``
all share them.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import warnings

from repro.configs.registry import ARCHS


def resolve_arch(name: str) -> str:
    """Normalize an architecture spelling to its registry id.

    ``tinyllama_1_1b``, ``tinyllama-1.1b`` and ``tinyllama-1-1b`` all resolve
    to ``tinyllama-1.1b``; unknown names pass through unchanged so the caller
    (argparse ``choices`` or ``Session.from_config``) owns the error.
    """
    fold = lambda s: s.replace("-", "_").replace(".", "_")  # noqa: E731
    canon = {fold(a): a for a in ARCHS}
    return canon.get(fold(str(name)), name)


def add_arch_argument(ap: argparse.ArgumentParser, required: bool = True):
    """The one ``--arch``/``--config`` argument all four CLIs share:
    underscore spellings are normalized by ``resolve_arch`` before the
    ``choices`` check, so every launcher accepts every spelling adapt did."""
    return ap.add_argument(
        "--arch", "--config", dest="arch", type=resolve_arch, choices=ARCHS,
        required=required, metavar="ARCH",
        help=f"architecture ({', '.join(ARCHS)}; underscore spellings "
             "accepted)")


def add_telemetry_arguments(ap: argparse.ArgumentParser):
    """The ``--telemetry``/``--profile-trace`` pair every launcher shares
    (README flag matrix; DESIGN.md §13)."""
    g = ap.add_argument_group("telemetry")
    g.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                   help="record spans/counters/gauges for the run and write "
                        "the event stream as JSONL here (validate with "
                        "python -m repro.telemetry OUT.jsonl)")
    g.add_argument("--profile-trace", default=None, metavar="DIR",
                   help="also capture a jax.profiler trace into DIR "
                        "(TraceAnnotation scopes, compile-vs-run split on "
                        "first step, device memory analysis); implies "
                        "telemetry recording")
    return g


@contextlib.contextmanager
def telemetry_recorder(args):
    """Recorder for a launcher run, from the ``add_telemetry_arguments``
    flags; yields ``None`` when neither flag was given.

    When recording: attaches the ``jax.profiler`` bridge if
    ``--profile-trace`` was set, runs the body under the profiler, exports
    the JSONL stream on exit, and prints one JSON line naming the outputs.
    """
    path = getattr(args, "telemetry", None)
    trace_dir = getattr(args, "profile_trace", None)
    if path is None and trace_dir is None:
        yield None
        return
    from repro.telemetry import Recorder, export_chrome_trace, export_jsonl
    rec = Recorder()
    if trace_dir is not None:
        rec.attach_profiler(trace_dir=trace_dir)
    with rec.profile():
        yield rec
    out = {}
    if path is not None:
        export_jsonl(rec, path)
        out["telemetry"] = path
        out["events"] = len(rec.events)
        out["dropped"] = rec.dropped
    if trace_dir is not None:
        # the recorder's own span timeline, loadable in chrome://tracing /
        # Perfetto, next to the raw xplane dump jax.profiler wrote
        chrome = os.path.join(trace_dir, "telemetry.trace.json")
        os.makedirs(trace_dir, exist_ok=True)
        export_chrome_trace(rec, chrome)
        out["profile_trace"] = trace_dir
        out["chrome_trace"] = chrome
    print(json.dumps({"telemetry_out": out}))


def parse_mesh(mesh) -> tuple[int, int] | None:
    """``--mesh D,M`` -> (data, model) axis sizes; tuples pass through."""
    if mesh is None or isinstance(mesh, tuple):
        return mesh
    try:
        shape = tuple(int(x) for x in str(mesh).split(","))
    except ValueError:
        shape = ()
    if len(shape) != 2:
        raise ValueError(f"--mesh {mesh!r} must be two comma-separated "
                         f"ints: data,model (e.g. 2,4)")
    return shape


def warn_programmatic_use(module: str, argv) -> None:
    """Deprecation shim for the pre-``repro.api`` programmatic surface.

    ``python -m repro.launch.X`` calls ``main()`` with ``argv=None`` (parse
    ``sys.argv``) — that path stays silent.  Passing an explicit ``argv``
    list is the old embed-the-CLI pattern, now deprecated in favour of
    ``repro.api.Session``.
    """
    if argv is not None:
        warnings.warn(
            f"programmatic use of {module}.main() is deprecated; embed "
            "repro.api.Session instead (see DESIGN.md §9)",
            DeprecationWarning, stacklevel=3)
