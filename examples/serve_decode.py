"""Serving example: continuous-batching decode across architectures (dense
GQA+SWA, MoE, SSM, hybrid, and the whisper encoder-decoder via precomputed
frames) through the one Engine code path.

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs.registry import get_config
from repro.models import build_model
from repro.runtime.serve_loop import Engine, Request, ServeCfg


def main():
    for arch in ("tinyllama-1.1b", "h2o-danube-3-4b", "moonshot-v1-16b-a3b",
                 "mamba2-130m", "jamba-1.5-large-398b"):
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        eng = Engine(api, params, ServeCfg(max_batch=2, max_len=48,
                                           temperature=0.0))
        reqs = [Request(uid=i, prompt=[2 + i, 7, 11, 5], max_new_tokens=6)
                for i in range(3)]
        done = eng.run(reqs)
        outs = {r.uid: r.out for r in done}
        s = eng.last_stats
        print(f"{arch:24s} -> {outs}  "
              f"[{s.tokens_per_s:.0f} tok/s, {s.decode_steps} steps]")
        assert all(len(v) == 6 for v in outs.values())

    # encoder-decoder: prompts ride with precomputed audio-frame embeddings
    cfg = get_config("whisper-medium").reduced()
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32))
    frames = jax.random.normal(key, (1, cfg.enc_len, cfg.d_model))
    reqs = [Request(uid=i, prompt=[1, 2 + i], max_new_tokens=4,
                    embeds=frames * (1.0 + 0.1 * i)) for i in range(3)]
    done = eng.run(reqs)
    outs = {r.uid: r.out for r in done}
    print(f"{'whisper-medium':24s} -> {outs}  "
          f"[{eng.last_stats.tokens_per_s:.0f} tok/s]")
    assert all(len(v) == 4 for v in outs.values())


if __name__ == "__main__":
    main()
