"""Shared machinery for the graph-lint rules: family iteration, residual
enumeration off the traced vjp, source attribution, lowered-module alias
parsing, and abstract-signature hashing.

Everything here is device-free: model state comes from ``eval_shape``,
residuals from ``jax.make_jaxpr`` over the vjp *pullback* (its closed-over
leaves are exactly the jaxpr outputs), donation aliasing from ``.lower()``
text.  Only the collectives audit needs real devices and goes through
:func:`run_forced_devices`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
import re
import subprocess
import sys
from functools import partial
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

BYTES_PER_ELEM = 4          # residual accounting is fp32, like the ledger

#: census shape used everywhere (goldens, ledger reconciliation, tests)
CENSUS_BATCH, CENSUS_SEQ = 2, 16

#: narrow a sweep for tests / local runs: comma-separated arch names
FAMILIES_ENV = "REPRO_GRAPH_FAMILIES"


def iter_families() -> Iterator[tuple[str, Any, Any]]:
    """Yield ``(arch, cfg, api)`` for every registry family (reduced shapes,
    ASI compression on — the configuration whose memory story the paper's
    headline table measures)."""
    from repro.configs.registry import ARCHS, get_config
    from repro.models import build_model
    only = os.environ.get(FAMILIES_ENV, "")
    wanted = [a.strip() for a in only.split(",") if a.strip()] or list(ARCHS)
    for arch in wanted:
        cfg = get_config(arch).reduced().replace(compress="asi")
        yield arch, cfg, build_model(cfg)


# --------------------------------------------------------------------------
# residual enumeration + classification

@dataclasses.dataclass
class ResidualRecord:
    """One saved vjp residual: shape, classification, producing source."""
    shape: tuple[int, ...]
    dtype: str
    category: str               # factor | param | dense | other | meta
    nbytes: int
    path: str | None = None     # repo-relative producer, when attributable
    line: int = 0
    primitive: str = ""


@dataclasses.dataclass
class Census:
    """Residual census of one family's train step at the census shape."""
    arch: str
    counts: dict[str, int]
    factor_bytes: int
    ledger_bytes: int
    factor_match: bool
    records: list[ResidualRecord]

    @property
    def reconciled(self) -> bool:
        return self.factor_match and self.factor_bytes == self.ledger_bytes

    def summary(self) -> dict:
        return {"counts": dict(sorted(self.counts.items())),
                "factor_bytes": self.factor_bytes,
                "ledger_bytes": self.ledger_bytes}


def residual_jaxpr(loss_fn: Callable, *example_args):
    """jaxpr whose outputs are the vjp residuals of ``loss_fn``.

    The pullback returned by ``jax.vjp`` closes over every tensor the
    backward pass needs; returning it makes those tensors the traced
    function's outputs, so ``make_jaxpr`` enumerates the residual set
    without touching a device.  ``has_aux=True`` mirrors the trainer's
    ``value_and_grad(loss_fn, has_aux=True)`` contract.
    """
    def resid(params, batch, asi):
        _out, pullback, _aux = jax.vjp(
            lambda p, s: loss_fn(p, batch, s), params, asi, has_aux=True)
        return pullback
    return jax.make_jaxpr(resid)(*example_args)


def _producer_map(jaxpr) -> dict[int, Any]:
    prod: dict[int, Any] = {}
    for eqn in jaxpr.jaxpr.eqns:
        for ov in eqn.outvars:
            prod[id(ov)] = eqn
    return prod


def _attribute(eqn) -> tuple[str | None, int, str]:
    """(repo-relative path, line, primitive) of the first repro-owned frame
    on the producing equation's traceback (jit/vjp framework frames are
    upstream jax files and get skipped)."""
    if eqn is None:
        return None, 0, ""
    prim = eqn.primitive.name
    tb = eqn.source_info.traceback
    if tb is None:
        return None, 0, prim
    for frame in tb.frames:
        if "/src/repro/" in frame.file_name:
            rel = "src/repro/" + frame.file_name.split("/src/repro/")[-1]
            return rel, frame.line_num, prim
    return None, 0, prim


def ledger_expectation(cfg, batch: int, seq_len: int):
    """The analytic side of the reconciliation: the exact multiset of ASI
    factor shapes the ledger predicts the backward pass saves, plus the
    site extents the dense-residual heuristic keys on."""
    from repro.ondevice import ledger as ledger_lib
    led = ledger_lib.build_ledger(cfg, batch, seq_len)
    expected: collections.Counter = collections.Counter()
    site_ks: set[int] = set()
    token_extents: set[int] = set()
    for row in led.rows:
        site, r = row.site, row.rank
        site_ks.add(site.k)
        token_extents.add(site.tokens)
        if site.kind == "grouped":
            expected[(site.groups, site.tokens, r)] += 1
            expected[(site.groups, site.k, r)] += 1
        else:
            expected[(site.tokens, r)] += 1
            expected[(site.k, r)] += 1
    return led, expected, site_ks, token_extents


def classify_residuals(jaxpr, expected: collections.Counter,
                       param_shapes: collections.Counter,
                       site_ks: set[int], token_extents: set[int]
                       ) -> list[ResidualRecord]:
    """Classify every residual (jaxpr output) by shape:

    - ``meta``   — non-float / rank<=1 / empty: counters, masks, indices;
    - ``factor`` — matches the ledger's expected ASI factor multiset
      (greedy: each expected shape absorbs at most its predicted count);
    - ``param``  — a saved weight (weights are alive anyway, zero marginal
      activation cost);
    - ``dense``  — token-extent leading dims with a site-k feature tail:
      a full activation the paper says must never be saved;
    - ``other``  — small per-token intermediates (norm scales, logits
      slices) that are neither factors nor full activations.
    """
    prod = _producer_map(jaxpr)
    factor_seen: collections.Counter = collections.Counter()
    records: list[ResidualRecord] = []
    for ov in jaxpr.jaxpr.outvars:
        av = ov.aval
        shape = tuple(getattr(av, "shape", ()))
        dtype = getattr(av, "dtype", None)
        nbytes = int(getattr(av, "size", 0)) * BYTES_PER_ELEM
        is_float = dtype is not None and jnp.issubdtype(dtype, jnp.floating)
        rec = ResidualRecord(shape=shape, dtype=str(dtype), category="other",
                             nbytes=nbytes)
        if not is_float or len(shape) <= 1 or min(shape) == 0:
            rec.category = "meta"
            records.append(rec)
            continue
        if factor_seen[shape] < expected[shape]:
            factor_seen[shape] += 1
            rec.category = "factor"
            records.append(rec)
            continue
        if param_shapes[shape]:
            rec.category = "param"
            records.append(rec)
            continue
        lead = math.prod(shape[:-1])
        lead2 = math.prod(shape[:-2])
        is_dense = (lead in token_extents and shape[-1] in site_ks) or \
                   (shape[-1] in site_ks and lead2 in token_extents)
        rec.path, rec.line, rec.primitive = _attribute(prod.get(id(ov)))
        rec.category = "dense" if is_dense else "other"
        records.append(rec)
    return records


def census_family(arch: str, cfg, api,
                  batch: int = CENSUS_BATCH,
                  seq_len: int = CENSUS_SEQ,
                  loss_fn: Callable | None = None) -> Census:
    """Full residual census of one family's train step.

    ``loss_fn`` defaults to the family's real ``api.loss``; tests inject a
    wrapped loss (e.g. a custom_vjp saving a dense activation) to prove the
    census sees through constructs AST taint cannot.
    """
    from repro.ondevice import ledger as ledger_lib
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(api.init, key)
    asi = jax.eval_shape(partial(api.init_asi, rank_plan=None), key)
    batch_struct = ledger_lib._batch_struct(cfg, batch, seq_len)
    led, expected, site_ks, token_extents = ledger_expectation(
        cfg, batch, seq_len)
    jaxpr = residual_jaxpr(loss_fn or api.loss, params, batch_struct, asi)
    param_shapes = collections.Counter(
        tuple(leaf.shape) for leaf in jax.tree.leaves(params))
    records = classify_residuals(jaxpr, expected, param_shapes,
                                 site_ks, token_extents)
    counts = collections.Counter(r.category for r in records)
    factor_bytes = sum(r.nbytes for r in records if r.category == "factor")
    factor_match = (collections.Counter(
        r.shape for r in records if r.category == "factor") == expected)
    return Census(arch=arch, counts=dict(counts), factor_bytes=factor_bytes,
                  ledger_bytes=led.asi_total_bytes,
                  factor_match=factor_match, records=records)


# --------------------------------------------------------------------------
# donation aliasing (lowered-module inspection, device-free)

_ALIAS_RE = re.compile(r"tf\.aliasing_output")
_ARG_RE = re.compile(r"%arg(\d+)((?::\s*tensor<[^>]*>)?\s*(\{[^}]*\})?)")


def aliased_argument_count(lowered_text: str) -> int:
    """Count ``@main`` parameters carrying ``tf.aliasing_output`` in a
    lowered module's MLIR text — the compiler's own record of which donated
    buffers it will actually reuse.  A donated-but-unaliased parameter is a
    dead donation."""
    main = lowered_text.split("func.func public @main", 1)
    if len(main) < 2:
        return len(_ALIAS_RE.findall(lowered_text))
    # attributes live in the {...} block attached to each %arg in the
    # signature; counting alias attrs before the function body starts
    sig = main[1].split("{\n", 1)[0]
    return len(_ALIAS_RE.findall(sig))


def donated_leaf_count(example_args: tuple, donate_argnums: tuple) -> int:
    """Flat leaf count across the donated positional arguments."""
    return sum(len(jax.tree.leaves(example_args[i])) for i in donate_argnums)


def audit_donation(jitted, example_args: tuple, donate_argnums: tuple
                   ) -> tuple[int, int]:
    """(donated_leaves, aliased_leaves) for one jitted call site, from the
    device-free lowering of abstract arguments."""
    lowered = jitted.lower(*example_args)
    aliased = aliased_argument_count(lowered.as_text())
    return donated_leaf_count(example_args, donate_argnums), aliased


# --------------------------------------------------------------------------
# abstract call-signature hashing (recompile audit)

def signature_key(*args) -> tuple:
    """Hashable abstract signature of a call: treedef + per-leaf
    (shape, dtype, weak_type).  Two calls with different keys compile two
    cache entries; a weak-type flip on an otherwise identical call is the
    classic silent-recompile bug."""
    leaves, treedef = jax.tree.flatten(args)
    abstract = []
    for leaf in leaves:
        aval = jax.api_util.shaped_abstractify(leaf)
        abstract.append((tuple(aval.shape), str(aval.dtype),
                         bool(getattr(aval, "weak_type", False))))
    return (str(treedef), tuple(abstract))


def weak_typed_leaves(tree) -> list[tuple[str, tuple]]:
    """(keypath, shape) of every weak-typed leaf — python scalars that
    leaked into state a jitted call will close over or take as input."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        aval = jax.api_util.shaped_abstractify(leaf)
        if getattr(aval, "weak_type", False):
            out.append((jax.tree_util.keystr(path), tuple(aval.shape)))
    return out


# --------------------------------------------------------------------------
# forced-device subprocess (collectives audit)

def run_forced_devices(code: str, devices: int = 8, timeout: int = 1200
                       ) -> str:
    """Run ``code`` in a subprocess with ``devices`` forced host-platform
    CPU devices (XLA device flags are read once at backend init, so a
    multi-device compile from a single-device process needs a fresh
    interpreter).  Returns stdout; raises on failure with both streams."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               JAX_PLATFORMS="cpu")
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"forced-device subprocess failed:\n{proc.stdout[-2000:]}"
            f"\n{proc.stderr[-2000:]}")
    return proc.stdout
