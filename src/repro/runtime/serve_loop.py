"""Continuous-batching decode serving.

``Engine`` keeps one KV/SSM cache of ``max_batch`` rows alive for the whole
request stream and drives all active rows in lock-step:

* **prefill** — either a whole prompt in one jitted call (``ModelAPI.prefill``,
  the legacy default) or *chunked*: the prompt runs through ``decode_step`` in
  fixed-size chunks interleaved with decode steps, so a long admission never
  stalls the lock-step batch and compile state stays bounded at ~one entry
  per chunk size instead of one per (prompt length, embeds shape).
* **decode** — one jitted ``_step`` advances every slot together.  Each slot
  carries its own position counter (per-slot ``pos`` threads through
  ``decode_step`` into the attention cache writes/masks), its own
  remaining-token budget, and an active flag; finished slots are frozen by
  masking, so retirement and admission never trigger recompilation.
* **paged KV** (``ServeCfg.cache == "paged"``) — attention layers share one
  physical block pool; each slot maps logical blocks through a host-side
  block table (``runtime/paged_kv.py``).  Blocks are allocated lazily as
  slots deepen and returned at retirement, so peak cache bytes track the
  *live* token count, not ``max_batch × max_len``.  Pool exhaustion
  back-pressures admission and, mid-decode, preempts the newest slot
  (recompute on re-admission — exact under the engine's deterministic
  sampling because the re-fed prompt+output prefix reproduces the cache).
* **sampling** — on device, inside the jitted step: greedy ``argmax`` or
  temperature sampling via per-slot ``jax.random.categorical``.  The only
  per-step host transfer is the sampled-token vector and the
  finished-this-step mask (two ``(max_batch,)`` vectors).

The scheduler (plain Python around the jitted calls) retires finished
requests, admits pending ones into freed slots (``Request.arrival_step``
gates admission for traffic-trace replay), and feeds the telemetry
recorder: the full request lifecycle (queued → admitted → prefill-chunk×N
→ first-token → decode → preempted/retired) plus TTFT/TPOT/queue-delay
histograms and paged-pool occupancy gauges, all piggybacked on the
existing per-step host transfer — telemetry adds **zero** device syncs
(the ``telemetry-contract`` lint rule keeps it that way).
``Engine.last_stats`` is a thin per-run view derived from the recorder's
aggregates (DESIGN.md §13).

``SequentialEngine`` preserves the original one-request-at-a-time loop
(per-token Python prefill, host-side argmax) as the A/B baseline for
``benchmarks/serve_throughput.py`` and the parity tests.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.paged_kv import PagedKVManager
from repro.telemetry import Recorder

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    embeds: Any = None            # vlm prefix embeds / encdec audio frames,
                                  # shape (1, n, d) — threaded into prefill
    ttft_s: float | None = None   # time-to-first-token, set by Engine.run
    arrival_step: int = 0         # earliest decode step this request may be
                                  # admitted at (traffic-trace replay; 0 =
                                  # available immediately, the legacy default)


@dataclasses.dataclass
class ServeCfg:
    max_batch: int = 4
    max_len: int = 128
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early
    cache: str = "dense"          # dense | paged
    prefill_chunk: int = 0        # >0: chunked prefill with this chunk size;
                                  # 0 = whole-prompt (dense) / page_block
                                  # (paged — paged prefill is always chunked)
    page_block: int = 16          # positions per physical KV block (paged)
    pool_blocks: int = 0          # physical blocks in the shared pool; 0 =
                                  # dense-equivalent capacity + trash block


@dataclasses.dataclass
class ServeStats:
    """Throughput/latency counters for one ``Engine.run``."""
    requests: int = 0
    generated_tokens: int = 0
    prefill_calls: int = 0
    decode_steps: int = 0
    wall_s: float = 0.0
    tokens_per_s: float = 0.0
    ttft_mean_s: float = 0.0
    ttft_max_s: float = 0.0
    ttft_p50_s: float = 0.0
    ttft_p99_s: float = 0.0
    prefill_chunks: int = 0       # chunked-prefill jit invocations
    preemptions: int = 0          # paged: slots evicted on pool exhaustion
    peak_cache_bytes: int = 0     # persistent cache + transient prefill cache
    peak_used_blocks: int = 0     # paged: high-water mark of pool blocks


#: recorder counters that back ``ServeStats`` (``serve.<name>``)
_SERVE_COUNTERS = ("requests", "tokens", "prefill_calls", "decode_steps",
                   "prefill_chunks", "preemptions")


def _serve_marks(rec: Recorder) -> dict:
    """Snapshot the serve counters/histograms at run start so per-run
    stats can be derived by delta from a (possibly session-shared,
    possibly multi-run) recorder."""
    marks = {name: rec.counter(f"serve.{name}").value
             for name in _SERVE_COUNTERS}
    marks["ttft"] = rec.hist("serve.ttft_s").count
    marks["t0"] = rec.now()
    return marks


def _stats_from_recorder(rec: Recorder, marks: dict, *,
                         peak_cache_bytes: int = 0,
                         peak_used_blocks: int = 0) -> ServeStats:
    """``ServeStats`` as a thin view over the recorder aggregates: every
    counter/percentile is computed from the telemetry plane, so the stats
    surface and an exported event stream can never disagree."""
    d = {n: rec.counter(f"serve.{n}").value - marks[n]
         for n in _SERVE_COUNTERS}
    ttfts = rec.hist("serve.ttft_s").values[int(marks["ttft"]):]
    wall = rec.now() - marks["t0"]
    gen = d["tokens"]
    return ServeStats(
        requests=int(d["requests"]), generated_tokens=int(gen),
        prefill_calls=int(d["prefill_calls"]),
        decode_steps=int(d["decode_steps"]), wall_s=wall,
        tokens_per_s=gen / wall if wall > 0 else 0.0,
        ttft_mean_s=float(np.mean(ttfts)) if ttfts else 0.0,
        ttft_max_s=float(np.max(ttfts)) if ttfts else 0.0,
        ttft_p50_s=float(np.percentile(ttfts, 50)) if ttfts else 0.0,
        ttft_p99_s=float(np.percentile(ttfts, 99)) if ttfts else 0.0,
        prefill_chunks=int(d["prefill_chunks"]),
        preemptions=int(d["preemptions"]),
        peak_cache_bytes=peak_cache_bytes,
        peak_used_blocks=peak_used_blocks)


def _prefix_len(req: Request, family: str) -> int:
    """How many decoder positions ``req.embeds`` occupies: vlm prefix embeds
    sit in front of the prompt; encdec frames feed the encoder (zero)."""
    if req.embeds is None or family == "encdec":
        return 0
    return req.embeds.shape[1]


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


class _PrefillJob:
    """An in-flight chunked prefill: one request being fed chunk-by-chunk
    through a transient batch-1 cache, interleaved with decode steps."""
    __slots__ = ("req", "slot", "cache1", "items", "done", "logits",
                 "embeds", "emb_key")

    def __init__(self, req, slot, cache1, items, embeds, emb_key):
        self.req, self.slot, self.cache1 = req, slot, cache1
        self.items = items            # token id per decoder item (prefix
        self.done = 0                 # positions carry a placeholder 0 —
        self.logits = None            # the vlm runner swaps in embeds)
        self.embeds, self.emb_key = embeds, emb_key


class Engine:
    """Single-host continuous-batching engine over a ModelAPI."""

    def __init__(self, model_api, params, cfg: ServeCfg, seed: int = 0,
                 telemetry: Recorder | None = None,
                 donate: bool | None = None):
        self.api = model_api
        self.params = params
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self.last_stats = ServeStats()
        # aggregates stay on even without an injected recorder (last_stats
        # derives from them); the event plane is off unless one is passed
        self.tele = telemetry if telemetry is not None \
            else Recorder(enabled=False)
        self._prefill_jit: dict = {}      # (prompt_len, embeds_shape) -> fn
        self._chunk_jit: dict = {}        # (chunk, embeds_shape) -> fn
        self._prime = None                # lazy jit of api.prime_cross
        B, temp, eos, max_len = (cfg.max_batch, cfg.temperature, cfg.eos_id,
                                 cfg.max_len)
        self._paged = cfg.cache == "paged"
        if cfg.cache not in ("dense", "paged"):
            raise ValueError(f"cache={cfg.cache!r}; expected dense|paged")
        if self._paged:
            if model_api.init_paged_cache is None:
                raise ValueError("this model family has no paged-cache "
                                 "support (ModelAPI.init_paged_cache is None)")
            if getattr(model_api.cfg, "sliding_window", 0):
                raise ValueError(
                    "cache='paged' is incompatible with sliding-window "
                    "attention: the SWA ring buffer already bounds the cache "
                    "at window size — use cache='dense' for SWA archs")
            if max_len % cfg.page_block:
                raise ValueError(
                    f"max_len={max_len} must divide by page_block="
                    f"{cfg.page_block} so the gathered paged view matches "
                    "the dense cache extent (the bitwise parity contract)")
        # paged prefill is always chunked (whole-prompt writes need the full
        # dense row); dense engines opt in via prefill_chunk > 0
        self._chunk = (cfg.prefill_chunk if cfg.prefill_chunk > 0
                       else (cfg.page_block if self._paged else 0))
        self._pool_blocks = (cfg.pool_blocks if cfg.pool_blocks > 0
                             else B * (max_len // cfg.page_block) + 1)
        # Donating the cache/state lets XLA update the (large) KV buffers in
        # place each step; CPU ignores donation, so only request it off-CPU
        # by default.  The explicit override exists for the graph-lint
        # donation-audit, which lowers these jits on CPU purely to read the
        # aliasing decisions out of the module text.
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self._donate = donate = bool(donate)

        def sample(logits: Array, key: Array) -> Array:
            """(n, V) logits -> (n,) sampled tokens, on device."""
            if temp > 0:
                keys = jax.random.split(key, logits.shape[0])
                return jax.vmap(
                    lambda k, l: jax.random.categorical(k, l / temp)
                )(keys, logits).astype(jnp.int32)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def _advance(cache, state, logits, key):
            tok = sample(logits, key)
            pos = jnp.where(state["active"], state["pos"] + 1, state["pos"])
            rem = jnp.where(state["active"], state["rem"] - 1, state["rem"])
            done = (tok == eos) | (rem <= 0) | (pos + 1 >= max_len)
            finished = state["active"] & done
            tok = jnp.where(state["active"], tok, state["tok"])
            state = {"tok": tok, "pos": pos, "rem": rem,
                     "active": state["active"] & ~done}
            return cache, state, tok, finished

        def step_fn(params, cache, state, key):
            """Advance all slots one token.  Frozen (inactive) slots keep
            their position/budget; their sampled token is discarded."""
            logits, cache = model_api.decode_step(params, cache,
                                                  state["tok"], state["pos"])
            return _advance(cache, state, logits, key)

        def step_paged_fn(params, cache, state, table, key):
            logits, cache = model_api.decode_step_paged(
                params, cache, table, state["tok"], state["pos"])
            return _advance(cache, state, logits, key)

        def admit_fn(state, slot, logits, pos0, rem0, key):
            """Occupy ``slot``: sample the first token from the prefill
            logits and install the slot's counters."""
            tok0 = sample(logits, key)[0]
            done0 = (tok0 == eos) | (rem0 - 1 <= 0) | (pos0 + 1 >= max_len)
            state = {"tok": state["tok"].at[slot].set(tok0),
                     "pos": state["pos"].at[slot].set(pos0),
                     "rem": state["rem"].at[slot].set(rem0 - 1),
                     "active": state["active"].at[slot].set(~done0)}
            return state, tok0, done0

        def write_slot(cache, one, slot):
            """Scatter a batch-1 prefill cache into row ``slot`` of the
            shared cache (slot reuse: the freed row is simply overwritten)."""
            return jax.tree.map(
                lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                    c, o.astype(c.dtype), slot, axis=1), cache, one)

        self._step = jax.jit(step_fn,
                             donate_argnums=(1, 2) if donate else ())
        self._step_paged = jax.jit(step_paged_fn,
                                   donate_argnums=(1, 2) if donate else ())
        self._admit = jax.jit(admit_fn)
        self._write_slot = jax.jit(write_slot,
                                   donate_argnums=(0,) if donate else ())
        self._write_paged = jax.jit(
            lambda cache, one, row, slot: model_api.write_paged_slot(
                cache, one, row, slot),
            donate_argnums=(0,) if donate else ())
        self._B = B

    # Each distinct (prompt length, embeds shape) compiles its own prefill;
    # the memo is bounded (LRU-ish: oldest insertion evicted) so a long-lived
    # engine over naturally varying lengths cannot grow compile state without
    # bound.  Length-bucketing with right-padding would bound compiles harder
    # but is not exactness-preserving for SSM/conv states (pad tokens enter
    # the recurrence), so we keep exact per-length prefill.  Chunked prefill
    # (prefill_chunk > 0) sidesteps the whole issue: every prompt length
    # shares the one compiled chunk body, so the compile-cache cardinality is
    # ~one entry per chunk size (asserted in tests/test_paged_serving.py).
    _PREFILL_MEMO_MAX = 64

    def _prefill(self, req: Request):
        """Jitted whole-prompt prefill, cached per (length, embeds-shape)."""
        key = (len(req.prompt), None if req.embeds is None
               else tuple(req.embeds.shape))
        fn = self._prefill_jit.get(key)
        if fn is None:
            while len(self._prefill_jit) >= self._PREFILL_MEMO_MAX:
                self._prefill_jit.pop(next(iter(self._prefill_jit)))
            max_len = self.cfg.max_len
            if req.embeds is None:
                fn = jax.jit(lambda p, t: self.api.prefill(p, t, max_len))
            else:
                fn = jax.jit(
                    lambda p, t, e: self.api.prefill(p, t, max_len, e))
            self._prefill_jit[key] = fn
        toks = jnp.asarray([req.prompt], jnp.int32)
        if req.embeds is None:
            return fn(self.params, toks)
        return fn(self.params, toks, jnp.asarray(req.embeds))

    # --- chunked prefill ---------------------------------------------------

    def _chunk_runner(self, C: int, emb_key):
        """One compiled fn per (chunk size, embeds shape): scan ``decode_step``
        over a fixed-size padded chunk of a batch-1 cache.  Items beyond
        ``n_valid`` are computed then reverted (cache and logits keep their
        pre-step values), so every prompt length reuses the same program."""
        fn = self._chunk_jit.get((C, emb_key))
        if fn is not None:
            return fn
        api = self.api
        V = api.cfg.vocab_size

        if emb_key is None:
            def scan_chunk(params, cache, toks, pos0, n_valid):
                def body(carry, i):
                    cache, logits = carry
                    lg, c2 = api.decode_step(params, cache, toks[i][None],
                                             pos0 + i)
                    act = i < n_valid
                    cache = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                         c2, cache)
                    logits = jnp.where(act, lg, logits)
                    return (cache, logits), None
                init = (cache, jnp.zeros((1, V), jnp.float32))
                (cache, logits), _ = jax.lax.scan(body, init, jnp.arange(C))
                return logits, cache
        else:
            n_img = emb_key[1]          # vlm: items [0, n_img) are patches

            def scan_chunk(params, cache, toks, embeds, pos0, n_valid):
                emb_t = embeds[0]                               # (n_img, d)

                def body(carry, i):
                    cache, logits = carry
                    pos = pos0 + i
                    tok_x = api.embed_tokens(params, toks[i][None])[0]
                    img_x = emb_t[jnp.clip(pos, 0, n_img - 1)].astype(
                        tok_x.dtype)
                    x = jnp.where(pos < n_img, img_x, tok_x)
                    lg, c2 = api.decode_step_embed(params, cache, x[None],
                                                   pos)
                    act = i < n_valid
                    cache = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                         c2, cache)
                    logits = jnp.where(act, lg, logits)
                    return (cache, logits), None
                init = (cache, jnp.zeros((1, V), jnp.float32))
                (cache, logits), _ = jax.lax.scan(body, init, jnp.arange(C))
                return logits, cache

        fn = jax.jit(scan_chunk,
                     donate_argnums=(1,) if self._donate else ())
        while len(self._chunk_jit) >= self._PREFILL_MEMO_MAX:
            self._chunk_jit.pop(next(iter(self._chunk_jit)))
        self._chunk_jit[(C, emb_key)] = fn
        return fn

    def _start_job(self, req: Request, slot: int, family: str) -> _PrefillJob:
        prefix = _prefix_len(req, family)
        # re-admission after preemption re-feeds prompt + generated prefix:
        # exact recompute of the released cache rows
        items = [0] * prefix + list(req.prompt) + list(req.out)
        cache1 = self.api.init_cache(1, self.cfg.max_len)
        embeds = emb_key = None
        if req.embeds is not None:
            if family == "encdec":
                if self._prime is None:
                    self._prime = jax.jit(
                        lambda p, f: self.api.prime_cross(p, f))
                cache1["cross"] = self._prime(self.params,
                                              jnp.asarray(req.embeds))
            else:
                embeds = jnp.asarray(req.embeds)
                emb_key = tuple(embeds.shape)
        return _PrefillJob(req, slot, cache1, items, embeds, emb_key)

    def _advance_job(self, job: _PrefillJob):
        C = self._chunk
        sel = job.items[job.done: job.done + C]
        toks = np.zeros((C,), np.int32)
        toks[: len(sel)] = sel
        fn = self._chunk_runner(C, job.emb_key)
        args = (self.params, job.cache1, jnp.asarray(toks))
        if job.emb_key is not None:
            args += (job.embeds,)
        job.logits, job.cache1 = fn(*args, jnp.int32(job.done),
                                    jnp.int32(len(sel)))
        job.done += len(sel)

    def compile_cache_sizes(self) -> dict:
        """Compile-state cardinality (regression-tested: chunked prefill
        keeps this bounded under mixed-length traffic)."""
        return {"prefill": len(self._prefill_jit),
                "chunk": len(self._chunk_jit)}

    def prefill_compile_keys(self, prompt_lens, emb_key=None) -> set:
        """Abstract jit-cache keys admission would touch for these prompt
        lengths (the recompile-audit's view of the chunk plan): chunked
        prefill folds every length onto the one ``(chunk, embeds-shape)``
        runner ``_advance_job`` uses, legacy whole-prompt prefill pays one
        entry per distinct length (bounded only by ``_PREFILL_MEMO_MAX``
        eviction)."""
        if self._chunk > 0:
            return {(self._chunk, emb_key)} if prompt_lens else set()
        return {(int(n), emb_key) for n in prompt_lens}

    # --- scheduler ---------------------------------------------------------

    def run(self, requests: list[Request], on_retire=None) -> list[Request]:
        """Serve ``requests``; returns them in completion order.  Counters
        for the run land in ``self.last_stats`` (derived from the telemetry
        recorder) and, when an enabled recorder was injected, the full
        request-lifecycle event stream lands in ``self.tele``.

        Requests are admitted FIFO, gated by ``arrival_step`` against the
        decode-step clock (when the engine is fully idle the clock jumps to
        the next arrival).  ``on_retire(req)`` is called once per request the
        moment it finishes, letting consumers stream completions (e.g. the
        on-device ``DeviceSession`` feeding its replay buffer) without
        copying this loop.  The callback runs between jitted steps, so it may
        mutate ``self.params`` (live weight swaps) — in-flight slots keep
        decoding under whatever params the next step reads."""
        rec = self.tele
        marks = _serve_marks(rec)
        with rec.span("serve.run", cache=self.cfg.cache, max_batch=self._B,
                      requests=len(requests)):
            return self._run_scheduler(requests, on_retire, rec, marks)

    def _run_scheduler(self, requests: list[Request], on_retire, rec: Recorder,
                       marks: dict) -> list[Request]:
        cfg = self.cfg
        B = self._B
        paged = self._paged
        chunk = self._chunk
        family = getattr(self.api.cfg, "family", "")
        bs = cfg.page_block
        usable = self._pool_blocks - 1
        for r in requests:
            if family == "encdec" and r.embeds is None:
                raise ValueError(f"request {r.uid}: encdec serving needs "
                                 "encoder frames in Request.embeds")
            if len(r.prompt) + _prefix_len(r, family) + 1 > cfg.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt ({len(r.prompt)} tokens "
                    f"+ {_prefix_len(r, family)} prefix) does not fit "
                    f"max_len={cfg.max_len} with room to generate")
            if paged:
                worst = min(len(r.prompt) + _prefix_len(r, family)
                            + r.max_new_tokens, cfg.max_len)
                if -(-worst // bs) > usable:
                    raise ValueError(
                        f"request {r.uid}: worst case needs "
                        f"{-(-worst // bs)} blocks but the pool has "
                        f"{usable} usable — raise ServeCfg.pool_blocks")
        t0 = marks["t0"]
        prof = rec.profiler
        ctok = rec.counter("serve.tokens")
        cpre = rec.counter("serve.prefill_calls")
        cstep = rec.counter("serve.decode_steps")
        cchunk = rec.counter("serve.prefill_chunks")
        cpree = rec.counter("serve.preemptions")
        creq = rec.counter("serve.requests")
        results: list[Request] = []
        pending = collections.deque(r for r in requests
                                    if r.max_new_tokens > 0)
        slots: list[Request | None] = [None] * B
        if paged:
            cache = self.api.init_paged_cache(B, self._pool_blocks, bs)
            mgr = PagedKVManager(self._pool_blocks, bs, B, cfg.max_len)
        else:
            cache = self.api.init_cache(B, cfg.max_len)
            mgr = None
        persistent_bytes = _tree_bytes(cache)
        rec.set_gauge("serve.cache.persistent_bytes", persistent_bytes)
        if paged:
            rec.set_gauge("serve.kv.pool_blocks", self._pool_blocks)
        transient_shape = jax.eval_shape(
            lambda: self.api.init_cache(1, cfg.max_len))
        state = {"tok": jnp.zeros((B,), jnp.int32),
                 "pos": jnp.zeros((B,), jnp.int32),
                 "rem": jnp.zeros((B,), jnp.int32),
                 "active": jnp.zeros((B,), bool)}
        clock = 0
        pos_h = [0] * B               # host mirror of per-slot positions
        admit_seq = [0] * B           # admission order (preemption victims)
        seq = 0
        table_dev = jnp.asarray(mgr.table) if paged else None
        table_dirty = False
        job: _PrefillJob | None = None
        arr_wall: dict[int, float] = {}
        ft_wall: dict[int, float] = {}  # first-token wall per uid (TPOT)

        def _retire(req: Request):
            req.done = True
            creq.add(1)
            rec.instant("serve.request.retired", uid=req.uid,
                        tokens=len(req.out))
            ftw = ft_wall.pop(req.uid, None)
            if ftw is not None and len(req.out) > 1:
                rec.observe("serve.tpot_s",
                            (rec.now() - ftw) / (len(req.out) - 1))
            results.append(req)
            if on_retire is not None:
                on_retire(req)

        def _free(slot: int):
            nonlocal table_dirty
            slots[slot] = None
            if paged:
                mgr.release(slot)
                table_dirty = True

        def _finish_admit(jb_logits, slot, req, cache):
            """Sample the first token off the prefill logits and install the
            slot (shared between the legacy and chunked paths)."""
            nonlocal table_dirty, seq
            self.key, sub = jax.random.split(self.key)
            pos0 = len(req.prompt) + _prefix_len(req, family) + len(req.out)
            rem0 = req.max_new_tokens - len(req.out)
            state2, tok0, done0 = self._admit(state, slot, jb_logits,
                                              pos0, rem0, sub)
            tok0_h, done0_h = jax.device_get((tok0, done0))
            req.out.append(int(tok0_h))
            rec.instant("serve.request.admitted", uid=req.uid, slot=slot,
                        pos0=pos0)
            if req.ttft_s is None:
                now_ft = rec.now()
                req.ttft_s = now_ft - arr_wall.get(req.uid, t0)
                rec.observe("serve.ttft_s", req.ttft_s)
                rec.instant("serve.request.first_token", uid=req.uid,
                            ttft_s=req.ttft_s)
                ft_wall[req.uid] = now_ft
            ctok.add(1)
            if bool(done0_h):
                _retire(req)
                if paged:
                    mgr.release(slot)
                    table_dirty = True
            else:
                slots[slot] = req
                pos_h[slot] = pos0
                admit_seq[slot] = seq
                seq += 1
            return state2, cache

        def _preempt(victim: int):
            nonlocal table_dirty
            req = slots[victim]
            slots[victim] = None
            state["active"] = state["active"].at[victim].set(False)
            mgr.release(victim)
            table_dirty = True
            pending.appendleft(req)
            cpree.add(1)
            rec.instant("serve.request.preempted", uid=req.uid, slot=victim)

        # zero-budget requests complete immediately (matches the sequential
        # engine, whose generate loop never runs for them)
        for r in requests:
            if r.max_new_tokens <= 0:
                rec.instant("serve.request.queued", uid=r.uid,
                            prompt_len=len(r.prompt),
                            arrival_step=r.arrival_step)
                _retire(r)

        while pending or job is not None or any(s is not None for s in slots):
            now = rec.now()
            for r in pending:
                if r.arrival_step <= clock and r.uid not in arr_wall:
                    arr_wall[r.uid] = now
                    rec.instant("serve.request.queued", uid=r.uid,
                                prompt_len=len(r.prompt),
                                arrival_step=r.arrival_step)
            # --- admission -------------------------------------------------
            if chunk == 0:
                # legacy: fill every free slot with a whole-prompt prefill
                for slot in range(B):
                    while (slots[slot] is None and pending
                           and pending[0].arrival_step <= clock):
                        req = pending.popleft()
                        rec.observe("serve.queue_delay_s",
                                    rec.now() - arr_wall.get(req.uid, t0))
                        with rec.span("serve.prefill", uid=req.uid,
                                      prompt_len=len(req.prompt)):
                            logits, pcache = self._prefill(req)
                            cache = self._write_slot(cache, pcache, slot)
                        cpre.add(1)
                        state, cache = _finish_admit(logits, slot, req, cache)
            else:
                # chunked: start at most one job, advance it one chunk per
                # loop iteration — admissions interleave with decode steps
                if (job is None and pending
                        and pending[0].arrival_step <= clock):
                    slot = next((i for i in range(B) if slots[i] is None),
                                None)
                    if slot is not None:
                        req = pending[0]
                        total = (len(req.prompt) + _prefix_len(req, family)
                                 + len(req.out))
                        if not paged or mgr.admit(slot, total + 1):
                            pending.popleft()
                            rec.observe("serve.queue_delay_s",
                                        rec.now() - arr_wall.get(req.uid, t0))
                            job = self._start_job(req, slot, family)
                            cpre.add(1)
                            if paged:
                                table_dirty = True
                        # else: pool exhausted — back-pressure, retry after
                        # retirements free blocks
                if job is not None:
                    with rec.span("serve.prefill_chunk", uid=job.req.uid,
                                  done=job.done):
                        self._advance_job(job)
                    cchunk.add(1)
                    if job.done == len(job.items):
                        if paged:
                            row = jnp.asarray(mgr.table[job.slot])
                            cache = self._write_paged(cache, job.cache1, row,
                                                      job.slot)
                        else:
                            cache = self._write_slot(cache, job.cache1,
                                                     job.slot)
                        state, cache = _finish_admit(job.logits, job.slot,
                                                     job.req, cache)
                        job = None
            # --- lock-step decode over all active slots --------------------
            if not any(s is not None for s in slots):
                if (job is None and pending
                        and pending[0].arrival_step > clock):
                    clock = pending[0].arrival_step   # idle: jump ahead
                continue
            if paged:
                # back every slot's next write position with a real block;
                # on exhaustion evict the newest admission (recompute later)
                for slot in sorted(range(B), key=lambda i: admit_seq[i]):
                    if slots[slot] is None:
                        continue
                    while not mgr.ensure(slot, pos_h[slot]):
                        victims = [i for i in range(B)
                                   if slots[i] is not None]
                        victim = max(victims, key=lambda i: admit_seq[i])
                        _preempt(victim)
                        if victim == slot:
                            break
                if table_dirty:
                    table_dev = jnp.asarray(mgr.table)
                    table_dirty = False
                if not any(s is not None for s in slots):
                    continue
                rec.set_gauge("serve.kv.used_blocks", mgr.used_blocks)
            self.key, sub = jax.random.split(self.key)
            if prof is not None:
                # one-shot compile-vs-run split (AOT lower+compile timing
                # and memory_analysis gauges), behind --profile-trace only
                if paged:
                    prof.compile_split("serve.decode_step", self._step_paged,
                                       self.params, cache, state, table_dev,
                                       sub)
                else:
                    prof.compile_split("serve.decode_step", self._step,
                                       self.params, cache, state, sub)
            n_act = sum(1 for s in slots if s is not None)
            with rec.span("serve.decode_step", step=clock, active=n_act):
                if paged:
                    cache, state, tok, finished = self._step_paged(
                        self.params, cache, state, table_dev, sub)
                else:
                    cache, state, tok, finished = self._step(
                        self.params, cache, state, sub)
                # the one per-step host transfer telemetry piggybacks on
                tok_h, fin_h = jax.device_get((tok, finished))
            cstep.add(1)
            clock += 1
            for slot, req in enumerate(slots):
                if req is None:
                    continue
                req.out.append(int(tok_h[slot]))
                ctok.add(1)
                pos_h[slot] += 1
                if bool(fin_h[slot]):
                    _retire(req)
                    _free(slot)

        peak_bytes = persistent_bytes
        if cpre.value > marks["prefill_calls"]:
            peak_bytes += _tree_bytes(transient_shape)
        if paged:
            rec.set_gauge("serve.kv.used_blocks", mgr.used_blocks)
        if prof is not None:
            prof.live_buffer_gauges("serve.live")
        self.last_stats = _stats_from_recorder(
            rec, marks, peak_cache_bytes=peak_bytes,
            peak_used_blocks=mgr.peak_used_blocks if paged else 0)
        return results


class SequentialEngine:
    """The original strictly sequential loop: one slot at a time, a fresh
    cache per request, per-token Python prefill, and a host argmax
    round-trip per generated token.  Kept as the A/B baseline — the
    continuous engine must beat this in tokens/s and match it
    token-for-token at any ``max_batch`` (the paged-serving property tests
    use this engine as their oracle)."""

    def __init__(self, model_api, params, cfg: ServeCfg, seed: int = 0,
                 telemetry: Recorder | None = None):
        self.api = model_api
        self.params = params
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self.last_stats = ServeStats()
        self.tele = telemetry if telemetry is not None \
            else Recorder(enabled=False)
        self._decode = jax.jit(
            lambda p, c, t, pos: model_api.decode_step(p, c, t, pos))

    def _prefill_one(self, cache, slot: int, prompt: Sequence[int]):
        """Feed a prompt token-by-token into one batch slot."""
        toks = list(prompt)
        logits = None
        for pos, t in enumerate(toks):
            tok_vec = self._slot_tokens(slot, t)
            logits, cache = self._decode(self.params, cache, tok_vec,
                                         jnp.int32(pos))
        return cache, logits, len(toks)

    def _slot_tokens(self, slot: int, tok: int) -> Array:
        v = np.zeros((self.cfg.max_batch,), np.int32)
        v[slot] = tok
        return jnp.asarray(v)

    def run(self, requests: list[Request], on_retire=None) -> list[Request]:
        rec = self.tele
        marks = _serve_marks(rec)
        with rec.span("serve.run", cache="sequential",
                      max_batch=self.cfg.max_batch, requests=len(requests)):
            results = self._run_waves(requests, on_retire, rec, marks["t0"])
        self.last_stats = _stats_from_recorder(rec, marks)
        return results

    def _run_waves(self, requests: list[Request], on_retire, rec: Recorder,
                   t0: float) -> list[Request]:
        ctok = rec.counter("serve.tokens")
        cstep = rec.counter("serve.decode_steps")
        creq = rec.counter("serve.requests")
        pending = list(requests)
        results = []
        while pending:
            active = pending[: self.cfg.max_batch]
            pending = pending[len(active):]
            for slot, req in enumerate(active):
                # a fresh cache per *request*, not per wave: decode_step
                # advances every batch row, so a wave-shared cache lets one
                # request's decode pollute the recurrent (SSM/conv) state
                # the next slot's prefill assumes starts at zero — KV
                # attention masks hide this, recurrences do not
                cache = self.api.init_cache(self.cfg.max_batch,
                                            self.cfg.max_len)
                cache, logits, pos = self._prefill_one(cache, slot, req.prompt)
                for _ in range(req.max_new_tokens):
                    row = logits[slot]
                    if self.cfg.temperature > 0:
                        self.key, sub = jax.random.split(self.key)
                        # per-token sync is the point of this A/B baseline:
                        # it measures what Engine's batched device_get avoids
                        tok = int(jax.random.categorical(  # repro-lint: disable=jit-purity
                            sub, row / self.cfg.temperature))
                    else:
                        tok = int(jnp.argmax(row))  # repro-lint: disable=jit-purity
                    req.out.append(tok)
                    ctok.add(1)
                    if req.ttft_s is None:
                        req.ttft_s = rec.now() - t0
                        rec.observe("serve.ttft_s", req.ttft_s)
                    if tok == self.cfg.eos_id or pos + 1 >= self.cfg.max_len:
                        break
                    logits, cache = self._decode(
                        self.params, cache, self._slot_tokens(slot, tok),
                        jnp.int32(pos))
                    cstep.add(1)
                    pos += 1
                req.done = True
                creq.add(1)
                rec.instant("serve.request.retired", uid=req.uid,
                            tokens=len(req.out))
                results.append(req)
                if on_retire is not None:
                    on_retire(req)
        return results
