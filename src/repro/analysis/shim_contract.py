"""shim-contract: deprecation shims in ``launch/`` must only re-export.

A *shim* is a ``launch/`` module with a module-level ``__getattr__`` that
emits a ``DeprecationWarning`` — its job is to forward old entry-point
names to their new homes (``repro.api`` etc.) and nothing else.  A shim
that imports ``repro.*`` at module top level defeats the point: importing
the shim (e.g. for ``--help`` in docs checks, or transitively via the
package) drags in jax and the heavy runtime even when no forwarded name
is touched, and any env-var setup the shim does (``XLA_FLAGS``,
``LIBTPU_INIT_ARGS``) happens *after* the library is already imported.

The rule builds a top-level import graph per shim and flags any
``repro.*`` import outside a function body, except ``repro.configs*``
(pure-dataclass config tables, safe and cheap) and ``repro.analysis*``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, FileContext, rule

LAUNCH_SCOPE = "src/repro/launch/"
#: top-level imports of these prefixes are allowed even in shims
_ALLOWED_PREFIXES = ("repro.configs", "repro.analysis")


def _is_shim(tree: ast.Module) -> bool:
    """Module-level ``__getattr__`` that raises a DeprecationWarning."""
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == "__getattr__":
            for inner in ast.walk(node):
                if isinstance(inner, ast.Name) and \
                        inner.id == "DeprecationWarning":
                    return True
    return False


def _top_level_repro_imports(tree: ast.Module):
    """(lineno, dotted_module) for each module-scope ``repro.*`` import."""
    for node in tree.body:
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "repro" or alias.name.startswith("repro."):
                    yield node.lineno, alias.name
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod == "repro" or mod.startswith("repro."):
                if mod == "repro":
                    # `from repro import api` names the submodule in the
                    # alias, not the module field
                    for alias in node.names:
                        yield node.lineno, f"repro.{alias.name}"
                else:
                    yield node.lineno, mod


@rule("shim-contract",
      doc="launch/ deprecation shims must only re-export: no top-level "
          "repro.* imports beyond configs")
def check_shims(ctx: FileContext):
    if not ctx.rel.startswith(LAUNCH_SCOPE):
        return
    if not _is_shim(ctx.tree):
        return
    for lineno, mod in _top_level_repro_imports(ctx.tree):
        if any(mod == p or mod.startswith(p + ".")
               for p in _ALLOWED_PREFIXES):
            continue
        yield Finding(
            "shim-contract", ctx.rel, lineno,
            f"deprecation shim imports {mod} at module top level — move it "
            "into the function/__getattr__ that needs it so importing the "
            "shim stays side-effect-free")
