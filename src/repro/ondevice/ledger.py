"""Per-layer activation-memory ledger (paper eq. 5 / Table 1 / Table 4).

For a given architecture and training shape (B, S) the ledger enumerates
every ASI-compressed linear site in the fine-tuned tail — in the exact order
the forward pass executes them, which is also the order the calibration
capture records them — and prices the activation storage each training mode
pays between forward and backward:

* **vanilla**   — the full input activation, M·K elements (``M = B·S``
  tokens, K input features; per-expert buffers for MoE sites);
* **HOSVD_ε / ASI-shortcut** — the rank-r factor pair, (M+K)·r elements
  (``asi.matrix_storage_elems``; per-expert stacks for grouped sites).
  Storage is identical between the two at equal rank — what separates them
  is the per-step decomposition cost, so the ledger also carries both
  overhead-FLOPs columns (HOSVD pays a full SVD every step, eq. 11/13; ASI
  pays one warm-started subspace iteration, eq. 14).

Beyond the closed-form accounting the ledger offers two measured views:
``measured_step_memory`` compiles the actual training step via
``jax.jit(...).lower().compile().memory_analysis()`` (works for every model
family in ``models/registry.py``), and ``measured_site_residual_bytes``
materializes one site's vjp residuals eagerly and weighs them — the
ground-truth counterpart the benchmark gates its analytical/measured gap on.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import flops as flops_lib
from repro.core.asi import MatrixASIState, matrix_storage_elems
from repro.models import build_model

BYTES_PER_ELEM = 4      # factors/activations are stored in fp32


# ---------------------------------------------------------------------------
# site enumeration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One compressed-linear site: ``name`` matches the ``rank_plan`` paths
    of ``init_asi_state``; enumeration order matches the forward pass."""
    name: str
    kind: str            # "matrix" | "grouped"
    k: int               # input features
    n: int               # output features
    tokens: int          # matrix: M = B*S; grouped: per-expert capacity T
    groups: int = 0      # E for grouped sites, 0 otherwise


def model_seq_len(cfg: ModelConfig, seq_len: int) -> int:
    """Sequence length the tail actually sees (VLM prepends image tokens)."""
    if cfg.family == "vlm":
        return seq_len + cfg.n_img_tokens
    return seq_len


def _ffn_sites(cfg: ModelConfig, at: str, m: int) -> list[SiteSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    names = ("gate", "up", "down") if cfg.act == "silu" else ("up", "down")
    return [SiteSpec(f"{at}/ffn/{nme}", "matrix",
                     *((ff, d) if nme == "down" else (d, ff)), m)
            for nme in names]


def _moe_sites(cfg: ModelConfig, at: str, batch: int, seq: int) -> list[SiteSpec]:
    from repro.models.moe import _capacity
    t = batch * _capacity(cfg, seq)           # per-expert tokens (B rows x cap)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return [SiteSpec(f"{at}/ffn/gate", "grouped", d, ff, t, e),
            SiteSpec(f"{at}/ffn/up", "grouped", d, ff, t, e),
            SiteSpec(f"{at}/ffn/down", "grouped", ff, d, t, e)]


def iter_asi_sites(cfg: ModelConfig, batch: int, seq_len: int) -> list[SiteSpec]:
    """All compressed sites of the fine-tuned tail, forward-trace order."""
    s = model_seq_len(cfg, seq_len)
    m = batch * s
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    sites: list[SiteSpec] = []
    if cfg.family == "encdec":
        tail = min(cfg.asi_last_k, cfg.n_layers)
        for i in range(cfg.n_layers - tail, cfg.n_layers):
            at = f"layer_{i}"
            sites += [SiteSpec(f"{at}/self/wq", "matrix", d, h * hd, m),
                      SiteSpec(f"{at}/self/wk", "matrix", d, kv * hd, m),
                      SiteSpec(f"{at}/self/wv", "matrix", d, kv * hd, m),
                      SiteSpec(f"{at}/self/wo", "matrix", h * hd, d, m),
                      SiteSpec(f"{at}/cross/wq", "matrix", d, h * hd, m),
                      SiteSpec(f"{at}/cross/wo", "matrix", h * hd, d, m),
                      SiteSpec(f"{at}/mlp/up", "matrix", d, cfg.d_ff, m),
                      SiteSpec(f"{at}/mlp/down", "matrix", cfg.d_ff, d, m)]
        return sites

    from repro.models.transformer import n_periods, period_pattern
    specs = period_pattern(cfg)
    np_ = n_periods(cfg)
    tail = min(cfg.asi_last_k, np_)
    for i in range(np_ - tail, np_):
        for j, (mixer, ffn) in enumerate(specs):
            at = f"period_{i}/sub{j}"
            if mixer == "attn":
                sites += [SiteSpec(f"{at}/mixer/wq", "matrix", d, h * hd, m),
                          SiteSpec(f"{at}/mixer/wk", "matrix", d, kv * hd, m),
                          SiteSpec(f"{at}/mixer/wv", "matrix", d, kv * hd, m),
                          SiteSpec(f"{at}/mixer/wo", "matrix", h * hd, d, m)]
            else:
                d_in_proj = 2 * cfg.ssm_d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
                sites += [SiteSpec(f"{at}/mixer/in_proj", "matrix",
                                   d, d_in_proj, m),
                          SiteSpec(f"{at}/mixer/out_proj", "matrix",
                                   cfg.ssm_d_inner, d, m)]
            if ffn == "dense":
                sites += _ffn_sites(cfg, at, m)
            elif ffn == "moe":
                sites += _moe_sites(cfg, at, batch, s)
    return sites


# ---------------------------------------------------------------------------
# the ledger
# ---------------------------------------------------------------------------

def site_vanilla_elems(site: SiteSpec) -> int:
    if site.kind == "grouped":
        return site.groups * site.tokens * site.k
    return site.tokens * site.k


def site_compressed_elems(site: SiteSpec, rank: int) -> int:
    """Factor storage at rank r — identical for ASI and fixed-rank HOSVD."""
    if site.kind == "grouped":
        return site.groups * matrix_storage_elems(site.tokens, site.k, rank)
    return matrix_storage_elems(site.tokens, site.k, rank)


def _site_overheads(site: SiteSpec, rank: int) -> tuple[int, int]:
    """(asi, hosvd) per-step decomposition FLOPs for this site."""
    g = max(site.groups, 1)
    ld = flops_lib.LinearDims(site.tokens, site.k, site.n)
    asi = g * flops_lib.linear_asi_overhead_flops(ld, rank)
    # HOSVD_eps: full SVD of the (M, K) activation every step
    hosvd = g * max(site.tokens, site.k) ** 2 * min(site.tokens, site.k)
    return asi, hosvd


@dataclasses.dataclass(frozen=True)
class LedgerRow:
    site: SiteSpec
    rank: int
    vanilla_bytes: int
    compressed_bytes: int        # HOSVD_eps == ASI factor storage at rank
    asi_overhead_flops: int
    hosvd_overhead_flops: int

    @property
    def reduction(self) -> float:
        return self.vanilla_bytes / max(self.compressed_bytes, 1)


@dataclasses.dataclass(frozen=True)
class Ledger:
    arch: str
    batch: int
    seq_len: int
    rows: tuple

    @property
    def vanilla_total_bytes(self) -> int:
        return sum(r.vanilla_bytes for r in self.rows)

    @property
    def asi_total_bytes(self) -> int:
        return sum(r.compressed_bytes for r in self.rows)

    @property
    def reduction(self) -> float:
        return self.vanilla_total_bytes / max(self.asi_total_bytes, 1)

    def fits(self, budget_mb: float) -> bool:
        return self.asi_total_bytes <= budget_mb * 2 ** 20

    def min_bytes(self) -> int:
        """Floor: every site at rank 1 — below this no plan exists."""
        return sum(site_compressed_elems(r.site, 1) * BYTES_PER_ELEM
                   for r in self.rows)

    def bytes_for(self, ranks: dict) -> int:
        """Re-price the tail under a planner rank assignment
        ({site name -> rank}; missing sites keep their ledger rank)."""
        return sum(
            site_compressed_elems(r.site, ranks.get(r.site.name, r.rank))
            * BYTES_PER_ELEM for r in self.rows)

    def summary(self) -> dict:
        return {
            "arch": self.arch, "batch": self.batch, "seq_len": self.seq_len,
            "n_sites": len(self.rows),
            "vanilla_mb": round(self.vanilla_total_bytes / 2 ** 20, 3),
            "asi_mb": round(self.asi_total_bytes / 2 ** 20, 4),
            "reduction": round(self.reduction, 1),
        }


def build_ledger(cfg: ModelConfig, batch: int, seq_len: int,
                 rank_plan: dict | None = None) -> Ledger:
    """Analytical ledger for one (architecture, training shape).

    ``rank_plan`` (site path -> rank) prices a planner assignment; default is
    the uniform ``cfg.asi_rank``.
    """
    plan = rank_plan or {}
    rows = []
    for site in iter_asi_sites(cfg, batch, seq_len):
        rank = int(plan.get(site.name, cfg.asi_rank))
        asi_fl, ho_fl = _site_overheads(site, rank)
        rows.append(LedgerRow(
            site=site, rank=rank,
            vanilla_bytes=site_vanilla_elems(site) * BYTES_PER_ELEM,
            compressed_bytes=site_compressed_elems(site, rank) * BYTES_PER_ELEM,
            asi_overhead_flops=asi_fl, hosvd_overhead_flops=ho_fl))
    return Ledger(arch=cfg.name, batch=batch, seq_len=seq_len,
                  rows=tuple(rows))


def ledgers_for_registry(batch: int, seq_len: int, reduced: bool = True) -> dict:
    """One ledger per registered architecture (every model family)."""
    from repro.configs.registry import ARCHS, get_config
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        out[arch] = build_ledger(cfg.replace(compress="asi"), batch, seq_len)
    return out


# ---------------------------------------------------------------------------
# measured views
# ---------------------------------------------------------------------------

def _batch_struct(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    d = jnp.dtype(cfg.dtype)
    bs = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
          "targets": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    if cfg.family == "encdec":
        bs["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_len, cfg.d_model), d)
    elif cfg.family == "vlm":
        bs["embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_img_tokens, cfg.d_model), d)
    return bs


def measured_step_memory(cfg: ModelConfig, batch: int, seq_len: int,
                         rank_plan: dict | None = None) -> dict | None:
    """Compile the training-step gradient program and read XLA's memory
    analysis (argument/temp bytes).  ``temp_size_in_bytes`` upper-bounds the
    live activation storage plus workspace; returns None when the backend
    does not expose the analysis.  Works for every registry family — the
    step is the same ``api.loss`` the trainer differentiates.
    """
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(api.init, key)
    asi_struct = (jax.eval_shape(partial(api.init_asi, rank_plan=rank_plan),
                                 key) if cfg.compress != "none" else {})

    def step(params, batch_, asi):
        (loss, _), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch_, asi)
        return loss, grads

    from repro.telemetry.memstats import LEDGER_FIELDS, stats_or_none
    compiled = jax.jit(step).lower(
        params_struct, _batch_struct(cfg, batch, seq_len), asi_struct
    ).compile()
    return stats_or_none(compiled, LEDGER_FIELDS)


def measured_site_residual_bytes(tokens: int, k: int, rank: int,
                                 n: int = 64, compressed: bool = True) -> int:
    """Ground truth for one site: the activation-derived arrays actually
    saved between forward and backward.

    * ASI — run the site's ``custom_vjp`` forward rule and weigh the
      residuals it returns minus the weight (that tuple IS the saved set;
      in a jitted step XLA frees the full input once only these survive):
      the (M, r) + (K, r) factor pair.
    * dense — the autodiff VJP of ``y = x @ w`` needs x for dW, so the
      saved set is the (M, K) input itself; weigh it off the eager vjp
      closure.
    """
    from repro.core import compressed_linear as cl
    x = jnp.zeros((tokens, k), jnp.float32)
    w = jnp.zeros((k, n), jnp.float32)
    if compressed:
        st = MatrixASIState.init(jax.random.PRNGKey(0), k, rank)
        ccfg = cl.LinearCompressionCfg(rank=rank, backend="reference")
        _, res = cl._asi_linear_vjp_fwd(ccfg, x, w, None, st)
        return sum(v.size * v.dtype.itemsize
                   for v in jax.tree.leaves(res)
                   if hasattr(v, "shape") and v is not w)
    _, vjp = jax.vjp(lambda w_: jnp.sum(cl.dense_linear(x, w_) ** 2), w)
    return sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(vjp)
               if hasattr(v, "shape") and tuple(v.shape) == (tokens, k))
