"""Mesh construction for every launcher (train / serve / dryrun).

Functions, not module-level constants — importing this module never touches
jax device state (required: smoke tests must see 1 device; only dryrun.py
sets the 512-placeholder-device XLA flag before importing jax).

Axis conventions (shared with ``repro.parallel``):

* ``data``  — batch / FSDP shards travel here.
* ``model`` — tensor-parallel shards (heads, ffn, vocab, experts).
* ``pod``   — optional leading axis for cross-pod data parallelism.

``make_layout_mesh`` is the entry the ``--layout {dp,fsdp,tp}`` training
flag uses: it folds all visible devices into a (data, model) mesh whose
split matches the layout, so reduced CPU runs (with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and real
accelerator runs take the same code path.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi-pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Generic helper for reduced meshes in tests (e.g. (2,2) on 4 host
    devices)."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_layout_mesh(layout: str = "dp",
                     shape: tuple[int, int] | None = None) -> Mesh:
    """(data, model) mesh over the visible devices, split to fit ``layout``.

    Without an explicit ``shape``: ``dp``/``fsdp`` put every device on the
    data axis (model=1 — fsdp shards weights over the *batch* axes, so it
    needs no model axis either); ``tp`` puts every device on the model axis.
    A ``shape`` override (e.g. ``(2, 4)`` from ``--mesh 2,4``) wins, letting
    tests exercise mixed data x model meshes.
    """
    n = len(jax.devices())
    if shape is None:
        shape = (1, n) if layout == "tp" else (n, 1)
    if int(np.prod(shape)) > n:
        raise ValueError(f"mesh shape {shape} needs {int(np.prod(shape))} "
                         f"devices; only {n} visible")
    return make_mesh(tuple(shape), ("data", "model"))
