"""Activation Subspace Iteration (ASI) — the paper's core contribution.

Two variants, both with warm-started single-step subspace iteration
(paper Algorithm 1 for the 4-mode Tucker case, Algorithm 2 / Appendix A.1
for the matrix case used on LLM linear layers, exactly PowerSGD-style):

* ``matrix_asi_step``  — X ∈ R^{M×K} ≈ P̂ Qᵀ with P̂ ∈ R^{M×r} orthonormal,
  Q ∈ R^{K×r}.  Storage M·r + K·r instead of M·K.
* ``tucker_asi_step``  — A ∈ R^{D1×…×Dn} ≈ S ×₁ U₁ … ×ₙ Uₙ with per-mode
  warm-started factors U_m ∈ R^{D_m×r_m} and core S ∈ R^{r1×…×rn}.

The warm start ("V = A_mᵀ U_m^{(t-1)}") is the paper's key trick: activations
drift slowly between steps (Lipschitz-1 nonlinearities + tiny updates), so the
previous subspace is a near-fixed-point initialization and ONE iteration
suffices.  State is threaded explicitly (JAX is functional).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def orthonormalize(p: Array) -> Array:
    """Orthonormalize the columns of ``p`` (M, r).

    The paper uses Gram-Schmidt (Θ(r³) beyond the M·r² work); reduced QR is the
    numerically-robust TPU-native equivalent and has the same asymptotic cost.
    """
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q.astype(p.dtype)


def _init_factor(key: Array, shape: tuple[int, ...], dtype) -> Array:
    """i.i.d. standard-normal init used at t=0 (Algorithm 1/2)."""
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Matrix (2-mode) ASI — used for LLM linear layers (paper Table 4, rank 20).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatrixASIState:
    """Warm-start state for one linear layer: the K×r co-factor Q."""
    q: Array          # (K, r) — used as V at the next step

    @staticmethod
    def init(key: Array, k: int, rank: int, dtype=jnp.float32) -> "MatrixASIState":
        return MatrixASIState(q=_init_factor(key, (k, rank), dtype))


def matrix_asi_step(x: Array, state: MatrixASIState) -> tuple[Array, Array, MatrixASIState]:
    """One warm-started subspace iteration on X (M, K).

    Returns (P̂, Q, new_state) with X ≈ P̂ Qᵀ; new_state carries Q for warm start.
    Algorithm 2 of the paper:  P = X·Q_{t-1};  P̂ = orth(P);  Q = Xᵀ·P̂.
    """
    v = state.q                                   # warm start (K, r)
    p = x @ v                                     # (M, r)   2·M·K·r FLOPs
    p_hat = orthonormalize(p)                     # (M, r)   Θ(M·r² + r³)
    q = x.T @ p_hat                               # (K, r)   2·M·K·r FLOPs
    return p_hat, q, MatrixASIState(q=q)


def matrix_reconstruct(p_hat: Array, q: Array) -> Array:
    return p_hat @ q.T


# ---------------------------------------------------------------------------
# Tucker (n-mode) ASI — paper Algorithm 1 (4 modes for conv activations).
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TuckerASIState:
    """Per-mode factors U_m (D_m, r_m), stored as a tuple (static length)."""
    factors: tuple[Array, ...]

    @staticmethod
    def init(key: Array, dims: Sequence[int], ranks: Sequence[int],
             dtype=jnp.float32) -> "TuckerASIState":
        keys = jax.random.split(key, len(dims))
        fs = tuple(
            _init_factor(k, (d, min(r, d)), dtype)
            for k, d, r in zip(keys, dims, ranks)
        )
        return TuckerASIState(factors=fs)


def _unfold(a: Array, mode: int) -> Array:
    """Mode-m unfolding: (D_m, prod(other dims))."""
    perm = (mode,) + tuple(i for i in range(a.ndim) if i != mode)
    return jnp.transpose(a, perm).reshape(a.shape[mode], -1)


def _mode_dot(a: Array, m: Array, mode: int) -> Array:
    """n-mode product A ×_mode M with M (Q, D_mode) -> result dim Q on `mode`."""
    moved = jnp.moveaxis(a, mode, -1)
    out = moved @ m.T
    return jnp.moveaxis(out, -1, mode)


def tucker_asi_step(a: Array, state: TuckerASIState
                    ) -> tuple[Array, tuple[Array, ...], TuckerASIState]:
    """Paper Algorithm 1: one warm-started subspace iteration per mode.

    For each mode m:  V = A_mᵀ U_m^{(t-1)};  U_m = orth(A_m V).
    Core: S = A ×₁ U₁ᵀ ×₂ U₂ᵀ … ×ₙ Uₙᵀ.
    Returns (core, factors, new_state).
    """
    new_factors = []
    for m in range(a.ndim):
        a_m = _unfold(a, m)                       # (D_m, P_m)
        u_prev = state.factors[m]                 # (D_m, r_m)
        v = a_m.T @ u_prev                        # warm start  (P_m, r_m)
        u = orthonormalize(a_m @ v)               # (D_m, r_m)
        new_factors.append(u)
    core = a
    for m, u in enumerate(new_factors):
        core = _mode_dot(core, u.T, m)            # project: dim D_m -> r_m
    factors = tuple(new_factors)
    return core, factors, TuckerASIState(factors=factors)


def tucker_reconstruct(core: Array, factors: Sequence[Array]) -> Array:
    a = core
    for m, u in enumerate(factors):
        a = _mode_dot(a, u, m)
    return a


# ---------------------------------------------------------------------------
# Memory accounting (paper eq. 5 / eq. 19).
# ---------------------------------------------------------------------------

def tucker_storage_elems(dims: Sequence[int], ranks: Sequence[int]) -> int:
    """Eq. 5:  M_i = prod(r_m) + Σ_m D_m·r_m   (elements, not bytes)."""
    ranks = [min(r, d) for r, d in zip(ranks, dims)]
    prod = 1
    for r in ranks:
        prod *= r
    return prod + sum(d * r for d, r in zip(dims, ranks))


def matrix_storage_elems(m: int, k: int, rank: int) -> int:
    return (m + k) * rank


def compression_ratio(dims: Sequence[int], ranks: Sequence[int]) -> float:
    """Eq. 19:  R_C = prod(D) / M_i."""
    full = 1
    for d in dims:
        full *= d
    return full / tucker_storage_elems(dims, ranks)
