"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately the *naive* formulations — materialized score matrices,
full reconstruction — so kernel tests compare an optimized implementation
against straight-line math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def matmul_sketch_ref(x: Array, w: Array, v: Array):
    """Fused forward+sketch oracle:  Y = X·W,  P = X·V  (fp32 accumulation)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    p = jnp.dot(x, v, preferred_element_type=jnp.float32)
    return y.astype(x.dtype), p.astype(jnp.float32)


def matmul_grad_sketch_ref(g: Array, w: Array, p_hat: Array):
    """Fused backward oracle:  g_x = g·Wᵀ,  R = P̂ᵀ·g  (fp32 accumulation).

    ``w`` is (K, N) — the forward weight layout, transposed inside — and the
    low-rank weight gradient is recovered outside as g_w = Q·R."""
    g_x = jnp.dot(g, w.T, preferred_element_type=jnp.float32)
    r = jnp.dot(p_hat.T, g, preferred_element_type=jnp.float32)
    return g_x.astype(g.dtype), r.astype(jnp.float32)


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 0) -> Array:
    """Naive attention.  q (BH, Sq, d), k/v (BH, Skv, d)."""
    sq, skv = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned positions
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def ssd_ref(x: Array, dt: Array, a: Array, b: Array, c: Array):
    """Sequential SSD recurrence oracle.

    x (BH, S, P), dt (BH, S), a (BH,), b/c (BH, S, N).
    Returns (y (BH, S, P), final state (BH, P, N)).
    """
    bh, s, p = x.shape
    n = b.shape[-1]

    def step(h, xs):
        xt, dtt, bt, ct = xs          # (BH,P), (BH,), (BH,N), (BH,N)
        da = jnp.exp(dtt * a)         # (BH,)
        h = h * da[:, None, None] + jnp.einsum(
            "z,zp,zn->zpn", dtt, xt.astype(jnp.float32), bt.astype(jnp.float32))
        y = jnp.einsum("zn,zpn->zp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((bh, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
