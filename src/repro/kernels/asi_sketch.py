"""Fused forward-matmul + ASI-sketch Pallas TPU kernel.

ASI's per-step cost on TPU is not FLOPs (the sketch is a tall-skinny matmul,
cheap on the MXU) but HBM traffic: unfused, X (M, K) is streamed from HBM once
for Y = X·W and again for P = X·V.  This kernel computes both in ONE pass:
each (bm, bk) VMEM tile of X feeds the Y-accumulator and, on the n == 0 grid
column, the P-accumulator.  Arithmetic intensity of the sketch becomes
infinite (zero extra HBM reads), which is the TPU-native formulation of the
paper's Algorithm 2 (see DESIGN.md §3).

Blocking: (bm, bn, bk) multiples of 128 keep the 128x128 MXU systolic array
full; the r (rank) dimension is zero-padded to the lane width by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(x_ref, w_ref, v_ref, y_ref, p_ref, acc_ref, pacc_ref, *, nk: int):
    k = pl.program_id(2)
    n = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(n == 0)
    def _sketch():
        @pl.when(k == 0)
        def _pinit():
            pacc_ref[...] = jnp.zeros_like(pacc_ref)
        pacc_ref[...] += jnp.dot(x, v_ref[...],
                                 preferred_element_type=jnp.float32)
        @pl.when(k == nk - 1)
        def _pout():
            p_ref[...] = pacc_ref[...]

    @pl.when(k == nk - 1)
    def _out():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_sketch(x: Array, w: Array, v: Array, *, bm: int = 128,
                  bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """Returns (Y = X·W in x.dtype, P = X·V in fp32).

    x (M, K), w (K, N), v (K, r).  Dims are zero-padded to block multiples;
    padding contributes exact zeros so results are unaffected.
    """
    m, k = x.shape
    _, n = w.shape
    r = v.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    pr = (-r) % 128 if r % 128 else 0
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk or pr:
        v = jnp.pad(v, ((0, pk), (0, pr)))
    mm, nn, kk = x.shape[0], w.shape[1], x.shape[1]
    rr = v.shape[1]
    nk = kk // bk
    grid = (mm // bm, nn // bn, nk)

    y, p = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk_: (i, kk_)),
            pl.BlockSpec((bk, bn), lambda i, j, kk_: (kk_, j)),
            pl.BlockSpec((bk, rr), lambda i, j, kk_: (kk_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk_: (i, j)),
            pl.BlockSpec((bm, rr), lambda i, j, kk_: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), x.dtype),
            jax.ShapeDtypeStruct((mm, rr), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, rr), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, v)
    return y[:m, :n], p[:m, :r]
