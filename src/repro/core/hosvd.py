"""HOSVD_ε — the Nguyen et al. 2024 baseline the paper improves upon.

Truncated higher-order SVD of an activation tensor, with per-mode ranks chosen
as the smallest r whose leading singular values explain ≥ ε of the variance
(energy).  Recomputed from scratch every call — this is exactly the per-step
cost the paper's ASI removes (eq. 11/13 overhead).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.asi import _mode_dot, _unfold

Array = jax.Array


def explained_variance_rank(s: Array, eps: float) -> Array:
    """Smallest r such that  Σ_{i<r} s_i² / Σ s_i²  ≥ eps.   (traced-safe)"""
    energy = s.astype(jnp.float32) ** 2
    cum = jnp.cumsum(energy) / jnp.maximum(jnp.sum(energy), 1e-30)
    return jnp.minimum(jnp.searchsorted(cum, jnp.float32(eps)) + 1, s.shape[0])


def mode_svd(a_m: Array):
    """Full (thin) SVD of a mode unfolding, in fp32 for stability."""
    return jnp.linalg.svd(a_m.astype(jnp.float32), full_matrices=False)


def hosvd(a: Array, eps: float) -> tuple[Array, list[Array], list[int]]:
    """HOSVD_ε decomposition (NOT jit-friendly: ranks are data-dependent).

    Returns (core, factors, ranks) with a ≈ core ×₁ U₁ … ×ₙ Uₙ.
    Used offline (rank selection / perplexity estimation) and as the baseline
    in benchmarks, mirroring how the paper uses it.
    """
    factors, ranks = [], []
    for m in range(a.ndim):
        u, s, _ = mode_svd(_unfold(a, m))
        r = int(explained_variance_rank(s, eps))
        factors.append(u[:, :r].astype(a.dtype))
        ranks.append(r)
    core = a
    for m, u in enumerate(factors):
        core = _mode_dot(core, u.T, m)
    return core, factors, ranks


def hosvd_fixed_rank(a: Array, ranks: Sequence[int]) -> tuple[Array, list[Array]]:
    """HOSVD truncated to explicit per-mode ranks (jit-friendly shapes)."""
    factors = []
    for m in range(a.ndim):
        u, _, _ = mode_svd(_unfold(a, m))
        r = min(int(ranks[m]), u.shape[1])
        factors.append(u[:, :r].astype(a.dtype))
    core = a
    for m, u in enumerate(factors):
        core = _mode_dot(core, u.T, m)
    return core, factors


def hosvd_ranks_for_eps(a: Array, eps: float) -> list[int]:
    """Just the per-mode ranks HOSVD_ε would pick (for rank selection)."""
    out = []
    for m in range(a.ndim):
        _, s, _ = mode_svd(_unfold(a, m))
        out.append(int(explained_variance_rank(s, eps)))
    return out
