"""Fault-tolerant training loop.

Design for 1000+ nodes (SPMD): every step is deterministic in (params, step)
— the data pipeline is a pure function of step — so recovery is exactly
"restore latest atomic checkpoint, continue".  Failure handling:

* crash/preemption  -> restart loop restores the latest checkpoint (tested
  via injected ``SimulatedFailure``);
* stragglers        -> within a pod, TPU SPMD is lock-step (no per-node
  stragglers); across pods, the loop records per-step wall-time watermarks
  and flags a persistently slow pod for eviction + elastic resume (the
  decision signal is implemented; the eviction itself belongs to the
  cluster manager);
* elastic rescale   -> checkpoints are layout-free (see checkpoint/elastic),
  so resuming on a different mesh Just Works.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.kernels import dispatch
from repro.optim.optimizers import Optimizer

Array = jax.Array


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainLoopCfg:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    max_restarts: int = 3
    straggler_factor: float = 3.0     # flag steps slower than factor x median
    fail_at_step: int = -1            # inject a failure once at this step
    keep_ckpts: int = 3


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    trainable_mask=None, donate: bool = True,
                    kernel_backend: str | None = None):
    """loss_fn(params, batch, asi_state) -> (loss, (metrics, new_asi_state)).

    ``kernel_backend`` is the model's fused-ASI dispatch flag; passing it here
    resolves it once up front, so an invalid flag aborts before the first
    (expensive) compile instead of deep inside the traced step.
    """
    if kernel_backend is not None:
        dispatch.resolve(kernel_backend)

    def train_step(params, opt_state, asi_state, batch, step):
        (loss, (metrics, new_asi)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, asi_state)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step,
                                               trainable_mask)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, (new_asi if new_asi is not None
                                     else asi_state), metrics

    return jax.jit(train_step,
                   donate_argnums=(0, 1, 2) if donate else ())


class WindowedMedian:
    """Running median over the last ``window`` samples: O(log n) insert +
    O(window) evict, vs the O(n log n) full re-sort per step it replaces."""

    def __init__(self, window: int = 128):
        self.window = window
        self._fifo: collections.deque = collections.deque()
        self._sorted: list[float] = []

    def push(self, v: float):
        self._fifo.append(v)
        bisect.insort(self._sorted, v)
        if len(self._fifo) > self.window:
            old = self._fifo.popleft()
            del self._sorted[bisect.bisect_left(self._sorted, old)]

    def __len__(self):
        return len(self._fifo)

    def median(self) -> float:
        return self._sorted[len(self._sorted) // 2]


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    asi_state: Any
    step: int
    history: list
    restarts: int
    straggler_steps: list


def run(train_step, init_params, init_opt_state, init_asi_state, data,
        cfg: TrainLoopCfg, hooks: dict | None = None) -> TrainResult:
    """Restartable training.  ``data.batch(step)`` must be pure in step."""
    hooks = hooks or {}
    restarts = 0
    history: list = []
    stragglers: list = []

    while True:
        try:
            start = checkpointer.latest_step(cfg.ckpt_dir)
            if start is None:
                params, opt_state, asi_state, step = (
                    init_params, init_opt_state, init_asi_state, 0)
            else:
                tpl = {"params": init_params, "opt": init_opt_state,
                       "asi": init_asi_state}
                tree, step, _ = checkpointer.restore(cfg.ckpt_dir, tpl)
                params, opt_state, asi_state = (tree["params"], tree["opt"],
                                                tree["asi"])
            durations = WindowedMedian()
            while step < cfg.total_steps:
                if step == cfg.fail_at_step and restarts == 0:
                    raise SimulatedFailure(f"injected at step {step}")
                t0 = time.perf_counter()
                batch = data.batch(step)
                params, opt_state, asi_state, metrics = train_step(
                    params, opt_state, asi_state, batch, jnp.int32(step))
                # dt times dispatch (plus any queue backpressure), not
                # device execution — the price of not forcing a per-step
                # sync.  The straggler watermark is therefore a coarse
                # between-syncs signal; the log-step float() below is the
                # only hard sync point.
                dt = time.perf_counter() - t0
                durations.push(dt)
                med = durations.median()
                if len(durations) > 5 and dt > cfg.straggler_factor * med:
                    stragglers.append((step, dt, med))
                step += 1
                if step % cfg.log_every == 0 or step == cfg.total_steps:
                    # the only per-step device sync: metrics stay as async
                    # device arrays on non-log steps, preserving dispatch
                    # pipelining and buffer donation
                    metrics = {k: float(v) for k, v in metrics.items()}
                    history.append({"step": step, **metrics})
                    if "on_log" in hooks:
                        hooks["on_log"](step, metrics)
                if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                    checkpointer.save(
                        cfg.ckpt_dir, step,
                        {"params": params, "opt": opt_state, "asi": asi_state},
                        keep=cfg.keep_ckpts)
            return TrainResult(params, opt_state, asi_state, step, history,
                               restarts, stragglers)
        except SimulatedFailure:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            if "on_restart" in hooks:
                hooks["on_restart"](restarts)
