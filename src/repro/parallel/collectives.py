"""Beyond-paper: ASI/PowerSGD-style *compressed gradient all-reduce*.

The paper compresses stored activations; at multi-pod scale the analogous
bottleneck is the DP gradient all-reduce over the slow cross-pod links.  The
same warm-started single subspace iteration compresses it: instead of
all-reducing G (d_in x d_out), all-reduce P = G·Q (d_in x r) and
Q' = Gᵀ·P̂ (d_out x r) — 2r(d_in+d_out)/(d_in·d_out) of the dense bytes,
with error feedback keeping the optimizer unbiased in the long run
(Vogels et al. 2019, the paper's own foundation).

Used inside ``shard_map`` over the data axes; measured in EXPERIMENTS.md
§Perf as the collective-term hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.asi import orthonormalize

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PowerSGDState:
    q: Array        # (d_out, r) warm-start co-factor
    err: Array      # (d_in, d_out) local error-feedback memory


def init_state(key: Array, shape: tuple[int, int], rank: int) -> PowerSGDState:
    return PowerSGDState(
        q=jax.random.normal(key, (shape[1], rank), jnp.float32),
        err=jnp.zeros(shape, jnp.float32),
    )


def compressed_psum(g: Array, state: PowerSGDState, axis_name: str
                    ) -> tuple[Array, PowerSGDState]:
    """Mean-reduce a 2-D gradient across ``axis_name`` in rank-r space.

    Wire cost per step: r·(d_in + d_out) floats instead of d_in·d_out.
    """
    m = g.astype(jnp.float32) + state.err                 # error feedback
    n = jax.lax.psum(1, axis_name)
    p = m @ state.q                                       # (d_in, r)
    p = jax.lax.psum(p, axis_name)
    p_hat = orthonormalize(p)
    q = m.T @ p_hat                                       # (d_out, r)
    q = jax.lax.psum(q, axis_name) / n
    g_hat = p_hat @ q.T
    new_err = m - g_hat
    return g_hat.astype(g.dtype), PowerSGDState(q=q, err=new_err)


def dense_psum(g: Array, axis_name: str) -> Array:
    """Uncompressed mean all-reduce — the baseline, and the path small
    (norm/bias) leaves always take."""
    return jax.lax.pmean(g, axis_name)


def compressed_psum_tree(grads: Any, states: dict[str, PowerSGDState],
                         axis_name: str):
    """Compress every >=2-D leaf that has a state (keyed by flat path);
    small leaves (norms, biases) go dense — their bytes are negligible."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    new_states = {}
    out = []
    for path, g in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key in states and g.ndim >= 2:
            m2 = g.reshape(-1, g.shape[-1])
            gh, ns = compressed_psum(m2, states[key], axis_name)
            out.append(gh.reshape(g.shape))
            new_states[key] = ns
        else:
            out.append(dense_psum(g, axis_name))
    return jax.tree_util.tree_unflatten(treedef, out), new_states


def init_states_for(grads_struct: Any, key: Array, rank: int
                    ) -> dict[str, PowerSGDState]:
    """One PowerSGDState per >=2-D leaf of ``grads_struct``, keyed by flat
    path — the dict ``compressed_psum_tree`` consumes."""
    flat, _ = jax.tree_util.tree_flatten_with_path(grads_struct)
    states = {}
    for path, g in flat:
        if len(g.shape) >= 2:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            key, sub = jax.random.split(key)
            d_in = 1
            for d in g.shape[:-1]:
                d_in *= d
            states[name] = init_state(sub, (d_in, g.shape[-1]), rank)
    return states


def wire_bytes_dense(shape, dtype_bytes: int = 4) -> int:
    """Bytes a dense all-reduce moves per step for one gradient leaf."""
    n = 1
    for d in shape:
        n *= d
    return n * dtype_bytes


def wire_bytes_compressed(shape, rank: int, dtype_bytes: int = 4) -> int:
    """Bytes the rank-``rank`` compressed all-reduce moves (P plus Q)."""
    d_in = 1
    for d in shape[:-1]:
        d_in *= d
    return (d_in + shape[-1]) * rank * dtype_bytes
