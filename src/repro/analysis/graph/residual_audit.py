"""residual-audit: prove the paper's central memory claim on the traced
graph.

For every registry family this walks the train step's vjp residual set
(``harness.residual_jaxpr``) and demands three things:

1. **Reconciliation** — the residuals classified as ASI factors form
   *exactly* the multiset of shapes the analytic ledger predicts, and
   their bytes equal ``Ledger.asi_total_bytes`` to 0%.  The measured and
   analytic activation-memory columns must be the same number or one of
   them is lying.
2. **No dense saves** — any residual shaped like a full token-extent
   activation ``(B*S, d)`` / ``(B, S, d)`` is flagged at the source line
   that produced it, no matter what code constructed it (custom_vjp,
   helper, closure — constructs AST taint cannot see through).  The
   benign dense saves inherent to backprop through the nonlinear tail
   (norm/activation/residual-stream/loss) carry per-line suppressions
   with justifications; anything new fails CI.
3. **No drift** — the per-family census (category counts + bytes) must
   match the committed golden fixture; intentional changes regenerate it
   via ``python -m repro.analysis --plane graph --update-golden``.
"""
from __future__ import annotations

import collections
import json
import os
from typing import Iterator

from repro.analysis.core import Finding, rule
from repro.analysis.graph import harness

GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_residuals.json")
#: repo-relative anchor for findings with no producing source line
#: (reconciliation and golden drift are family-level facts)
GOLDEN_REL = "src/repro/analysis/graph/golden_residuals.json"
LEDGER_REL = "src/repro/ondevice/ledger.py"


def load_golden() -> dict:
    if not os.path.exists(GOLDEN_PATH):
        return {"census_shape": list((harness.CENSUS_BATCH,
                                      harness.CENSUS_SEQ)), "families": {}}
    with open(GOLDEN_PATH, encoding="utf-8") as f:
        return json.load(f)


def update_golden() -> str:
    """Regenerate the golden census for every family in the current sweep
    (honours ``REPRO_GRAPH_FAMILIES`` narrowing — existing entries for
    families outside the sweep are preserved)."""
    doc = load_golden()
    doc["census_shape"] = [harness.CENSUS_BATCH, harness.CENSUS_SEQ]
    for arch, cfg, api in harness.iter_families():
        doc["families"][arch] = harness.census_family(arch, cfg, api
                                                      ).summary()
    doc["families"] = dict(sorted(doc["families"].items()))
    with open(GOLDEN_PATH, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return GOLDEN_PATH


def census_findings(censuses: list[harness.Census],
                    golden: dict | None = None) -> Iterator[Finding]:
    """Findings for a batch of family censuses (separated from the rule so
    tests can feed synthetic censuses or injected loss functions)."""
    golden = golden if golden is not None else load_golden()
    dense: dict[tuple, dict] = {}
    for census in censuses:
        if not census.factor_match:
            yield Finding(
                rule="residual-audit", path=LEDGER_REL, line=1,
                message=f"{census.arch}: saved ASI factor shapes do not "
                        f"match the ledger's predicted multiset — the "
                        f"backward pass is not saving what the analytic "
                        f"memory column charges for")
        elif census.factor_bytes != census.ledger_bytes:
            yield Finding(
                rule="residual-audit", path=LEDGER_REL, line=1,
                message=f"{census.arch}: factor residual bytes "
                        f"{census.factor_bytes} != ledger analytic bytes "
                        f"{census.ledger_bytes} (gap must be 0%)")
        for rec in census.records:
            if rec.category != "dense":
                continue
            key = (rec.path or GOLDEN_REL, rec.line)
            slot = dense.setdefault(key, {"n": 0, "arches": set(),
                                          "shape": rec.shape,
                                          "primitive": rec.primitive})
            slot["n"] += 1
            slot["arches"].add(census.arch)
        entry = golden.get("families", {}).get(census.arch)
        if entry is None:
            yield Finding(
                rule="residual-audit", path=GOLDEN_REL, line=1,
                message=f"{census.arch}: no golden census entry — run "
                        f"python -m repro.analysis --plane graph "
                        f"--update-golden")
        elif entry != census.summary():
            yield Finding(
                rule="residual-audit", path=GOLDEN_REL, line=1,
                message=f"{census.arch}: residual census drifted from "
                        f"golden {entry} -> {census.summary()}; if "
                        f"intentional, regenerate with --update-golden")
    for (path, line), slot in sorted(dense.items()):
        arches = ",".join(sorted(slot["arches"]))
        yield Finding(
            rule="residual-audit", path=path, line=line,
            message=f"dense activation saved as vjp residual (e.g. shape "
                    f"{slot['shape']} by {slot['primitive']}; "
                    f"{slot['n']} save(s) across {arches}) — the paper's "
                    f"memory claim forbids dense (B,S,d) residuals")


@rule("residual-audit", scope="tree", plane="graph",
      doc="train-step vjp residuals: factor/ledger 0%-gap reconciliation, "
          "dense-save detection at producer lines, golden census drift")
def check_residuals(root, contexts) -> Iterator[Finding]:
    censuses = [harness.census_family(arch, cfg, api)
                for arch, cfg, api in harness.iter_families()]
    yield from census_findings(censuses)
