"""Gradient filtering baseline (Yang et al., CVPR 2023).

The paper benchmarks against this: approximate activations and output
gradients by average-pooling over RxR spatial patches before computing the
weight gradient.  Memory drops by R² for the stored activation; the gradient
is approximated (unlike ASI, the error also propagates to ∂L/∂A in the
original method — we reproduce the stored-activation variant used by the
paper's comparison, i.e. pooled A and pooled g for ∂L/∂W).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def patch_pool(x: Array, r: int) -> Array:
    """Average-pool an NCHW tensor over non-overlapping r×r patches.

    H/W are zero-padded up to multiples of r and each patch sum is divided
    by the number of *real* elements it covers, so edge patches on ragged
    shapes get their exact mean (dividing by the full r×r count would bias
    them low).
    """
    b, c, h, w = x.shape
    ph, pw = (-h) % r, (-w) % r
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, ph), (0, pw)))
    hh, ww = (h + ph) // r, (w + pw) // r
    sums = x.reshape(b, c, hh, r, ww, r).sum(axis=(3, 5))
    rows = jnp.minimum(jnp.arange(hh) * r + r, h) - jnp.arange(hh) * r
    cols = jnp.minimum(jnp.arange(ww) * r + r, w) - jnp.arange(ww) * r
    counts = (rows[:, None] * cols[None, :]).astype(sums.dtype)
    return sums / counts


def pooled_storage_elems(shape: tuple[int, int, int, int], r: int) -> int:
    b, c, h, w = shape
    return b * c * ((h + r - 1) // r) * ((w + r - 1) // r)
