"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, enc_len, d_model).  The backbone is real:
bidirectional encoder blocks (LayerNorm + MHA + GELU MLP) and a decoder with
causal self-attention + cross-attention, learned positions, biases — the
Whisper block layout.  ASI fine-tuning wraps the decoder-tail linears.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.asi import MatrixASIState
from repro.models.attention import (attn_decode, attn_decode_paged,
                                    attn_forward, attn_init, cross_kv,
                                    init_kv_cache, init_paged_kv_cache,
                                    quantize_cache)
from repro.models.layers import (embed_init, initializer, mlp_apply, mlp_init,
                                 norm_apply, norm_init, sinusoidal_positions,
                                 unembed_init)
from repro.parallel.sharding import logical_shard

Array = jax.Array


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": norm_init(cfg, dtype), "attn": attn_init(k1, cfg, dtype),
            "norm2": norm_init(cfg, dtype), "mlp": mlp_init(k2, cfg, dtype)}


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg, dtype), "self": attn_init(k1, cfg, dtype),
        "norm2": norm_init(cfg, dtype), "cross": attn_init(k2, cfg, dtype),
        "norm3": norm_init(cfg, dtype), "mlp": mlp_init(k3, cfg, dtype),
    }


def init_params(key: Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd, kt, ko, kp = jax.random.split(key, 5)
    return {
        "embed": embed_init(kt, cfg, dtype),
        "dec_pos": initializer(kp, (4096, cfg.d_model), dtype),
        "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            jax.random.split(ke, cfg.n_enc_layers)),
        "enc_norm": norm_init(cfg, dtype),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
            jax.random.split(kd, cfg.n_layers)),
        "final_norm": norm_init(cfg, dtype),
        "unembed": unembed_init(ko, cfg, dtype),
    }


def encode(params: dict, frames: Array, cfg: ModelConfig) -> Array:
    """frames: precomputed embeddings (B, enc_len, d) — frontend stub."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = logical_shard(x, "batch", None, "embed")

    def block(x, bp):
        h = norm_apply(bp["norm1"], x, cfg)
        y, _, _ = attn_forward(bp["attn"], h, cfg, causal=False)
        x = x + y
        h = norm_apply(bp["norm2"], x, cfg)
        y, _ = mlp_apply(bp["mlp"], h, cfg)
        return x + y, None

    x, _ = jax.lax.scan(jax.checkpoint(block) if cfg.remat != "none" else block,
                        x, params["encoder"],
                        unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
    return norm_apply(params["enc_norm"], x, cfg)


def _dec_pos_emb(params, positions, dtype):
    return params["dec_pos"].astype(dtype)[positions]


def decode_train(params: dict, tokens: Array, enc_out: Array,
                 cfg: ModelConfig, asi_state: dict | None = None):
    """Teacher-forced decoder over a full target sequence."""
    B, S = tokens.shape
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = x + _dec_pos_emb(params, jnp.arange(S) % params["dec_pos"].shape[0],
                         x.dtype)[None]
    tail = min(cfg.asi_last_k, cfg.n_layers) if cfg.compress != "none" else 0
    n_prefix = cfg.n_layers - tail
    new_asi: dict = {}

    def block(x, bp, st=None):
        ns: dict = {}
        h = norm_apply(bp["norm1"], x, cfg)
        y, s1, _ = attn_forward(bp["self"], h, cfg, causal=True,
                                asi_state=st.get("self") if st else None)
        if s1:
            ns["self"] = s1
        x = x + y
        h = norm_apply(bp["norm2"], x, cfg)
        ekv = cross_kv(bp["cross"], enc_out, cfg)
        y, s2, _ = attn_forward(bp["cross"], h, cfg, causal=False, enc_kv=ekv,
                                asi_state=st.get("cross") if st else None)
        if s2:
            ns["cross"] = s2
        x = x + y
        h = norm_apply(bp["norm3"], x, cfg)
        y, s3 = mlp_apply(bp["mlp"], h, cfg, st.get("mlp") if st else None)
        if s3:
            ns["mlp"] = s3
        return x + y, (ns or None)

    def scan_body(x, bp):
        x, _ = block(x, bp)
        return x, None

    body = jax.checkpoint(scan_body) if cfg.remat != "none" else scan_body
    u = cfg.n_layers if cfg.scan_unroll else 1
    if tail == 0:
        x, _ = jax.lax.scan(body, x, params["decoder"], unroll=u)
    else:
        if n_prefix > 0:
            prefix = jax.tree.map(lambda a: a[:n_prefix], params["decoder"])
            x, _ = jax.lax.scan(body, x, prefix,
                                unroll=n_prefix if cfg.scan_unroll else 1)
            x = jax.lax.stop_gradient(x)
        for i in range(n_prefix, cfg.n_layers):
            bp = jax.tree.map(lambda a: a[i], params["decoder"])
            st = asi_state.get(f"layer_{i}") if asi_state else None
            x, ns = block(x, bp, st)
            if ns is not None:
                new_asi[f"layer_{i}"] = ns
    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logical_shard(logits, "batch", None, "vocab"), (new_asi or None)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            asi_state: dict | None = None):
    # anchor the batch on the data axes even when the caller did not
    # device_put it (no-op outside an axis_rules context)
    batch = {k: logical_shard(v, "batch", *([None] * (v.ndim - 1)))
             for k, v in batch.items()}
    enc_out = encode(params, batch["frames"], cfg)
    if cfg.compress != "none":
        enc_out = jax.lax.stop_gradient(enc_out)     # frozen encoder backbone  # repro-lint: disable=residual-audit — cross-attn KV source: kept as a forward value at the encode/decode boundary, not a gradient residual
    logits, new_asi = decode_train(params, batch["tokens"], enc_out, cfg,
                                   asi_state)
    t = batch["targets"]
    lse = jax.nn.logsumexp(logits, axis=-1)  # repro-lint: disable=residual-audit — softmax-CE vjp keeps exp(logits - lse); the loss head is outside ASI's sites
    picked = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    return ce, ({"ce": ce, "aux": jnp.float32(0.0)}, new_asi)


def init_asi_state(key: Array, cfg: ModelConfig,
                   rank_plan: dict | None = None) -> dict:
    """``rank_plan`` maps ``layer_{i}/self/wq``-style site paths to per-site
    ranks (planner output); unlisted sites use ``cfg.asi_rank``."""
    if cfg.compress == "none":
        return {}
    plan = rank_plan or {}
    d, hd, h, f = cfg.d_model, cfg.hd, cfg.n_heads, cfg.d_ff
    tail = min(cfg.asi_last_k, cfg.n_layers)
    out = {}
    for i in range(cfg.n_layers - tail, cfg.n_layers):
        key, *ks = jax.random.split(key, 12)
        r = lambda site: plan.get(f"layer_{i}/{site}", cfg.asi_rank)
        out[f"layer_{i}"] = {
            "self": {n: MatrixASIState.init(k, d if n != "wo" else h * hd,
                                            r(f"self/{n}"))
                     for n, k in zip(("wq", "wk", "wv", "wo"), ks[:4])},
            "cross": {n: MatrixASIState.init(k, d if n != "wo" else h * hd,
                                             r(f"cross/{n}"))
                      for n, k in zip(("wq", "wo"), ks[4:6])},
            "mlp": {"up": MatrixASIState.init(ks[6], d, r("mlp/up")),
                    "down": MatrixASIState.init(ks[7], f, r("mlp/down"))},
        }
    return out


def trainable_mask(params: dict, cfg: ModelConfig):
    if cfg.compress == "none":
        return jax.tree.map(lambda _: True, params)
    tail = min(cfg.asi_last_k, cfg.n_layers)
    L = cfg.n_layers

    def mask_stack(a):
        m = jnp.zeros((L,), bool).at[L - tail:].set(True)
        return jnp.broadcast_to(m.reshape((L,) + (1,) * (a.ndim - 1)), a.shape)

    return {
        "embed": False, "dec_pos": False,
        "encoder": jax.tree.map(lambda _: False, params["encoder"]),
        "enc_norm": jax.tree.map(lambda _: False, params["enc_norm"]),
        "decoder": jax.tree.map(mask_stack, params["decoder"]),
        "final_norm": jax.tree.map(lambda _: True, params["final_norm"]),
        "unembed": True,
    }


# --- serving -------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    self_cache = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype),
        init_kv_cache(cfg, batch, max_len, dtype))
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads,
                        cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads,
                        cfg.hd), dtype),
    }
    return {"self": self_cache, "cross": cross}


def init_paged_cache(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int) -> dict:
    """Paged layout: decoder self-attention K/V page through a shared block
    pool; cross K/V stay per-slot (fixed ``enc_len`` rows primed once per
    request — nothing grows, nothing to page)."""
    dtype = jnp.dtype(cfg.dtype)
    self_pool = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype),
        init_paged_kv_cache(cfg, n_blocks, block_size, dtype))
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads,
                        cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads,
                        cfg.hd), dtype),
    }
    return {"self": self_pool, "cross": cross}


def write_paged_slot(cfg: ModelConfig, cache: dict, one: dict,
                     table_row: Array, slot) -> dict:
    """Install a batch-1 prefill cache: self-attention rows scatter into the
    physical blocks of ``table_row``; cross K/V write per-slot."""
    L = table_row.shape[0]

    def put(pool, leaf):
        nl, _, s = leaf.shape[:3]
        r = leaf.reshape((nl, L, s // L) + leaf.shape[3:])
        return pool.at[:, table_row].set(r.astype(pool.dtype))

    return {
        "self": jax.tree.map(put, cache["self"], one["self"]),
        "cross": jax.tree.map(
            lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                c, o.astype(c.dtype), slot, axis=1),
            cache["cross"], one["cross"]),
    }


def prime_cross(params: dict, frames: Array, cfg: ModelConfig) -> dict:
    """Encode frames and project per-decoder-layer cross K/V, without
    touching the self cache — the chunked-prefill path installs this into a
    transient batch-1 cache, then feeds the prompt through ``decode_step``."""
    enc_out = encode(params, frames, cfg)

    def layer(_, bp):
        k, v = cross_kv(bp["cross"], enc_out, cfg)
        return None, {"k": k, "v": v}

    _, cross = jax.lax.scan(layer, None, params["decoder"])
    return cross          # {"k","v"} each (n_layers, B, enc_len, KV, hd)


def prefill(params: dict, frames: Array, tokens: Array, cfg: ModelConfig,
            max_len: int):
    """Encode the audio stub + teacher-force the prompt, returning
    (last_logits, primed {self, cross} caches).  Cross K/V are projected
    once per layer inside the scan (the same ``ekv`` the cross-attention
    consumes), not a second time via ``prime_cross_cache``."""
    B, S = tokens.shape
    enc_out = encode(params, frames, cfg)
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    x = x + _dec_pos_emb(params, jnp.arange(S) % params["dec_pos"].shape[0],
                         x.dtype)[None]
    n = min(S, max_len)

    def block_fn(x, bp):
        h = norm_apply(bp["norm1"], x, cfg)
        y, _, (k, v) = attn_forward(bp["self"], h, cfg, causal=True)
        x = x + y
        ck = jnp.zeros((B, max_len) + k.shape[2:], k.dtype)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, -n:], 0, 1)
        cv = jnp.zeros((B, max_len) + v.shape[2:], v.dtype)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, -n:], 0, 1)
        h = norm_apply(bp["norm2"], x, cfg)
        ek, ev = cross_kv(bp["cross"], enc_out, cfg)
        y, _, _ = attn_forward(bp["cross"], h, cfg, causal=False,
                               enc_kv=(ek, ev))
        x = x + y
        h = norm_apply(bp["norm3"], x, cfg)
        y, _ = mlp_apply(bp["mlp"], h, cfg)
        self_c = (quantize_cache({"k": ck, "v": cv})
                  if cfg.kv_cache_dtype == "int8" else {"k": ck, "v": cv})
        return x + y, {"self": self_c, "cross": {"k": ek, "v": ev}}

    x, caches = jax.lax.scan(block_fn, x, params["decoder"],
                             unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = (x[:, -1] @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, {"self": caches["self"], "cross": caches["cross"]}


def decode_step(params: dict, cache: dict, token: Array, pos: Array,
                cfg: ModelConfig):
    """token (B,) int32; pos scalar or (B,) per-slot positions."""
    B = token.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[token][:, None]
    x = x + _dec_pos_emb(params, posb % params["dec_pos"].shape[0],
                         x.dtype)[:, None]

    def block_fn(x, xs):
        bp, bc = xs
        h = norm_apply(bp["norm1"], x, cfg)
        y, new_self = attn_decode(bp["self"], h, bc["self"], pos, cfg)
        x = x + y
        h = norm_apply(bp["norm2"], x, cfg)
        y, _ = attn_decode(bp["cross"], h, bc["cross"], pos, cfg, cross=True)
        x = x + y
        h = norm_apply(bp["norm3"], x, cfg)
        y, _ = mlp_apply(bp["mlp"], h, cfg)
        return x + y, {"self": new_self, "cross": bc["cross"]}

    x, new_cache = jax.lax.scan(block_fn, x, (params["decoder"], cache),
                                unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], new_cache


def decode_step_paged(params: dict, cache: dict, table: Array, token: Array,
                      pos: Array, cfg: ModelConfig):
    """``decode_step`` against a paged self cache (``init_paged_cache``)."""
    B = token.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[token][:, None]
    x = x + _dec_pos_emb(params, posb % params["dec_pos"].shape[0],
                         x.dtype)[:, None]

    def block_fn(x, xs):
        bp, bc = xs
        h = norm_apply(bp["norm1"], x, cfg)
        y, new_self = attn_decode_paged(bp["self"], h, bc["self"], table,
                                        pos, cfg)
        x = x + y
        h = norm_apply(bp["norm2"], x, cfg)
        y, _ = attn_decode(bp["cross"], h, bc["cross"], pos, cfg, cross=True)
        x = x + y
        h = norm_apply(bp["norm3"], x, cfg)
        y, _ = mlp_apply(bp["mlp"], h, cfg)
        return x + y, {"self": new_self, "cross": bc["cross"]}

    x, new_cache = jax.lax.scan(block_fn, x, (params["decoder"], cache),
                                unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], new_cache
