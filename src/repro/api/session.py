"""Embeddable runtime sessions: one wiring of config -> model -> state.

``Session.from_config`` performs the resolution every launcher used to
re-implement — config-name normalization, ``ModelAPI`` build, kernel-backend
dispatch validation, optimizer/schedule construction, checkpointer attach —
exactly once, then hands out composable runtime handles:

* ``session.trainer(...)`` — the fault-tolerant loop (``runtime.train_loop``),
  including ``--layout``-style mesh sharding and gradient accumulation;
* ``session.server(...)`` — the continuous-batching engine
  (``runtime.serve_loop``), with live ``swap_params``;
* ``session.adapter(...)`` — budget-planned train-while-serve
  (``repro.ondevice``: ledger -> planner -> ``DeviceSession``);
* ``session.analyze(...)`` — the dry-run's FLOPs + activation-ledger report
  as data (``repro.api.analyze``), not prints.

State transitions are explicit and checkpoint-backed: ``trainer.fit()``
writes its result back into the session's params/optimizer/ASI state,
``session.save()`` persists them with provenance meta, ``Session.load()``
reconstructs an equivalent session from that meta, and a live
``server.swap_params(adapter.step())`` reuses one params lifecycle across
serving and adaptation.  The four ``repro.launch`` CLIs are thin argparse
shims over this module (see DESIGN.md §9 for the shim contract).
"""
from __future__ import annotations

import json
import os
import warnings
import weakref
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.api.resolve import parse_mesh, resolve_arch
from repro.checkpoint import checkpointer
from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.kernels import dispatch
from repro.launch.mesh import make_layout_mesh
from repro.models import build_model
from repro.models.registry import ModelAPI
from repro.ondevice.ledger import build_ledger
from repro.ondevice.planner import build_plan
from repro.ondevice.session import DeviceSession, ReplayBuffer, SessionCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.serve_loop import (Engine, Request, SequentialEngine,
                                      ServeCfg)
from repro.telemetry import Recorder
from repro.runtime.train_loop import (TrainLoopCfg, TrainResult,
                                      make_mesh_plan, make_train_step, run)


def data_source(cfg: ModelConfig, seq_len: int, global_batch: int, seed: int):
    """Synthetic LM stream for ``cfg``'s family: plain token batches for
    decoder-only models, plus constant frames/patch embeds for encdec/vlm.
    Pure in ``step`` — exactly what the restartable loop requires."""
    base = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                global_batch=global_batch, seed=seed,
                                branching=2))
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return base

    class Wrapped:
        def batch(self, step):
            b = base.batch(step)
            n = b["tokens"].shape[0]
            if cfg.family == "encdec":
                b["frames"] = 0.1 * jnp.ones(
                    (n, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
            else:  # vlm
                b["embeds"] = 0.1 * jnp.ones(
                    (n, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            return b
    return Wrapped()


def demo_requests(n: int, max_new: int = 8, *, start_uid: int = 0,
                  prompt_len: int = 5) -> list[Request]:
    """The deterministic synthetic request stream the serve/adapt CLIs use."""
    return [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(prompt_len)],
                    max_new_tokens=max_new)
            for i in range(start_uid, start_uid + n)]


class Session:
    """One resolved (config, model, state) lifecycle shared by every handle.

    Construction resolves everything exactly once; params/ASI state are
    materialized lazily so analysis-only sessions (``session.analyze()``)
    never allocate real weights.
    """

    def __init__(self, cfg: ModelConfig, arch: str, model: ModelAPI, *,
                 reduced: bool = False, overrides: dict | None = None,
                 seed: int = 0, ckpt_dir: str | None = None,
                 telemetry: Recorder | None = None):
        self.cfg = cfg
        self.arch = arch
        self.model = model
        self.seed = seed
        self.ckpt_dir = ckpt_dir
        self.reduced = reduced
        # one recorder shared by every handle this session creates: trainer
        # steps, engine request lifecycles, and adaptation bursts land on a
        # single timeline (None = aggregates only, no event ring)
        self.telemetry = telemetry
        self.overrides = dict(overrides or {})
        self.step = 0
        self.rank_plan: dict | None = None      # planner output, shapes ASI state
        # live engines sharing params; weak so a dropped Server re-enables
        # trainer buffer donation and frees its KV cache
        self._servers: weakref.WeakSet = weakref.WeakSet()
        self.opt = None
        self.opt_name: str | None = None
        self.opt_state = None
        self.optimizer_substitution: dict | None = None
        self._params = None
        self._asi = None

    # --- construction -----------------------------------------------------

    @classmethod
    def from_config(cls, name: str, *, reduced: bool = False, seed: int = 0,
                    ckpt_dir: str | None = None,
                    telemetry: Recorder | None = None,
                    **overrides) -> "Session":
        """Resolve ``name`` (underscore spellings accepted), apply ``reduced``
        and any non-``None`` ``ModelConfig`` overrides, validate the kernel
        backend, and build the ``ModelAPI`` — once.

        ``None`` override values are dropped, so CLI shims can forward
        optional flags verbatim (``asi_rank=args.asi_rank``).

        ``telemetry`` takes a ``repro.telemetry.Recorder``; every handle the
        session builds records its lifecycle into it (DESIGN.md §13).
        """
        arch = resolve_arch(name)
        if arch not in ARCHS:
            raise ValueError(f"unknown arch {name!r}; choose from {ARCHS}")
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        applied = {k: v for k, v in overrides.items() if v is not None}
        if applied:
            cfg = cfg.replace(**applied)
        dispatch.resolve(cfg.kernel_backend)    # invalid flag fails fast here
        return cls(cfg, arch, build_model(cfg), reduced=reduced,
                   overrides=applied, seed=seed, ckpt_dir=ckpt_dir,
                   telemetry=telemetry)

    def derive(self, **overrides) -> "Session":
        """A sibling session with extra config overrides (fresh state)."""
        return Session.from_config(
            self.arch, reduced=self.reduced, seed=self.seed,
            ckpt_dir=self.ckpt_dir, telemetry=self.telemetry,
            **{**self.overrides, **overrides})

    # --- state ------------------------------------------------------------

    @property
    def params(self):
        if self._params is None:
            self._params = self.model.init(jax.random.PRNGKey(self.seed))
        return self._params

    @params.setter
    def params(self, value):
        self._params = value

    @property
    def asi_state(self):
        if self._asi is None:
            self._asi = (self.model.init_asi(jax.random.PRNGKey(self.seed),
                                             rank_plan=self.rank_plan)
                         if self.cfg.compress != "none" else {})
        return self._asi

    @asi_state.setter
    def asi_state(self, value):
        self._asi = value

    def trainable_mask(self):
        return (self.model.trainable_mask(self.params)
                if self.cfg.compress != "none" else None)

    # --- optimizer / step wiring -------------------------------------------

    def attach_optimizer(self, lr: float, warmup_steps: int, total_steps: int,
                         clip_norm: float = 2.0):
        """Build optimizer + warmup-cosine schedule and init its state.

        adafactor is substituted with adamw (it is not mask-aware for frozen
        backbones); the substitution is recorded in
        ``self.optimizer_substitution`` for callers that surface it.
        """
        configured = self.cfg.optimizer
        used = "adamw" if configured == "adafactor" else configured
        self.optimizer_substitution = None if used == configured else {
            "configured": configured, "used": used,
            "reason": "adafactor is not mask-aware for frozen backbones"}
        self.opt_name = used
        self.opt = make_optimizer(
            used, warmup_cosine(lr, warmup_steps, total_steps),
            clip_norm=clip_norm)                # paper: L2 clip threshold 2.0
        self.opt_state = self.opt.init(self.params)
        return self.opt

    def train_step(self, *, plan=None, grad_accum: int = 1,
                   donate: bool = True):
        """The jitted step over this session's loss/mask/backend — the
        blessed replacement for hand-wiring ``make_train_step``."""
        if self.opt is None:
            raise ValueError("no optimizer attached: call attach_optimizer() "
                             "or use session.trainer()/session.adapter()")
        model = self.model
        return make_train_step(lambda p, b, s: model.loss(p, b, s), self.opt,
                               trainable_mask=self.trainable_mask(),
                               donate=donate,
                               kernel_backend=self.cfg.kernel_backend,
                               plan=plan, grad_accum=grad_accum)

    # --- checkpoints --------------------------------------------------------

    def save(self, ckpt_dir: str | None = None, *, step: int | None = None,
             meta: dict | None = None, keep: int = 3) -> str:
        """Atomic checkpoint of params/ASI (+ optimizer state when attached)
        with session provenance meta, so ``Session.load`` can rebuild an
        equivalent session without the caller re-supplying the config."""
        directory = ckpt_dir or self.ckpt_dir
        if directory is None:
            raise ValueError("no checkpoint directory: pass ckpt_dir or set "
                             "session.ckpt_dir")
        self.ckpt_dir = directory
        tree = {"params": self.params, "asi": self.asi_state}
        if self.opt_state is not None:
            tree["opt"] = self.opt_state
        m: dict = {"arch": self.arch, "reduced": self.reduced,
                   "overrides": self.overrides, "seed": self.seed}
        if self.opt_name is not None:
            m["optimizer"] = self.opt_name
        if self.rank_plan:
            m["rank_plan"] = {k: int(v) for k, v in self.rank_plan.items()}
        m.update(meta or {})
        return checkpointer.save(directory, self.step if step is None else step,
                                 tree, meta=m, keep=keep)

    @classmethod
    def load(cls, ckpt_dir: str, *, step: int | None = None,
             **overrides) -> "Session":
        """Rebuild a session from a ``Session.save`` checkpoint: provenance
        meta supplies arch/overrides/rank-plan, the templates come from the
        ``eval_shape``-safe ``ModelAPI.init_struct``, and params/ASI state
        are restored (optimizer state stays with whoever attaches one)."""
        at = checkpointer.latest_step(ckpt_dir) if step is None else step
        if at is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        with open(os.path.join(ckpt_dir, f"step_{at:08d}", "meta.json")) as f:
            meta = json.load(f)
        if "arch" not in meta:
            raise ValueError(
                f"{ckpt_dir}: meta.json has no session provenance; restore "
                "into an explicit Session.from_config template instead")
        kw = dict(meta.get("overrides", {}))
        kw.update(overrides)
        # session-level fields are explicit from_config keywords — pop them
        # so user overrides replace the meta values instead of colliding
        reduced = kw.pop("reduced", meta.get("reduced", False))
        seed = kw.pop("seed", meta.get("seed", 0))
        sess = cls.from_config(meta["arch"], reduced=reduced, seed=seed,
                               ckpt_dir=kw.pop("ckpt_dir", ckpt_dir), **kw)
        sess.rank_plan = meta.get("rank_plan") or None
        template = {"params": sess.model.init_struct()}
        if sess.cfg.compress != "none":
            template["asi"] = jax.eval_shape(
                lambda k: sess.model.init_asi(k, rank_plan=sess.rank_plan),
                jax.random.PRNGKey(sess.seed))
        tree, at, _ = checkpointer.restore(ckpt_dir, template, step=at)
        sess._params = tree["params"]
        sess._asi = tree.get("asi", {})
        sess.step = at
        return sess

    # --- handles ------------------------------------------------------------

    def trainer(self, **kw) -> "Trainer":
        return Trainer(self, **kw)

    def server(self, **kw) -> "Server":
        return Server(self, **kw)

    def adapter(self, **kw) -> "Adapter":
        return Adapter(self, **kw)

    def analyze(self, shape: str = "train_4k", *,
                reduce_shape: bool | None = None, verbose: bool = False,
                **kw) -> dict:
        """The dry-run cell report (lower+compile, memory/cost analysis,
        roofline terms, activation ledger) as a dict — see
        ``repro.api.analyze.analyze_cell`` for the knobs.

        A reduced session analyzes the reduced input shape by default
        (parity with ``dryrun --reduced``); pass ``reduce_shape=False`` to
        lower the full-size shape against the miniature config anyway."""
        from repro.api import analyze as _analyze
        from repro.configs.base import SHAPES
        if isinstance(shape, str):
            shape = SHAPES[shape]
        if self.reduced if reduce_shape is None else reduce_shape:
            shape = shape.reduced()
        return _analyze.analyze_cell(self, shape, verbose=verbose, **kw)


class Trainer:
    """``make_train_step`` + the fault-tolerant loop over a session.

    Mirrors the train CLI contract: warmup-cosine over ``steps``, synthetic
    ``data_source`` unless ``data`` is supplied, optional ``layout``/``mesh``
    sharding (``mesh_info`` carries the dict the CLI prints), checkpoints
    under ``ckpt_dir``.  ``fit()`` runs to ``steps`` and writes the final
    params/optimizer/ASI state back into the session.
    """

    @staticmethod
    def validate(*, batch: int = 8, grad_accum: int = 1,
                 layout: str | None = None, mesh=None) -> None:
        """Pure flag validation (no model/optimizer work) — CLI shims call
        this up front so argparse-shaped errors stay argparse-shaped while
        real construction failures keep their tracebacks."""
        if grad_accum < 1:
            raise ValueError(f"--grad-accum {grad_accum} must be >= 1")
        if batch % grad_accum != 0:
            raise ValueError(f"--batch {batch} must divide by "
                             f"--grad-accum {grad_accum}")
        if mesh is not None and layout is None:
            raise ValueError("--mesh requires --layout (it only shapes a "
                             "layout's mesh)")
        parse_mesh(mesh)

    def __init__(self, session: Session, *, steps: int = 100,
                 seq_len: int = 64, batch: int = 8, lr: float = 1e-3,
                 layout: str | None = None, mesh=None, grad_accum: int = 1,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 fail_at: int = -1, data=None):
        self.validate(batch=batch, grad_accum=grad_accum, layout=layout,
                      mesh=mesh)
        self.session = session
        # one checkpoint lifecycle: the loop writes where the session points
        # unless the caller says otherwise
        ckpt_dir = (ckpt_dir if ckpt_dir is not None
                    else (session.ckpt_dir or "/tmp/repro_ckpt"))
        session.ckpt_dir = session.ckpt_dir or ckpt_dir
        session.attach_optimizer(lr, max(steps // 20, 1), steps)
        self.data = (data if data is not None
                     else data_source(session.cfg, seq_len, batch,
                                      session.seed))
        self.plan = None
        self.mesh_info: dict | None = None
        if layout is not None:
            mesh_obj = make_layout_mesh(layout, parse_mesh(mesh))
            self.plan = make_mesh_plan(session.cfg, mesh_obj, layout,
                                       session.params, session.opt_state,
                                       session.asi_state, self.data.batch(0))
            self.mesh_info = {"mesh": dict(mesh_obj.shape), "layout": layout,
                              "n_devices": mesh_obj.size,
                              "grad_accum": grad_accum}
        self.loop_cfg = TrainLoopCfg(total_steps=steps, ckpt_dir=ckpt_dir,
                                     ckpt_every=ckpt_every,
                                     fail_at_step=fail_at)
        self._grad_accum = grad_accum
        self._step_fn = None
        self._donated: bool | None = None
        self.result: TrainResult | None = None

    def fit(self, on_log=None, hooks: dict | None = None) -> TrainResult:
        hooks = dict(hooks or {})
        if on_log is not None:
            hooks["on_log"] = on_log
        s = self.session
        # donation recycles the step's input buffers in place — never donate
        # params a live Server engine still references (use-after-donate on
        # accelerators; CPU ignores donation, so tests alone won't catch it)
        donate = not s._servers
        if self._step_fn is None or donate != self._donated:
            self._step_fn = s.train_step(plan=self.plan,
                                         grad_accum=self._grad_accum,
                                         donate=donate)
            self._donated = donate
        res = run(self._step_fn, s.params, s.opt_state, s.asi_state,
                  self.data, self.loop_cfg, hooks=hooks, plan=self.plan,
                  telemetry=s.telemetry)
        s.params, s.opt_state, s.asi_state = (res.params, res.opt_state,
                                              res.asi_state)
        s.step = res.step
        self.result = res
        return res

    def summary(self, res: TrainResult | None = None) -> dict:
        res = res if res is not None else self.result
        return {"final_step": res.step, "restarts": res.restarts,
                "stragglers": len(res.straggler_steps),
                "final_loss": round(res.history[-1]["loss"], 4)}


class Server:
    """A serving engine over the session's params with live weight swaps."""

    def __init__(self, session: Session, *, engine: str = "continuous",
                 max_batch: int = 4, max_len: int = 128,
                 temperature: float = 0.0, eos_id: int = -1,
                 cache: str = "dense", prefill_chunk: int = 0,
                 page_block: int = 16, pool_blocks: int = 0):
        if engine not in ("continuous", "sequential"):
            raise ValueError(f"engine {engine!r} must be continuous or "
                             "sequential")
        if cache != "dense" and engine == "sequential":
            raise ValueError("the sequential engine has no paged cache; "
                             "use engine='continuous' with cache='paged'")
        self.session = session
        self.engine_name = engine
        cls = Engine if engine == "continuous" else SequentialEngine
        self.engine = cls(session.model, session.params,
                          ServeCfg(max_batch=max_batch, max_len=max_len,
                                   temperature=temperature, eos_id=eos_id,
                                   cache=cache, prefill_chunk=prefill_chunk,
                                   page_block=page_block,
                                   pool_blocks=pool_blocks),
                          seed=session.seed, telemetry=session.telemetry)
        session._servers.add(self)      # trainers must not donate our params

    def run(self, requests: list[Request], on_retire=None) -> list[Request]:
        """Serve ``requests`` to completion; counters land in
        ``last_stats``.  ``on_retire(req)`` streams finished requests (e.g.
        into ``Adapter.observe``)."""
        return self.engine.run(requests, on_retire=on_retire)

    def swap_params(self, params) -> "Server":
        """Install ``params`` live: the next decode step serves them.
        In-flight requests keep their slots, positions, and KV rows."""
        if params is not None:
            self.session.params = params
            self.engine.params = params
        return self

    def close(self) -> None:
        """Detach from the session: trainers may donate buffers again and
        the engine (with its KV cache) becomes collectable.  The weak
        registry also drops a Server that simply goes out of scope; close()
        makes the hand-back deterministic."""
        self.session._servers.discard(self)

    @property
    def last_stats(self):
        return self.engine.last_stats

    def stats_dict(self) -> dict:
        s = self.engine.last_stats
        d = {"engine": self.engine_name, "requests": s.requests,
             "generated_tokens": s.generated_tokens,
             "decode_steps": s.decode_steps,
             "tokens_per_s": round(s.tokens_per_s, 1),
             "ttft_mean_s": round(s.ttft_mean_s, 4)}
        if getattr(self.engine.cfg, "cache", "dense") == "paged":
            d.update(cache="paged", preemptions=s.preemptions,
                     peak_used_blocks=s.peak_used_blocks,
                     peak_cache_bytes=s.peak_cache_bytes)
        return d


class Adapter:
    """Budget-driven on-device adaptation: ledger -> planner ->
    ``DeviceSession``, over the session's params.

    The ledger is priced eagerly (feasibility is cheap and analytical); the
    §3.3 calibration + budget search runs lazily on first use, re-shapes the
    session's ASI state to the planned per-site ranks, and attaches a fresh
    optimizer.  Two composable modes share one replay buffer and one step
    counter:

    * ``run(requests)`` — train-while-serve via ``DeviceSession`` (the adapt
      CLI path: bursts ride the engine's retirement hook);
    * ``observe(req)`` / ``step()`` — feed retirements from *your own* server
      and run bursts yourself, then ``server.swap_params(adapter.step())``.
    """

    def __init__(self, session: Session, *, mem_budget_mb: float,
                 steps: int = 10, adapt_every: int = 4, burst_steps: int = 1,
                 replay_size: int = 64, batch: int = 2, seq_len: int = 32,
                 calib_batches: int = 2, rank_select: str = "knapsack",
                 lr: float = 1e-2, max_batch: int = 4, max_len: int = 64,
                 temperature: float = 0.0, replay: ReplayBuffer | None = None):
        if session.cfg.compress != "asi":
            raise ValueError(
                "adapter needs an ASI session: "
                "Session.from_config(..., compress='asi')")
        self.session = session
        self.mem_budget_mb = mem_budget_mb
        self.steps = steps
        self.adapt_every = adapt_every
        self.burst_steps = burst_steps
        self.replay_size = replay_size
        self.batch = batch
        self.seq_len = seq_len
        self.calib_batches = calib_batches
        self.rank_select = rank_select
        self.lr = lr
        self.serve_cfg = ServeCfg(max_batch=max_batch, max_len=max_len,
                                  temperature=temperature)
        self.ledger = build_ledger(session.cfg, batch, seq_len)
        self._data = LMStream(LMStreamCfg(vocab_size=session.cfg.vocab_size,
                                          seq_len=seq_len, global_batch=batch,
                                          seed=session.seed, branching=2))
        # any ReplayBuffer-contract policy slots in (reservoir / stratified /
        # ... from repro.scenarios.replay); default is the FIFO ring
        if replay is not None and replay.seq_len != seq_len:
            raise ValueError(f"injected replay buffer has seq_len "
                             f"{replay.seq_len}, adapter wants {seq_len}")
        self.replay = (replay if replay is not None
                       else ReplayBuffer(replay_size, seq_len,
                                         seed=session.seed))
        self._plan = None
        self._ds: DeviceSession | None = None
        self._retired_before_ds = 0   # observe() arrivals predating the DS

    # --- ledger / plan ------------------------------------------------------

    def ledger_report(self) -> dict:
        """Budget feasibility before anything trains (analytical bytes)."""
        led = self.ledger
        return {"ledger": led.summary(), "budget_mb": self.mem_budget_mb,
                "vanilla_fits": (led.vanilla_total_bytes
                                 <= self.mem_budget_mb * 2 ** 20),
                "rank1_floor_mb": round(led.min_bytes() / 2 ** 20, 4)}

    @property
    def plan(self):
        """The §3.3 calibration + budget-search plan (computed once)."""
        if self._plan is None:
            s = self.session
            calib = [self._data.batch(i) for i in range(self.calib_batches)]
            self._plan = build_plan(s.model, s.cfg, s.params,
                                    self.mem_budget_mb, calib,
                                    batch_size=self.batch,
                                    seq_len=self.seq_len,
                                    method=self.rank_select, seed=s.seed)
        return self._plan

    @property
    def plan_respects_budget(self) -> bool:
        return (self.ledger.bytes_for(self.plan.rank_plan)
                <= self.plan.budget_bytes)

    def plan_report(self) -> dict:
        return {"plan": self.plan.summary(),
                "plan_respects_ledger_budget": self.plan_respects_budget}

    def replan(self, mem_budget_mb: float | None = None,
               batches: Sequence[dict] | None = None):
        """Re-invoke the §3.3 planner mid-stream (elastic budget / subspace
        re-selection): re-calibrate — on ``batches`` from the *current*
        traffic distribution when given — re-search ranks under the (possibly
        new) budget, and swap the plan into a live ``DeviceSession`` via
        fresh ``init_asi_state`` shapes plus a fresh optimizer.  The params
        and the serving engine are untouched: in-flight requests keep
        decoding, only the adaptation path re-shapes.  Returns the new plan.
        """
        s = self.session
        if mem_budget_mb is not None:
            self.mem_budget_mb = mem_budget_mb
        calib = (list(batches) if batches is not None
                 else [self._data.batch(i) for i in range(self.calib_batches)])
        self._plan = build_plan(s.model, s.cfg, s.params, self.mem_budget_mb,
                                calib, batch_size=self.batch,
                                seq_len=self.seq_len, method=self.rank_select,
                                seed=s.seed)
        plan = self._plan
        s.rank_plan = {k: int(v) for k, v in plan.rank_plan.items()}
        if self._ds is not None:                  # re-shape the live session
            ds = self._ds
            s.asi_state = s.model.init_asi(jax.random.PRNGKey(s.seed),
                                           rank_plan=plan.rank_plan)
            s.attach_optimizer(self.lr, max(self.steps // 5, 1),
                               max(self.steps, 2))
            ds.asi_state = s.asi_state
            ds.opt_state = s.opt_state
            ds._train_step = s.train_step(donate=False)
        return plan

    # --- the device session -------------------------------------------------

    def device_session(self) -> DeviceSession:
        """The wired ``DeviceSession`` (built once): planned-rank ASI state,
        fresh optimizer, non-donating train step, shared replay buffer."""
        if self._ds is None:
            s = self.session
            plan = self.plan
            s.rank_plan = {k: int(v) for k, v in plan.rank_plan.items()}
            s.asi_state = s.model.init_asi(jax.random.PRNGKey(s.seed),
                                           rank_plan=plan.rank_plan)
            s.attach_optimizer(self.lr, max(self.steps // 5, 1),
                               max(self.steps, 2))
            step_fn = s.train_step(donate=False)  # engine shares the params
            ds = DeviceSession(
                s.model, s.params, step_fn, s.opt_state, s.asi_state,
                self.serve_cfg,
                SessionCfg(adapt_every=self.adapt_every,
                           burst_steps=self.burst_steps,
                           total_steps=self.steps, batch_size=self.batch,
                           seq_len=self.seq_len, replay_size=self.replay_size),
                probe_batch=self._data.batch(10_000), seed=s.seed,
                telemetry=s.telemetry)
            ds.replay = self.replay               # observe() and run() share it
            ds.report.retired = self._retired_before_ds
            # seed the pre-adaptation probe baseline here (not only in
            # ds.run()) so the observe()+step() path measures forgetting
            # from *before* the first burst too
            baseline = ds.probe_loss()
            if baseline is not None:
                ds.report.probe_losses.append(baseline)
            self._ds = ds
        return self._ds

    def _sync(self, ds: DeviceSession):
        s = self.session
        s.params, s.opt_state, s.asi_state = ds.params, ds.opt_state, \
            ds.asi_state
        s.step = ds.report.steps

    # --- adaptation ---------------------------------------------------------

    def observe(self, req: Request) -> "Adapter":
        """Feed a retired request's token stream into the replay buffer
        (pass this as ``server.run(..., on_retire=adapter.observe)``)."""
        self.replay.add(list(req.prompt) + list(req.out))
        if self._ds is not None:
            self._ds.report.retired += 1
        else:
            self._retired_before_ds += 1
        return self

    def _sync_in(self, ds: DeviceSession):
        """Point the device session at the session's current state (the
        session may have moved on via trainer.fit() or an external swap)."""
        if ds.params is not self.session.params:
            ds.params = ds.engine.params = self.session.params
            ds.opt_state = self.session.opt_state
            ds.asi_state = self.session.asi_state

    def step(self, n: int | None = None):
        """Run up to ``n`` (default ``burst_steps``) replay train steps and
        return the updated params — feed them to ``server.swap_params``."""
        ds = self.device_session()
        self._sync_in(ds)
        if ds._step_count >= self.steps:
            warnings.warn(
                f"adaptation budget exhausted ({self.steps} steps): "
                "Adapter.step() is now a no-op — build the adapter with a "
                "larger steps= budget for longer-lived loops", stacklevel=2)
        ds.adapt_steps(self.burst_steps if n is None else n)
        self._sync(ds)
        return self.session.params

    def run(self, requests: list[Request],
            drain_steps: bool = True):
        """Train-while-serve: decode ``requests`` on the device session's
        engine with adaptation bursts riding the retirement hook."""
        ds = self.device_session()
        self._sync_in(ds)
        report = ds.run(requests, drain_steps=drain_steps)
        self._sync(ds)
        return report

    @property
    def report(self):
        return None if self._ds is None else self._ds.report
