"""Roofline-term extraction from a compiled dry-run artifact.

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_operand_bytes_per_device / link_bw

cost_analysis() on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes, so the terms above are already per-chip; the global formulation
in the spec (global / (chips x rate)) is identical.  Collective bytes are not
in cost_analysis — we parse the post-partitioning HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (operand shapes appear inline in HLO text).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\)|[\w\[\],{}\s]+?))\s*"        # scalar or tuple type
    r"([\w\-]+)\(([^)]*)\)", re.MULTILINE)
_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: dict[str, int]
    count: int


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in (per-device) HLO text.

    Operand shapes are resolved through a name->result-type map built from
    all definition lines (modern HLO text omits operand shapes inline); when
    an operand cannot be resolved, we fall back to the collective's result
    shape adjusted by the replica-group size (exact for all-reduce /
    all-to-all / collective-permute; all-gather operand = result/group;
    reduce-scatter operand = result*group).
    """
    defs: dict[str, str] = {}
    ops = []
    for m in _DEF_RE.finditer(hlo_text):
        name, rtype, opcode, operands = m.groups()
        defs[name] = rtype
        base = opcode.removesuffix("-start").removesuffix("-done")
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            ops.append((base, rtype, operands, m.group(0)))

    by_kind: dict[str, int] = {}
    count = 0
    for kind, rtype, operands, line in ops:
        b = 0
        for om in _OPERAND_RE.finditer(operands):
            t = defs.get(om.group(1))
            if t:
                b += _shapes_bytes(t)
        if b == 0:                                 # fallback via result shape
            rb = _shapes_bytes(rtype)
            g = _GROUPS_RE.search(line)
            group = int(g.group(2)) if g else 1
            if kind == "all-gather":
                b = rb // max(group, 1)
            elif kind == "reduce-scatter":
                b = rb * group
            else:
                b = rb
        if b:
            by_kind[kind] = by_kind.get(kind, 0) + b
            count += 1
    return CollectiveStats(sum(by_kind.values()), by_kind, count)


def collective_counts(hlo_text: str) -> dict[str, int]:
    """Count collective ops per kind in (per-device) HLO text.

    Unlike :func:`collective_bytes` this counts every definition (including
    zero-byte fallback failures), with ``-start``/``-done`` async pairs
    counted once — it is the comm-signature metric the graph-lint
    collectives-audit gates against ``partition.COMM_SIGNATURE``.
    """
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _DEF_RE.finditer(hlo_text):
        opcode = m.group(3)
        base = opcode.removesuffix("-start").removesuffix("-done")
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            counts[base] += 1
    return counts


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # analytic useful FLOPs (global)
    n_chips: int

    @property
    def useful_ratio(self) -> float:
        total_hw = self.flops * self.n_chips
        return self.model_flops / total_hw if total_hw else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time over the modeled step time: how close the step
        is to the compute roofline for its *useful* (model) FLOPs."""
        t_useful = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return t_useful / self.bound_s if self.bound_s else 0.0


def analyze(cost: dict, hlo_text: str, n_chips: int,
            model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    if flops < 0:
        flops = 0.0
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text).total_bytes
    c = flops / PEAK_FLOPS
    m = hbm / HBM_BW
    k = coll / LINK_BW
    dominant = max((("compute", c), ("memory", m), ("collective", k)),
                   key=lambda t: t[1])[0]
    return Roofline(flops, hbm, coll, c, m, k, dominant, model_flops, n_chips)


def model_flops_train(n_params_trained: float, tokens: float) -> float:
    """6·N·D (dense training convention; use N_active for MoE)."""
    return 6.0 * n_params_trained * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    """2·N·tokens (one forward, no backward)."""
    return 2.0 * n_params_active * tokens
