"""Architecture registry: ``get_config(arch_id)`` for every assigned arch."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCHS = (
    "h2o-danube-3-4b",
    "internlm2-20b",
    "phi3-mini-3.8b",
    "tinyllama-1.1b",
    "jamba-1.5-large-398b",
    "mamba2-130m",
    "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b",
    "whisper-medium",
    "internvl2-1b",
)


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
