"""Multi-device tests: run in a subprocess with host-platform placeholder
devices (the main test process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ,
           REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=8",
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH=os.path.join(REPO, "src"))


def _run(code: str, timeout=900):
    return subprocess.run([sys.executable, "-c", code], env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def _dryrun(args, timeout=1200):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=ENV, capture_output=True, text=True, timeout=timeout)


def test_dryrun_train_cell_tiny_mesh():
    p = _dryrun(["--arch", "tinyllama-1.1b", "--shape", "train_4k",
                 "--mesh", "2,2:data,model"])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    res = json.loads([l for l in p.stdout.splitlines()
                      if l.startswith("{")][0])
    assert res["status"] == "ok"
    assert res["collective_ops"] > 0            # TP must communicate
    assert res["flops_per_device"] > 0
    assert res["dominant"] in ("compute", "memory", "collective")


def test_dryrun_decode_cell_tiny_mesh():
    p = _dryrun(["--arch", "mamba2-130m", "--shape", "decode_32k",
                 "--mesh", "2,2:data,model"])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    res = json.loads([l for l in p.stdout.splitlines()
                      if l.startswith("{")][0])
    assert res["status"] == "ok"


def test_dryrun_multipod_axis_shards():
    p = _dryrun(["--arch", "tinyllama-1.1b", "--shape", "decode_32k",
                 "--mesh", "2,2,2:pod,data,model"])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    res = json.loads([l for l in p.stdout.splitlines()
                      if l.startswith("{")][0])
    assert res["status"] == "ok"
    assert res["mesh"] == {"pod": 2, "data": 2, "model": 2}


def test_dryrun_asi_compress_mode():
    p = _dryrun(["--arch", "tinyllama-1.1b", "--shape", "train_4k",
                 "--mesh", "2,2:data,model", "--compress", "asi"])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    res = json.loads([l for l in p.stdout.splitlines()
                      if l.startswith("{")][0])
    assert res["status"] == "ok"
    assert res["compress"] == "asi"


def test_compressed_psum_reduces_wire_bytes_and_stays_accurate():
    code = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.parallel import collectives as C

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
# low-rank-ish per-worker gradients with small worker noise
base = jax.random.normal(key, (64, 6)) @ jax.random.normal(jax.random.fold_in(key,1), (6, 32))
gs = jnp.stack([base + 0.05*jax.random.normal(jax.random.fold_in(key, i), base.shape)
                for i in range(8)])
st = C.init_state(key, base.shape, rank=8)

@jax.jit
def run(gs, st):
    def f(g, q, e):
        gh, ns = C.compressed_psum(g[0], C.PowerSGDState(q=q, err=e[0]),
                                   "data")
        return gh[None], ns.q[None], ns.err[None]
    # err (error feedback) is per-worker local -> sharded in/out specs
    errs = jnp.tile(st.err[None], (8, 1, 1))
    return shard_map(f, mesh=mesh, in_specs=(P("data"), P(), P("data")),
                     out_specs=(P("data"), P("data"), P("data")),
                     check_rep=False)(gs, st.q, errs)

gh, q, err = run(gs, st)
exact = gs.mean(0)
rel = float(jnp.linalg.norm(gh[0] - exact) / jnp.linalg.norm(exact))
dense = C.wire_bytes_dense(base.shape)
comp = C.wire_bytes_compressed(base.shape, 8)
print(json.dumps({"rel": rel, "dense": dense, "comp": comp}))
"""
    p = _run(code)
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-1500:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["rel"] < 0.15                    # near-exact on low-rank grads
    assert out["comp"] < 0.5 * out["dense"]     # the wire win


def test_elastic_reshard_roundtrip():
    code = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.checkpoint.elastic import reshard
from repro.launch.mesh import make_mesh

x = {"w": jnp.arange(64.).reshape(8, 8), "b": jnp.ones(3)}
specs = {"w": P("data", "model"), "b": P()}
m1 = make_mesh((2, 2), ("data", "model"))
placed = reshard(x, specs, m1)
assert placed["w"].sharding.spec == P("data", "model")
m2 = make_mesh((4, 2), ("data", "model"))       # elastic: grow data axis
placed2 = reshard(jax.tree.map(np.asarray, placed), specs, m2)
np.testing.assert_array_equal(np.asarray(placed2["w"]), np.arange(64.).reshape(8,8))
print("OK")
"""
    p = _run(code)
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-1500:]
    assert "OK" in p.stdout
