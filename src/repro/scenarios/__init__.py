"""Continual-learning scenario harness (streams, replay policies, runner).

Public surface::

    from repro.scenarios import run_scenario
    report = run_scenario(scenario="domain-shift", arch="tinyllama_1_1b",
                          reduced=True, seed=0)
    report.curves()       # deterministic benchmark series (pure in seed)
    report.summary()      # recovery / forgetting / throughput rollup
"""
from repro.scenarios.replay import (REPLAY_POLICIES, ReplayBuffer,
                                    ReservoirReplay, StratifiedReplay,
                                    make_replay)
from repro.scenarios.runner import (SCENARIOS, ScenarioCfg, ScenarioReport,
                                    measured_plan_bytes, run_scenario)
from repro.scenarios.streams import (BurstyTraffic, DomainShiftStream,
                                     TaskSequenceStream, TaskStreamCfg,
                                     TrafficCfg, VisionPhaseStream,
                                     VisionStreamCfg)

__all__ = [
    "SCENARIOS", "ScenarioCfg", "ScenarioReport", "run_scenario",
    "measured_plan_bytes",
    "REPLAY_POLICIES", "ReplayBuffer", "ReservoirReplay", "StratifiedReplay",
    "make_replay",
    "BurstyTraffic", "DomainShiftStream", "TaskSequenceStream",
    "TaskStreamCfg", "TrafficCfg", "VisionPhaseStream", "VisionStreamCfg",
]
