"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 (MoE every other layer).

Notes: the mamba sublayers use the Mamba2/SSD formulation (TPU/MXU-friendly;
see DESIGN.md hardware-adaptation).  Adafactor keeps optimizer state within
v5e HBM at 398B params; FSDP shards params over the data axes."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_tok=2,
    moe_layer_period=2,
    attn_layer_period=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_theta=10000.0,
    act="silu",
    fsdp=True,
    optimizer="adafactor",
)
