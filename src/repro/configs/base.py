"""Config system: model architectures and input-shape cells.

Every assigned architecture is a ``ModelConfig`` (exact numbers from the
assignment table); every input shape is a ``ShapeCfg``.  ``reduced()`` yields
the small same-family config used by CPU smoke tests; the full configs are
only ever lowered via ShapeDtypeStructs (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- attention ---
    rope_theta: float = 10000.0
    sliding_window: int = 0           # 0 -> full attention; >0 -> SWA (danube)
    qkv_bias: bool = False            # qwen2-style (internvl2 backbone)
    attn_chunk: int = 1024            # online-softmax block size
    kv_cache_dtype: str = ""          # "" -> activations dtype; "int8" for
                                      # quantized decode caches (C-cell lever)
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_layer_period: int = 1         # every k-th layer is MoE (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (mamba2 / jamba mamba sublayers) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (jamba) ---
    attn_layer_period: int = 0        # 8 -> 1 attention layer per 8 (1:7)
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 0                  # precomputed-frame count (stub frontend)
    # --- vlm (internvl2) ---
    n_img_tokens: int = 0             # precomputed-patch count (stub frontend)
    # --- numerics / structure ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    act: str = "silu"                 # silu -> SwiGLU MLP; gelu -> GELU MLP
    use_bias: bool = False            # MLP/attn-out biases (whisper)
    learned_pos: bool = False         # whisper-style positions instead of RoPE
    remat: str = "full"               # none | full | dots
    scan_layers: bool = True
    scan_unroll: bool = False         # dry-run: unroll layer scan so HLO cost
                                      # analysis & collective counts see every
                                      # layer (while-bodies are counted once)
    fsdp: bool = False                # shard weights over the data axes too
    optimizer: str = "adamw"          # adamw | sgdm | adafactor
    # --- paper technique (ASI) ---
    compress: str = "none"            # none | asi | hosvd
    asi_rank: int = 20
    asi_last_k: int = 2               # fine-tune the last k blocks
    kernel_backend: str = "auto"      # fused ASI kernels: auto (pallas on TPU,
                                      # jnp reference elsewhere) | pallas
                                      # (interpret off-TPU) | reference

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Same-family miniature for CPU smoke tests."""
        period = max(self.attn_layer_period, 1)
        n_layers = max(2, period)           # keep at least one full period
        if self.attn_layer_period:
            n_layers = period               # one jamba super-block
        return self.replace(
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
            n_experts=min(self.n_experts, 4),
            experts_per_tok=min(self.experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_len=min(self.enc_len, 16) if self.enc_len else 0,
            n_img_tokens=min(self.n_img_tokens, 4) if self.n_img_tokens else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            attn_chunk=16,
            dtype="float32",
            param_dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    def reduced(self) -> "ShapeCfg":
        return dataclasses.replace(self, seq_len=32, global_batch=2)


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def long_context_supported(cfg: ModelConfig) -> bool:
    """long_500k needs sub-quadratic attention: SSM / hybrid / SWA archs."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0
