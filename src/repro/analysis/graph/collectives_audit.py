"""collectives-audit: gate the compiled train step's communication
pattern against the layout's declared signature.

Collectives only exist *after* the SPMD partitioner runs, so this is the
one graph rule that cannot be device-free: the train step is compiled on
an 8-way forced-host-device mesh (in a subprocess when the current
process was not started with the XLA flag — device flags are read once
at backend init) and the per-device HLO is counted per collective kind
(``roofline.collective_counts``).  Counts are gated against
``parallel.partition.COMM_SIGNATURE``: a kind outside its layout's row
(a collective-permute in dp, an all-to-all in a pure-DP backward) is the
silent comm regression that erases the layout's scaling story without
failing a single numeric test.
"""
from __future__ import annotations

import json
import os
from typing import Iterator

from repro.analysis.core import Finding, rule
from repro.analysis.graph import harness

PARTITION_REL = "src/repro/parallel/partition.py"
LAYOUTS_ENV = "REPRO_GRAPH_LAYOUTS"
ARCH_ENV = "REPRO_GRAPH_COLLECTIVES_ARCH"
DEFAULT_ARCH = "tinyllama-1.1b"

_WORKER = """
import json
import jax, jax.numpy as jnp
from repro.configs.registry import get_config
from repro.models import build_model
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import make_mesh_plan, make_train_step
from repro.launch.mesh import make_layout_mesh
from repro.launch.roofline import collective_counts

ARCH = {arch!r}
LAYOUTS = {layouts!r}
cfg = get_config(ARCH).reduced().replace(compress="asi")
api = build_model(cfg)
key = jax.random.PRNGKey(0)
data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=16,
                            global_batch=8, seed=0, branching=2))
out = {{}}
for layout in LAYOUTS:
    params = jax.eval_shape(api.init, key)
    asi = jax.eval_shape(api.init_asi, key)
    mask = api.trainable_mask(params)
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 1, 6), clip_norm=2.0)
    opt_state = jax.eval_shape(opt.init, params)
    batch = data.batch(0)
    mesh = make_layout_mesh(layout, (2, 4) if layout == "tp" else None)
    plan = make_mesh_plan(cfg, mesh, layout, params, opt_state, asi, batch)
    step = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                           trainable_mask=mask,
                           kernel_backend=cfg.kernel_backend,
                           plan=plan, grad_accum=1)
    with plan.activate():
        lowered = step.lower(params, opt_state, asi, batch, jnp.int32(0))
    out[layout] = collective_counts(lowered.compile().as_text())
print(json.dumps(out))
"""


def measured_counts(arch: str, layouts: list[str]) -> dict[str, dict]:
    """Per-layout collective counts of the compiled train step, via a
    forced-8-device subprocess."""
    code = _WORKER.format(arch=arch, layouts=list(layouts))
    stdout = harness.run_forced_devices(code)
    return json.loads(stdout.strip().splitlines()[-1])


def signature_findings(layout: str, counts: dict[str, int],
                       signature: dict[str, dict],
                       anchor_line: int = 1) -> Iterator[Finding]:
    """Gate one layout's measured counts against the declared signature
    (separated from the rule so tests can feed a deliberately wrong
    signature without an 8-device compile)."""
    row = signature.get(layout)
    if row is None:
        yield Finding(rule="collectives-audit", path=PARTITION_REL,
                      line=anchor_line,
                      message=f"layout {layout!r} has no COMM_SIGNATURE row")
        return
    for kind, n in sorted(counts.items()):
        bounds = row.get(kind)
        if bounds is None:
            if n:
                yield Finding(
                    rule="collectives-audit", path=PARTITION_REL,
                    line=anchor_line,
                    message=f"{layout}: {n} {kind} op(s) in the compiled "
                            f"train step but COMM_SIGNATURE forbids "
                            f"{kind} for this layout")
            continue
        lo, hi = bounds
        if n < lo or (hi is not None and n > hi):
            yield Finding(
                rule="collectives-audit", path=PARTITION_REL,
                line=anchor_line,
                message=f"{layout}: {kind} count {n} outside declared "
                        f"bounds [{lo}, {'inf' if hi is None else hi}]")
    for kind, (lo, _hi) in sorted(row.items()):
        if lo > 0 and counts.get(kind, 0) == 0:
            yield Finding(
                rule="collectives-audit", path=PARTITION_REL,
                line=anchor_line,
                message=f"{layout}: required {kind} is absent — the "
                        f"layout's structural collective disappeared "
                        f"(e.g. gradients no longer synchronized)")


def _anchor_line(contexts) -> int:
    for ctx in contexts:
        if ctx.rel == PARTITION_REL:
            for lineno, text in enumerate(ctx.source.splitlines(), start=1):
                if text.startswith("COMM_SIGNATURE"):
                    return lineno
    return 1


@rule("collectives-audit", scope="tree", plane="graph",
      doc="compiled dp/fsdp/tp train-step collectives vs the declared "
          "per-layout COMM_SIGNATURE (8 forced host devices, subprocess)")
def check_collectives(root, contexts) -> Iterator[Finding]:
    from repro.parallel.partition import COMM_SIGNATURE
    arch = os.environ.get(ARCH_ENV, DEFAULT_ARCH)
    layouts = [l.strip() for l in
               os.environ.get(LAYOUTS_ENV, "dp,fsdp,tp").split(",")
               if l.strip()]
    anchor = _anchor_line(contexts)
    try:
        measured = measured_counts(arch, layouts)
    except Exception as e:  # subprocess/toolchain failure is itself a finding
        yield Finding(rule="collectives-audit", path=PARTITION_REL,
                      line=anchor,
                      message=f"could not compile train steps for "
                              f"collective counting: {e}")
        return
    for layout in layouts:
        yield from signature_findings(layout, measured[layout],
                                      COMM_SIGNATURE, anchor)
