"""End-to-end training launcher — a thin argparse shim over ``repro.api``.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --compress asi --ckpt-dir /tmp/ckpt

Mesh-sharded training: ``--layout {dp,fsdp,tp}`` builds a (data, model) mesh
over all visible devices (override the split with ``--mesh D,M``);
``--grad-accum N`` scans N microbatches per step.  Validate on CPU with

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 20 --layout fsdp --grad-accum 2

All wiring lives in ``repro.api.Session``/``Trainer``; embed those instead
of calling ``main()`` programmatically (which is deprecated).
"""
from __future__ import annotations

import os

# compute/comm overlap: latency-hiding scheduler (no-op on CPU, effective on
# TPU); set before jax import.
os.environ.setdefault("LIBTPU_INIT_ARGS",
                      "--xla_tpu_enable_latency_hiding_scheduler=true")

import argparse
import json
import warnings


def build_parser() -> argparse.ArgumentParser:
    from repro import api

    ap = argparse.ArgumentParser(
        epilog="Full flag matrix, quickstart and architecture map: README.md")
    api.add_arch_argument(ap)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress", default="none",
                    choices=("none", "asi", "hosvd"))
    ap.add_argument("--kernel-backend", default="auto",
                    choices=("auto", "pallas", "reference"),
                    help="fused ASI kernel dispatch (see repro.kernels.dispatch)")
    ap.add_argument("--asi-rank", type=int, default=None)
    ap.add_argument("--asi-last-k", type=int, default=None)
    ap.add_argument("--layout", default=None, choices=("dp", "fsdp", "tp"),
                    help="mesh-sharded training over all visible devices; "
                         "omit for the single-device step")
    ap.add_argument("--mesh", default=None, metavar="D,M",
                    help="data,model axis sizes overriding the --layout "
                         "default split (e.g. 2,4)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches accumulated per optimizer step "
                         "(lax.scan inside the jitted step)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a simulated node failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    api.add_telemetry_arguments(ap)
    return ap


def main(argv=None):
    from repro import api

    api.warn_programmatic_use(__name__, argv)
    ap = build_parser()
    args = ap.parse_args(argv)
    try:                       # flag validation only; real failures traceback
        api.Trainer.validate(batch=args.batch, grad_accum=args.grad_accum,
                             layout=args.layout, mesh=args.mesh)
    except ValueError as e:
        ap.error(str(e))
    with api.telemetry_recorder(args) as rec:
        sess = api.Session.from_config(
            args.arch, reduced=args.reduced, seed=args.seed,
            compress=args.compress, kernel_backend=args.kernel_backend,
            asi_rank=args.asi_rank, asi_last_k=args.asi_last_k,
            telemetry=rec)
        trainer = sess.trainer(
            steps=args.steps, seq_len=args.seq_len, batch=args.batch,
            lr=args.lr, layout=args.layout, mesh=args.mesh,
            grad_accum=args.grad_accum, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, fail_at=args.fail_at)
        if trainer.mesh_info is not None:
            print(json.dumps(trainer.mesh_info))
        res = trainer.fit(on_log=lambda s, m: print(
            json.dumps({"step": s,
                        **{k: round(v, 4) for k, v in m.items()}})))
        print(json.dumps(trainer.summary(res)))
    return res


def __getattr__(name):
    if name == "build_data":        # pre-api helper, moved to repro.api
        warnings.warn("repro.launch.train.build_data moved to "
                      "repro.api.data_source", DeprecationWarning,
                      stacklevel=2)
        from repro import api
        return api.data_source
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


if __name__ == "__main__":
    main()
