"""repro-lint core: findings, rule registry, suppressions, file walker.

Two kinds of rules register here:

- *file rules* (``scope="file"``): called once per source file with a
  parsed ``FileContext`` (path, source, AST, suppression table).
- *tree rules* (``scope="tree"``): called once per lint run with the
  full list of ``FileContext`` objects — used by rules that need a
  cross-file view (partition coverage) or that import the package
  (config × layout sweeps via ``eval_shape``).

Rules yield ``Finding`` objects; the driver stamps ``suppressed`` by
consulting the per-line ``# repro-lint: disable=<rule>`` table, so rule
implementations never deal with suppression logic themselves.

Rules also carry a *plane* (DESIGN.md §14): ``ast`` rules read source
text, ``graph`` rules read what JAX actually traces/compiles (jaxpr
residuals, compiled-HLO collectives, executable aliasing, abstract call
signatures).  ``run_lint(plane=...)`` selects one plane or ``all``; both
planes share this registry, the suppression table, and the renderers.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Iterable, Iterator

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([\w,\- ]+)")

#: rule name -> (scope, callable, one-line description)
RULES: dict[str, tuple[str, Callable, str]] = {}

#: rule name -> plane ("ast" | "graph"); parallel to RULES so existing
#: consumers unpacking the 3-tuple keep working
PLANES: dict[str, str] = {}


def rules_in_plane(plane: str) -> list[str]:
    """Sorted rule names for one plane (or every plane for ``all``)."""
    if plane == "all":
        return sorted(RULES)
    return sorted(n for n in RULES if PLANES.get(n, "ast") == plane)


@dataclasses.dataclass
class Finding:
    """One lint offence, pointing at a file/line with a rule tag."""

    rule: str
    path: str            # repo-relative, forward slashes
    line: int
    message: str
    col: int = 0
    suppressed: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)


@dataclasses.dataclass
class FileContext:
    """Parsed view of one source file handed to file-scope rules."""

    path: str            # absolute
    rel: str             # repo-relative, forward slashes
    source: str
    tree: ast.Module
    # line -> set of rule names disabled on that line
    line_disables: dict[int, set[str]]
    # rule names disabled for the entire file
    file_disables: set[str]

    @classmethod
    def parse(cls, path: str, root: str) -> "FileContext":
        with open(path, encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        line_disables: dict[int, set[str]] = {}
        file_disables: set[str] = set()
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(text)
            if not m:
                continue
            names = {n.strip() for n in m.group(2).split(",") if n.strip()}
            if m.group(1) == "disable-file":
                file_disables |= names
            else:
                line_disables.setdefault(lineno, set()).update(names)
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        return cls(path=path, rel=rel, source=source, tree=tree,
                   line_disables=line_disables, file_disables=file_disables)

    def is_suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_disables or "all" in self.file_disables:
            return True
        names = self.line_disables.get(line, ())
        return rule in names or "all" in names


def rule(name: str, scope: str = "file", doc: str = "", plane: str = "ast"):
    """Register ``fn`` as a lint rule.  ``scope`` is ``file`` or ``tree``;
    ``plane`` is ``ast`` (source-level) or ``graph`` (jaxpr/HLO-level)."""
    assert scope in ("file", "tree"), scope
    assert plane in ("ast", "graph"), plane
    def wrap(fn):
        RULES[name] = (scope, fn, doc or (fn.__doc__ or "").strip()
                       .splitlines()[0] if (doc or fn.__doc__) else "")
        PLANES[name] = plane
        return fn
    return wrap


def iter_source_files(root: str, paths: Iterable[str] | None = None
                      ) -> Iterator[str]:
    """Yield absolute paths of the .py files a lint run covers.

    Default coverage is ``src/repro`` under ``root``; explicit ``paths``
    (files or directories) narrow it.
    """
    targets = list(paths) if paths else [os.path.join(root, "src", "repro")]
    seen = set()
    for target in targets:
        target = os.path.abspath(target)
        if os.path.isfile(target):
            if target.endswith(".py") and target not in seen:
                seen.add(target)
                yield target
            continue
        for dirpath, dirnames, filenames in os.walk(target):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    path = os.path.join(dirpath, fn)
                    if path not in seen:
                        seen.add(path)
                        yield path


def find_repo_root(start: str | None = None) -> str:
    """Walk up from ``start`` (or this file) to the directory holding
    ``src/repro`` — works from a checkout or an installed-in-place tree."""
    here = os.path.abspath(start or os.path.dirname(__file__))
    cur = here
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return here
        cur = parent


def run_lint(root: str | None = None,
             paths: Iterable[str] | None = None,
             select: Iterable[str] | None = None,
             ignore: Iterable[str] | None = None,
             plane: str = "ast") -> list[Finding]:
    """Run the registered rules and return all findings (suppressed ones
    included, flagged).  Import rule modules before calling this — the
    CLI and ``scripts/repro_lint.py`` do so via ``repro.analysis.rules``.

    ``plane`` selects which rule plane runs (``ast`` | ``graph`` | ``all``);
    an explicit ``select`` overrides the plane filter so tests and the CLI
    can target one graph rule without flipping ``--plane``."""
    assert plane in ("ast", "graph", "all"), plane
    root = root or find_repo_root()
    active = dict(RULES)
    if select:
        wanted = set(select)
        unknown = wanted - set(active)
        if unknown:
            raise SystemExit(f"repro-lint: unknown rule(s) in --select: "
                             f"{', '.join(sorted(unknown))}")
        active = {k: v for k, v in active.items() if k in wanted}
    elif plane != "all":
        active = {k: v for k, v in active.items()
                  if PLANES.get(k, "ast") == plane}
    if ignore:
        active = {k: v for k, v in active.items() if k not in set(ignore)}

    contexts: list[FileContext] = []
    findings: list[Finding] = []
    for path in iter_source_files(root, paths):
        try:
            contexts.append(FileContext.parse(path, root))
        except SyntaxError as e:
            findings.append(Finding(rule="parse-error",
                                    path=os.path.relpath(path, root),
                                    line=e.lineno or 0,
                                    message=f"does not parse: {e.msg}"))

    for name, (scope, fn, _doc) in active.items():
        if scope == "file":
            for ctx in contexts:
                for f in fn(ctx):
                    f.suppressed = ctx.is_suppressed(f.rule, f.line)
                    findings.append(f)
        else:
            by_rel = {ctx.rel: ctx for ctx in contexts}
            for f in fn(root, contexts):
                ctx = by_rel.get(f.path)
                if ctx is not None:
                    f.suppressed = ctx.is_suppressed(f.rule, f.line)
                findings.append(f)
    findings.sort(key=Finding.sort_key)
    return findings


def render_text(findings: list[Finding]) -> str:
    lines = []
    unsuppressed = 0
    for f in findings:
        tag = " (suppressed)" if f.suppressed else ""
        unsuppressed += not f.suppressed
        lines.append(f"{f.path}:{f.line}:{f.col}: [{f.rule}] "
                     f"{f.message}{tag}")
    lines.append(f"repro-lint: {unsuppressed} finding(s), "
                 f"{len(findings) - unsuppressed} suppressed")
    return "\n".join(lines)


def render_json(findings: list[Finding], root: str,
                plane: str = "ast") -> str:
    counts: dict[str, int] = {}
    for f in findings:
        if not f.suppressed:
            counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {
        "version": 2,
        "root": root,
        "plane": plane,
        "rules": rules_in_plane(plane),
        "findings": [f.to_dict() for f in findings],
        "counts": counts,
        "total": sum(counts.values()),
    }
    return json.dumps(doc, indent=2, sort_keys=True)


# ---------------------------------------------------------------------------
# shared AST helpers (used by several rule modules)

def dotted_name(node: ast.AST) -> str | None:
    """Return ``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def const_int(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = const_int(node.operand)
        return -inner if inner is not None else None
    return None
