"""A/B: fused ASI kernel pipeline vs the unfused two-pass formulation.

Two numbers per (fwd, bwd) phase:

* **HBM passes over the streamed operand** — analytic, backend-independent.
  Unfused, X is read for Y = X·W and again for P = X·V (and g for g_x = g·Wᵀ
  plus again for R = P̂ᵀ·g); fused, each is read once.  At paper shapes the
  streamed operand dominates traffic, so pass count is the roofline lever.
* **wall-clock** — measured through ``repro.kernels.dispatch`` on the active
  backend.  On TPU this times the compiled Pallas kernels; on CPU it times
  the jnp reference (the interpreter would only measure Python overhead), so
  the CPU wall-clock column is a sanity check, not the headline.

Run:  PYTHONPATH=src python -m benchmarks.fused_asi
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import dispatch

# (M tokens, K in-features, N out-features, r sketch rank)
SHAPES = [
    (4096, 1024, 1024, 32),       # attention-projection scale
    (4096, 1024, 4096, 32),       # MLP up-projection scale
    (16384, 2048, 2048, 32),      # long-batch fine-tune step
]


def _time(fn, *args, iters: int = 5) -> float:
    out = jax.block_until_ready(fn(*args))          # compile + warm cache
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    del out
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True) -> dict:
    backend = dispatch.resolve("auto")
    timed_backend = "auto"
    # Analytic, by construction of the kernels: unfused streams X twice
    # (Y = X·W then P = X·V) and g twice (g_x = g·Wᵀ then R = P̂ᵀ·g);
    # fused streams each exactly once.  Constant 2x, independent of shape.
    hbm_pass_ratio = 2.0
    key = jax.random.PRNGKey(0)
    rows = []
    for m, k, n, r in SHAPES:
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (m, k), jnp.float32)
        w = jax.random.normal(ks[1], (k, n)) * 0.05
        v = jax.random.normal(ks[2], (k, r))
        g = jax.random.normal(ks[3], (m, n))
        p_hat = jax.random.normal(ks[2], (m, r))

        # --- wall clock through dispatch ------------------------------------
        fused_fwd = jax.jit(
            lambda x, w, v: dispatch.matmul_sketch(x, w, v,
                                                   backend=timed_backend))
        unfused_fwd = jax.jit(lambda x, w, v: (x @ w, x @ v))
        fused_bwd = jax.jit(
            lambda g, w, p: dispatch.matmul_grad_sketch(g, w, p,
                                                        backend=timed_backend))
        unfused_bwd = jax.jit(lambda g, w, p: (g @ w.T, p.T @ g))

        row = {
            "shape": f"{m}x{k}x{n}r{r}",
            "fwd_fused_us": _time(fused_fwd, x, w, v),
            "fwd_unfused_us": _time(unfused_fwd, x, w, v),
            "bwd_fused_us": _time(fused_bwd, g, w, p_hat),
            "bwd_unfused_us": _time(unfused_bwd, g, w, p_hat),
        }
        rows.append(row)
        if verbose:
            print(f"{row['shape']}: fwd {row['fwd_fused_us']:.0f}us fused / "
                  f"{row['fwd_unfused_us']:.0f}us unfused, "
                  f"bwd {row['bwd_fused_us']:.0f}us / "
                  f"{row['bwd_unfused_us']:.0f}us "
                  f"({hbm_pass_ratio:.0f}x fewer streamed-operand passes)")
    out = {"backend": backend, "rows": rows,
           "hbm_pass_ratio": hbm_pass_ratio}
    if verbose:
        print(f"active backend: {backend}")
    return out


if __name__ == "__main__":
    run()
