"""One code path for reading XLA's ``memory_analysis()`` off a compiled
executable.

Three consumers used to hand-roll this — the ledger's measured column
(``ondevice/ledger.py``), the dryrun report (``api/analyze.py``), and the
profiler bridge's byte gauges (``telemetry/jaxprof.py``) — each with its
own field list and its own idea of what a missing backend looks like.
The graph-lint plane reconciles measured bytes against analytic bytes,
which only means something if every reporter reads the same numbers the
same way; this module is that single reader.

Fallback contract (uniform across callers): ``{"error": ...}`` when the
analysis call itself raises (interpret-only backends), ``{}`` when the
backend reports nothing (no devices / fields absent on CPU) — callers
needing the legacy ``None`` sentinel use :func:`stats_or_none`.
"""
from __future__ import annotations

#: every byte field XLA may report, superset across backends
MEM_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")

#: the persistent-vs-transient split the ledger's measured column uses
LEDGER_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes")

#: fields exported as telemetry gauges (profiler bridge)
GAUGE_FIELDS = ("temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "generated_code_size_in_bytes")


def compiled_memory_stats(compiled, fields: tuple = MEM_FIELDS) -> dict:
    """Byte counts from ``compiled.memory_analysis()``, keyed by field.

    Only fields the backend actually reports appear; ``{"error": str}``
    when the analysis raises, ``{}`` when it returns nothing.
    """
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # noqa: BLE001
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in fields:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def stats_or_none(compiled, fields: tuple = MEM_FIELDS) -> dict | None:
    """Like :func:`compiled_memory_stats` but collapses both fallbacks
    (error / nothing reported) to ``None`` — the ledger's legacy
    "no measured column available" sentinel."""
    stats = compiled_memory_stats(compiled, fields)
    if not stats or "error" in stats:
        return None
    return stats
