"""Unit tests for the paper's core: subspace iteration, warm start, storage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asi import (MatrixASIState, TuckerASIState, compression_ratio,
                            matrix_asi_step, matrix_reconstruct,
                            matrix_storage_elems, orthonormalize,
                            tucker_asi_step, tucker_reconstruct,
                            tucker_storage_elems)


def _lowrank_matrix(key, m, k, r, noise=0.01):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (m, r))
    v = jax.random.normal(k2, (k, r))
    return u @ v.T + noise * jax.random.normal(k3, (m, k))


def test_orthonormalize_columns():
    p = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    q = orthonormalize(p)
    gram = q.T @ q
    np.testing.assert_allclose(np.asarray(gram), np.eye(8), atol=1e-5)


def test_matrix_asi_converges_to_svd():
    key = jax.random.PRNGKey(1)
    x = _lowrank_matrix(key, 128, 48, 6)
    st = MatrixASIState.init(key, 48, 6)
    errs = []
    for _ in range(5):
        p, q, st = matrix_asi_step(x, st)
        errs.append(float(jnp.linalg.norm(x - matrix_reconstruct(p, q))
                          / jnp.linalg.norm(x)))
    # truncated-SVD optimum for reference
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    opt = float(jnp.linalg.norm(x - (u[:, :6] * s[:6]) @ vt[:6])
                / jnp.linalg.norm(x))
    assert errs[-1] <= errs[0] + 1e-6          # iterations do not diverge
    assert errs[-1] < 2.0 * opt + 1e-3         # close to optimal


def test_warm_start_beats_cold_start_on_drifting_stream():
    """Paper §3.4: under slow activation drift, reusing the previous factors
    gives a strictly better single-iteration approximation than a fresh
    random start (this is the +3.87% accuracy mechanism)."""
    key = jax.random.PRNGKey(2)
    x = _lowrank_matrix(key, 256, 64, 8, noise=0.02)
    warm = MatrixASIState.init(jax.random.PRNGKey(3), 64, 8)
    warm_errs, cold_errs = [], []
    for t in range(12):
        key, sub = jax.random.split(key)
        x = x + 0.01 * jax.random.normal(sub, x.shape)   # slow drift
        p, q, warm = matrix_asi_step(x, warm)
        warm_errs.append(float(jnp.linalg.norm(x - matrix_reconstruct(p, q))))
        cold = MatrixASIState.init(jax.random.fold_in(key, t), 64, 8)
        pc, qc, _ = matrix_asi_step(x, cold)
        cold_errs.append(float(jnp.linalg.norm(x - matrix_reconstruct(pc, qc))))
    assert np.mean(warm_errs[3:]) < np.mean(cold_errs[3:])


def test_tucker_asi_recovers_lowrank_tensor():
    key = jax.random.PRNGKey(4)
    ranks = (3, 4, 3, 2)
    core = jax.random.normal(key, ranks)
    factors = [orthonormalize(jax.random.normal(jax.random.fold_in(key, i),
                                                (d, r)))
               for i, (d, r) in enumerate(zip((8, 12, 10, 6), ranks))]
    a = tucker_reconstruct(core, factors)
    st = TuckerASIState.init(jax.random.PRNGKey(5), a.shape, ranks)
    for _ in range(6):
        c, f, st = tucker_asi_step(a, st)
    err = float(jnp.linalg.norm(a - tucker_reconstruct(c, f))
                / jnp.linalg.norm(a))
    assert err < 1e-3


def test_storage_formulas_match_actual_arrays():
    key = jax.random.PRNGKey(6)
    a = jax.random.normal(key, (8, 16, 10, 12))
    ranks = (2, 3, 4, 5)
    st = TuckerASIState.init(key, a.shape, ranks)
    core, factors, _ = tucker_asi_step(a, st)
    actual = core.size + sum(f.size for f in factors)
    assert actual == tucker_storage_elems(a.shape, ranks)      # paper eq. 5
    # matrix variant
    x = jax.random.normal(key, (64, 32))
    ms = MatrixASIState.init(key, 32, 7)
    p, q, _ = matrix_asi_step(x, ms)
    assert p.size + q.size == matrix_storage_elems(64, 32, 7)


def test_compression_ratio_eq19():
    dims, ranks = (128, 32, 28, 28), (1, 1, 1, 1)
    rc = compression_ratio(dims, ranks)
    full = int(np.prod(dims))
    stored = 1 + sum(dims)
    assert abs(rc - full / stored) < 1e-9
    assert rc > 100     # the "120x" regime of the paper exists at rank 1
