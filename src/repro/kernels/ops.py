"""Jit'd dispatch wrappers around the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they run
in interpret mode — the kernel body executes in Python, which validates the
exact TPU code path bit-for-bit against the oracles in ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.paged_attention import paged_attention as _paged_attention
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul_sketch(x: Array, w: Array, v: Array, **kw):
    # backend="pallas": compiled on TPU, interpret elsewhere — these wrappers
    # exist to exercise the kernel code path; policy lives in dispatch.
    return dispatch.matmul_sketch(x, w, v, backend="pallas", **kw)


def matmul_grad_sketch(g: Array, w: Array, p_hat: Array, **kw):
    return dispatch.matmul_grad_sketch(g, w, p_hat, backend="pallas", **kw)


def flash_attention(q: Array, k: Array, v: Array, **kw):
    kw.setdefault("interpret", _interpret())
    # pick valid block sizes for any sequence length
    sq, skv = q.shape[1], k.shape[1]
    bq = kw.pop("bq", 512)
    bk = kw.pop("bk", 512)
    while sq % min(bq, sq):
        bq -= 1
    while skv % min(bk, skv):
        bk -= 1
    return _flash_attention(q, k, v, bq=min(bq, sq), bk=min(bk, skv), **kw)


def paged_attention(q: Array, k_pool: Array, v_pool: Array, table: Array,
                    pos: Array, **kw):
    kw.setdefault("interpret", _interpret())
    return _paged_attention(q, k_pool, v_pool, table, pos, **kw)


def ssd_scan(x: Array, dt: Array, a: Array, b: Array, c: Array, *,
             n_heads: int, chunk: int = 256, **kw):
    kw.setdefault("interpret", _interpret())
    s = x.shape[1]
    while s % min(chunk, s):
        chunk -= 1
    return _ssd_scan(x, dt, a, b, c, n_heads=n_heads, chunk=min(chunk, s), **kw)
