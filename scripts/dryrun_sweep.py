"""Production dry-run sweep driver: every (arch x shape x mesh) cell, one
fresh subprocess per cell (XLA leaks compile memory), resumable via the
results JSONL.  Cheap cells first so the roofline table fills up early."""
import json
import os
import subprocess
import sys
import time

RESULTS = "results/dryrun.jsonl"
ARCH_ORDER = [
    "tinyllama-1.1b", "mamba2-130m", "internvl2-1b", "phi3-mini-3.8b",
    "h2o-danube-3-4b", "whisper-medium", "granite-moe-3b-a800m",
    "internlm2-20b", "moonshot-v1-16b-a3b", "jamba-1.5-large-398b",
]
SHAPE_ORDER = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def done_cells():
    seen = set()
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            for line in f:
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if d.get("status") in ("ok", "skipped") \
                        and d.get("compress", "none") == "none":
                    seen.add((d["arch"], d["shape"], bool(d.get("multi_pod"))))
    return seen


def main():
    os.makedirs("results", exist_ok=True)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("REPRO_XLA_FLAGS", None)         # use the production 512 devices
    cells = [(a, s, mp)
             for mp in (False, True)
             for s in SHAPE_ORDER
             for a in ARCH_ORDER]
    seen = done_cells()
    todo = [c for c in cells if c not in seen]
    print(f"{len(todo)} cells to run ({len(seen)} already done)", flush=True)
    for arch, shape, mp in todo:
        args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                "--shape", shape, "--out", RESULTS]
        if mp:
            args.append("--multi-pod")
        t0 = time.time()
        try:
            p = subprocess.run(args, env=env, capture_output=True, text=True,
                               timeout=int(os.environ.get("CELL_TIMEOUT",
                                                          5400)))
            ok = p.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            with open(RESULTS, "a") as f:
                f.write(json.dumps({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "status": "timeout"})
                        + "\n")
        print(f"{arch:24s} {shape:12s} mp={int(mp)} "
              f"{'ok' if ok else 'FAIL'} {time.time()-t0:6.0f}s", flush=True)


if __name__ == "__main__":
    main()
