"""Recorder-overhead gate: telemetry-on vs telemetry-off on the serve trace.

The same seeded paged-serving traffic trace (``serve_throughput.make_trace``)
is replayed by two engines: one with no recorder attached (aggregates only —
the default every engine gets) and one with a fully enabled event-recording
``Recorder``.  Best-of-``REPEATS`` tokens/s per arm bounds timing noise; the
gate asserts the event plane costs < ``GATE_FRAC`` (2%) throughput, and that
the lifecycle counts re-derived from the recorded events match the engine's
``last_stats`` exactly (one source of truth, observed two ways).

Run:  PYTHONPATH=src python -m benchmarks.telemetry_overhead
"""
from __future__ import annotations

import jax

from benchmarks.serve_throughput import (TRACE_ARCH, TRACE_POOL_BLOCKS,
                                         _stats_counts, _trace_cfgs,
                                         derived_lifecycle_counts,
                                         make_trace)
from repro.configs.registry import get_config
from repro.models import build_model
from repro.runtime.serve_loop import Engine
from repro.telemetry import Recorder

N_REQUESTS = 24
SEED = 0
REPEATS = 5
GATE_FRAC = 0.02


def run(verbose: bool = True) -> dict:
    cfg = get_config(TRACE_ARCH).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    _, paged_cfg = _trace_cfgs(TRACE_POOL_BLOCKS)

    # both arms share warmed engines, and the timed replays alternate
    # off/on so slow machine-load drift hits both arms equally instead of
    # biasing whichever arm ran second
    off_eng = Engine(api, params, paged_cfg)
    rec = Recorder(capacity=1 << 15)
    on_eng = Engine(api, params, paged_cfg, telemetry=rec)
    off_eng.run(make_trace(N_REQUESTS, SEED))        # warm-up: compile
    on_eng.run(make_trace(N_REQUESTS, SEED))

    off_tok_s = on_tok_s = 0.0
    events = []
    for _ in range(REPEATS):
        off_eng.run(make_trace(N_REQUESTS, SEED))
        off_tok_s = max(off_tok_s, off_eng.last_stats.tokens_per_s)
        mark = len(rec.events)
        on_eng.run(make_trace(N_REQUESTS, SEED))
        on_tok_s = max(on_tok_s, on_eng.last_stats.tokens_per_s)
        events = list(rec.events)[mark:]

    derived = derived_lifecycle_counts(events)
    parity = derived == _stats_counts(on_eng.last_stats)
    overhead = 1.0 - (on_tok_s / off_tok_s) if off_tok_s else 1.0
    out = {
        "arch": TRACE_ARCH, "n_requests": N_REQUESTS, "seed": SEED,
        "repeats": REPEATS, "gate_frac": GATE_FRAC,
        "off_tok_s": off_tok_s, "on_tok_s": on_tok_s,
        "overhead_frac": overhead,
        "events_per_run": len(events), "dropped": rec.dropped,
        "derived_matches_stats": parity,
    }
    if verbose:
        print(f"telemetry off  {off_tok_s:7.1f} tok/s (best of {REPEATS})")
        print(f"telemetry on   {on_tok_s:7.1f} tok/s "
              f"({len(events)} events/run, {rec.dropped} dropped)")
        print(f"overhead       {overhead * 100:+.2f}% "
              f"(gate < {GATE_FRAC * 100:.0f}%)  "
              f"derived==stats: {'OK' if parity else 'FAIL'}")
    assert parity, (
        f"event-derived lifecycle counts {derived} diverged from "
        f"last_stats {_stats_counts(on_eng.last_stats)}")
    assert rec.dropped == 0, "event ring overflowed during the trace"
    assert overhead < GATE_FRAC, (
        f"recorder overhead {overhead * 100:.2f}% exceeds the "
        f"{GATE_FRAC * 100:.0f}% gate")
    return out


if __name__ == "__main__":
    run()
