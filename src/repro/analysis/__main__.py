"""CLI for repro-lint: ``python -m repro.analysis [paths] [options]``.

Examples:

    python -m repro.analysis --format json
    python -m repro.analysis --select jit-purity src/repro/runtime
    python -m repro.analysis --ignore partition-coverage --format text

Exit status is 0 when no *unsuppressed* findings remain, 1 otherwise
(suppressed findings are still reported, flagged, so CI artifacts keep
the full audit trail).
"""
from __future__ import annotations

import argparse
import sys


def build_parser() -> argparse.ArgumentParser:
    from repro.analysis.rules import RULES
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: static contract checks for ASI residuals, "
                    "jit purity, partition coverage, Pallas geometry, and "
                    "launch shims.",
        epilog="rules: " + "; ".join(
            f"{name} — {doc}" for name, (_s, _f, doc) in sorted(RULES.items())))
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="output format (default: text)")
    p.add_argument("--select", action="append", default=None,
                   metavar="RULE",
                   help="run only these rules (repeatable, or comma-"
                        "separated)")
    p.add_argument("--ignore", action="append", default=None,
                   metavar="RULE",
                   help="skip these rules (repeatable, or comma-separated)")
    p.add_argument("--root", default=None,
                   help="repo root (default: auto-detected from the "
                        "installed package location)")
    return p


def _split(values) -> list[str] | None:
    if not values:
        return None
    out: list[str] = []
    for v in values:
        out.extend(x.strip() for x in v.split(",") if x.strip())
    return out or None


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.analysis import core
    from repro.analysis import rules  # noqa: F401  (registers rules)

    root = args.root or core.find_repo_root()
    findings = core.run_lint(root=root, paths=args.paths or None,
                             select=_split(args.select),
                             ignore=_split(args.ignore))
    if args.format == "json":
        print(core.render_json(findings, root))
    else:
        print(core.render_text(findings))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
