"""Convolutional nets for the paper-faithful reproduction (the paper's own
models: MCUNet-class separable-conv net and ResNet-18).

These are the models the paper's Tables 1/2 use; we train reduced versions on
synthetic/small data and drive the cost model with the paper's exact layer
shapes.  The last ``last_k`` standard convolutions (counted from the end, as
the paper counts fine-tuned layers) can be ASI- or HOSVD-compressed;
depthwise (grouped) convs stay vanilla — their activations are the same size
as the pointwise ones that follow, and the paper compresses standard convs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.asi import TuckerASIState
from repro.core.compressed_conv import (ConvCompressionCfg, asi_conv2d, conv2d,
                                        hosvd_conv2d)
from repro.models.layers import initializer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    c_in: int
    c_out: int
    ksize: int
    stride: int = 1
    depthwise: bool = False


@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    name: str
    layers: tuple[ConvLayerSpec, ...]
    num_classes: int = 10
    input_hw: int = 32
    compress: str = "none"           # none | asi | hosvd
    last_k: int = 2                  # compressed tail, standard convs only
    ranks: tuple[int, int, int, int] = (4, 4, 4, 4)


def mcunet_mini(num_classes=10, compress="none", last_k=2,
                ranks=(4, 4, 4, 4)) -> ConvNetConfig:
    """MCUNet-style separable-conv net (stem + 4 separable stages)."""
    ls = [ConvLayerSpec(3, 16, 3, 2)]
    for c_in, c_out, s in ((16, 32, 2), (32, 64, 2), (64, 96, 1), (96, 128, 2)):
        ls.append(ConvLayerSpec(c_in, c_in, 3, s, depthwise=True))
        ls.append(ConvLayerSpec(c_in, c_out, 1, 1))
    return ConvNetConfig("mcunet_mini", tuple(ls), num_classes, 32,
                         compress, last_k, ranks)


def resnet18_mini(num_classes=10, compress="none", last_k=2,
                  ranks=(4, 4, 4, 4)) -> ConvNetConfig:
    """ResNet-18 layer sequence (residual adds omitted in the mini variant —
    the activation-memory behaviour under compression is identical)."""
    ls = [ConvLayerSpec(3, 64, 3, 1)]
    for c_in, c_out, s in ((64, 64, 1), (64, 128, 2), (128, 256, 2),
                           (256, 512, 2)):
        ls.append(ConvLayerSpec(c_in, c_out, 3, s))
        ls.append(ConvLayerSpec(c_out, c_out, 3, 1))
    return ConvNetConfig("resnet18_mini", tuple(ls), num_classes, 32,
                         compress, last_k, ranks)


def _compressed_indices(cfg: ConvNetConfig) -> set[int]:
    if cfg.compress == "none":
        return set()
    idx = [i for i, l in enumerate(cfg.layers) if not l.depthwise]
    return set(idx[-cfg.last_k:])


def init_params(key: Array, cfg: ConvNetConfig) -> dict:
    keys = jax.random.split(key, len(cfg.layers) + 1)
    convs = []
    for k, l in zip(keys[:-1], cfg.layers):
        c_in_g = 1 if l.depthwise else l.c_in
        w = initializer(k, (l.c_out, c_in_g, l.ksize, l.ksize), jnp.float32,
                        scale=(2.0 / (l.ksize * l.ksize * l.c_in)) ** 0.5)
        convs.append({"w": w, "scale": jnp.ones((l.c_out,)),
                      "bias": jnp.zeros((l.c_out,))})
    head_w = initializer(keys[-1], (cfg.layers[-1].c_out, cfg.num_classes),
                         jnp.float32)
    return {"convs": convs, "head_w": head_w,
            "head_b": jnp.zeros((cfg.num_classes,))}


def activation_shapes(cfg: ConvNetConfig, batch: int) -> list[tuple]:
    """Input shape of every conv layer (what would be stored for backward)."""
    h = w = cfg.input_hw
    shapes = []
    for l in cfg.layers:
        shapes.append((batch, l.c_in, h, w))
        h = max(h // l.stride, 1)
        w = max(w // l.stride, 1)
    return shapes


def init_asi_state(key: Array, cfg: ConvNetConfig, batch: int) -> dict:
    comp = _compressed_indices(cfg)
    shapes = activation_shapes(cfg, batch)
    out = {}
    for i in sorted(comp):
        key, sub = jax.random.split(key)
        out[f"conv_{i}"] = TuckerASIState.init(sub, shapes[i], cfg.ranks)
    return out


def forward(params: dict, x: Array, cfg: ConvNetConfig,
            asi_state: dict | None = None):
    """x (B, 3, H, W) NCHW.  Returns (logits, new_asi_state)."""
    comp = _compressed_indices(cfg)
    new_state: dict = {}
    frozen_until = min(comp) if (comp and cfg.compress != "none") else None
    for i, (l, p) in enumerate(zip(cfg.layers, params["convs"])):
        stride = (l.stride, l.stride)
        if i in comp and asi_state is not None:
            ccfg = ConvCompressionCfg(ranks=cfg.ranks, stride=stride,
                                      padding="SAME")
            if cfg.compress == "asi":
                x, ns = asi_conv2d(ccfg, x, p["w"], asi_state[f"conv_{i}"])
                new_state[f"conv_{i}"] = ns
            else:
                x = hosvd_conv2d(ccfg, x, p["w"])
        elif l.depthwise:
            x = lax.conv_general_dilated(
                x, p["w"], stride, "SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
                feature_group_count=l.c_in)
        else:
            x = conv2d(x, p["w"], stride=stride, padding="SAME")
        x = x * p["scale"][None, :, None, None] + p["bias"][None, :, None, None]
        x = jax.nn.relu(x)
        if frozen_until is not None and i + 1 == frozen_until:
            x = jax.lax.stop_gradient(x)         # frozen backbone prefix
    x = x.mean(axis=(2, 3))
    logits = x @ params["head_w"] + params["head_b"]
    return logits, (new_state if asi_state is not None else None)


def loss_fn(params: dict, batch: dict, cfg: ConvNetConfig,
            asi_state: dict | None = None):
    logits, new_asi = forward(params, batch["images"], cfg, asi_state)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = jnp.mean(lse - picked)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ce, ({"ce": ce, "acc": acc}, new_asi)
