"""Numerical-equivalence tests: chunked attention vs naive, SSD vs sequential
recurrence, decode vs teacher-forced forward, prefill-then-decode continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import build_model
from repro.models import transformer as tfm
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(1)


def _naive_attn(q, k, v, causal, window=0):
    B, S, KV, G, hd = q.shape
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k) / jnp.sqrt(hd)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.moveaxis(jnp.einsum("bkgqc,bckh->bkgqh", p, v), 3, 1)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 8)])
@pytest.mark.parametrize("chunks", [(16, 16), (8, 32), (64, 64)])
def test_chunked_attention_matches_naive(causal, window, chunks):
    ks = jax.random.split(KEY, 3)
    B, S, KV, G, hd = 2, 64, 2, 3, 16
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o1 = chunked_attention(q, k, v, causal=causal, window=window,
                           q_chunk=chunks[0], kv_chunk=chunks[1])
    o2 = _naive_attn(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_sequential(chunk):
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 32, 3, 4, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N))
    c = jax.random.normal(ks[4], (B, S, N))

    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        da = jnp.exp(dt[:, t] * a)
        h = h * da[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], b[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", c[:, t], h))
    y_ref = jnp.stack(ys, 1)
    y, hf = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "jamba-1.5-large-398b",
                                  "granite-moe-3b-a800m", "h2o-danube-3-4b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = tfm.forward(params, toks, cfg)
    cache = api.init_cache(B, S)
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i))
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   atol=3e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m"])
def test_prefill_then_decode_continuity(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    # route A: prefill S tokens, decode token S
    logits_pre, cache = tfm.prefill(params, toks[:, :S], cfg, max_len=S + 4)
    lg_a, _ = api.decode_step(params, cache, toks[:, S], jnp.int32(S))
    # route B: full teacher forcing
    logits_full, _, _ = tfm.forward(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(lg_a),
                               np.asarray(logits_full[:, S]),
                               atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, S - 1]),
                               atol=3e-4, rtol=1e-3)


def test_swa_ring_cache_decode():
    """Decode with a ring cache smaller than the context must equal decode
    with a full cache restricted to the window."""
    cfg = get_config("h2o-danube-3-4b").reduced().replace(sliding_window=8)
    api = build_model(cfg)
    params = api.init(KEY)
    B, S = 1, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = tfm.forward(params, toks, cfg)   # masked full attn
    cache = api.init_cache(B, S)                          # ring of size 8
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i))
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, -1]),
                               atol=3e-4, rtol=1e-3)


def test_int8_kv_cache_decode():
    """C3 lever: int8 KV cache decode must track the fp cache closely."""
    cfg0 = get_config("tinyllama-1.1b").reduced()
    cfg8 = cfg0.replace(kv_cache_dtype="int8")
    api0, api8 = build_model(cfg0), build_model(cfg8)
    params = api0.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg0.vocab_size)
    c0, c8 = api0.init_cache(B, S), api8.init_cache(B, S)
    assert jax.tree.leaves(c8)[0].dtype == jnp.int8
    errs = []
    for t in range(S):
        l0, c0 = api0.decode_step(params, c0, toks[:, t], jnp.int32(t))
        l8, c8 = api8.decode_step(params, c8, toks[:, t], jnp.int32(t))
        errs.append(float(jnp.abs(jax.nn.softmax(l0)
                                  - jax.nn.softmax(l8)).max()))
    assert max(errs) < 0.05, max(errs)
