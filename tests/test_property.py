"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.asi import (MatrixASIState, matrix_asi_step,
                            matrix_storage_elems, orthonormalize,
                            tucker_storage_elems)
from repro.core.gradient_filter import patch_pool, pooled_storage_elems
from repro.launch.roofline import collective_bytes

SETTINGS = dict(max_examples=25, deadline=None)


@given(m=st.integers(8, 64), k=st.integers(4, 32), r=st.integers(1, 4),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_asi_factors_always_orthonormal_and_sized(m, k, r, seed):
    r = min(r, k, m)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, k))
    state = MatrixASIState.init(key, k, r)
    p, q, new = matrix_asi_step(x, state)
    assert p.shape == (m, r) and q.shape == (k, r)
    gram = np.asarray(p.T @ p)
    np.testing.assert_allclose(gram, np.eye(r), atol=1e-3)
    assert p.size + q.size == matrix_storage_elems(m, k, r)
    # state round-trips: next step consumes what this step produced
    p2, q2, _ = matrix_asi_step(x, new)
    assert np.isfinite(np.asarray(q2)).all()


@given(dims=st.tuples(*[st.integers(2, 12)] * 4),
       ranks=st.tuples(*[st.integers(1, 12)] * 4))
@settings(**SETTINGS)
def test_tucker_storage_formula_bounds(dims, ranks):
    elems = tucker_storage_elems(dims, ranks)
    full = int(np.prod(dims))
    assert elems > 0
    capped = [min(r, d) for r, d in zip(ranks, dims)]
    if all(c == d for c, d in zip(capped, dims)):
        assert elems >= full            # full rank never smaller than dense
    if all(c == 1 for c in capped):
        assert elems == 1 + sum(dims)   # rank-1 closed form


@given(b=st.integers(1, 3), c=st.integers(1, 4), h=st.integers(2, 16),
       w=st.integers(2, 16), r=st.sampled_from([2, 4]),
       seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_patch_pool_mean_preserved(b, c, h, w, r, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, c, h, w))
    y = patch_pool(x, r)
    assert y.size == pooled_storage_elems((b, c, h, w), r)
    if h % r == 0 and w % r == 0:       # exact mean on full patches
        np.testing.assert_allclose(float(y.mean()), float(x.mean()),
                                   atol=1e-5)
    # every patch — edge patches included — is the exact mean of the real
    # elements it covers (no zero-pad bias on ragged H/W)
    xn = np.asarray(x)
    for i in range((h + r - 1) // r):
        for j in range((w + r - 1) // r):
            patch = xn[:, :, i * r: min((i + 1) * r, h),
                       j * r: min((j + 1) * r, w)]
            np.testing.assert_allclose(np.asarray(y[:, :, i, j]),
                                       patch.mean(axis=(2, 3)), atol=1e-5)


@given(seed=st.integers(0, 2**16), s=st.sampled_from([8, 16]),
       future=st.integers(0, 7))
@settings(**SETTINGS)
def test_causal_attention_ignores_future(seed, s, future):
    """Perturbing token t+1.. must not change output at positions <= t."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, KV, G, hd = 1, 1, 2, 8
    q = jax.random.normal(ks[0], (B, s, KV, G, hd))
    k = jax.random.normal(ks[1], (B, s, KV, hd))
    v = jax.random.normal(ks[2], (B, s, KV, hd))
    t = s - future - 1
    o1 = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    k2 = k.at[:, t + 1:].add(100.0)
    v2 = v.at[:, t + 1:].add(-50.0)
    o2 = chunked_attention(q, k2, v2, causal=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(o1[:, :t + 1]),
                               np.asarray(o2[:, :t + 1]), atol=1e-5)


@given(n=st.integers(1, 6), g=st.integers(2, 8), d1=st.integers(1, 64),
       d2=st.integers(1, 64))
@settings(**SETTINGS)
def test_collective_parser_on_synthetic_hlo(n, g, d1, d2):
    lines = ["HloModule m"]
    expected = 0
    for i in range(n):
        lines.append(f"  %p.{i} = f32[{d1},{d2}] parameter({i})")
        lines.append(f"  %all-reduce.{i} = f32[{d1},{d2}] all-reduce(%p.{i}),"
                     f" replica_groups=[1,{g}]<=[{g}]")
        expected += d1 * d2 * 4
    stats = collective_bytes("\n".join(lines))
    assert stats.total_bytes == expected
    assert stats.count == n


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_orthonormalize_idempotent(seed):
    p = jax.random.normal(jax.random.PRNGKey(seed), (32, 4))
    q1 = orthonormalize(p)
    q2 = orthonormalize(q1)
    np.testing.assert_allclose(np.abs(np.asarray(q1.T @ q2)), np.eye(4),
                               atol=1e-3)
