"""Paper-faithful convnet tests (MCUNet-class / ResNet18 on synthetic data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import ImageStream, ImageStreamCfg
from repro.models import convnets
from repro.optim.optimizers import make_optimizer

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("builder", [convnets.mcunet_mini,
                                     convnets.resnet18_mini])
def test_forward_shapes(builder):
    cfg = builder(num_classes=7)
    params = convnets.init_params(KEY, cfg)
    x = jax.random.normal(KEY, (4, 3, 32, 32))
    logits, _ = convnets.forward(params, x, cfg)
    assert logits.shape == (4, 7)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("compress", ["asi", "hosvd"])
def test_compressed_train_step(compress):
    cfg = convnets.mcunet_mini(num_classes=4, compress=compress, last_k=2,
                               ranks=(2, 2, 2, 2))
    params = convnets.init_params(KEY, cfg)
    st = convnets.init_asi_state(KEY, cfg, batch=4) if compress == "asi" else {}
    batch = {"images": jax.random.normal(KEY, (4, 3, 32, 32)),
             "labels": jnp.array([0, 1, 2, 3])}

    def lossf(p):
        loss, (m, ns) = convnets.loss_fn(p, batch, cfg,
                                         st if compress == "asi" else None)
        return loss

    loss, grads = jax.value_and_grad(lossf)(params)
    assert bool(jnp.isfinite(loss))
    # frozen prefix convs get zero grads (backbone frozen before compressed
    # tail, as in the paper's fine-tuning protocol)
    gsum = [float(jnp.abs(g["w"]).sum()) for g in grads["convs"]]
    assert gsum[0] == 0.0
    assert gsum[-1] > 0.0


def test_asi_training_tracks_vanilla_on_synthetic_task():
    """E8-mini: ASI fine-tuning reaches a loss close to vanilla fine-tuning
    on the blob-classification task (paper's accuracy-parity claim)."""
    data = ImageStream(ImageStreamCfg(num_classes=4, hw=16, global_batch=32,
                                      noise=0.25))

    def train(compress, steps=30):
        cfg = convnets.mcunet_mini(num_classes=4, compress=compress,
                                   last_k=2, ranks=(4, 4, 4, 4))
        cfg = cfg.__class__(**{**cfg.__dict__, "input_hw": 16})
        params = convnets.init_params(KEY, cfg)
        st = (convnets.init_asi_state(KEY, cfg, batch=32)
              if compress == "asi" else None)
        opt = make_optimizer("sgdm", lambda s: 0.05, momentum=0.9)
        ostate = opt.init(params)

        @jax.jit
        def step(params, ostate, st, batch):
            def lossf(p):
                loss, (m, ns) = convnets.loss_fn(p, batch, cfg, st)
                return loss, (m, ns)
            (loss, (m, ns)), g = jax.value_and_grad(lossf, has_aux=True)(params)
            params, ostate = opt.update(g, ostate, params, jnp.int32(0))
            return params, ostate, (ns if ns is not None else st), loss

        losses = []
        for i in range(steps):
            params, ostate, st, loss = step(params, ostate, st, data.batch(i))
            losses.append(float(loss))
        return np.mean(losses[-5:])

    vanilla = train("none")
    asi = train("asi")
    assert asi < vanilla + 0.5       # parity within tolerance on this task


def test_activation_shapes_tracker():
    cfg = convnets.resnet18_mini()
    shapes = convnets.activation_shapes(cfg, batch=2)
    assert shapes[0] == (2, 3, 32, 32)
    assert len(shapes) == len(cfg.layers)
    assert shapes[-1][1] == cfg.layers[-1].c_in
