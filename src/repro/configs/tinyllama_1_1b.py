"""tinyllama-1.1b — llama2-arch small; the paper's own Table-4 LLM.
[arXiv:2401.02385; hf]  22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Default fine-tune setting mirrors the paper: ASI rank 20 on the tail."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    act="silu",
    asi_rank=20,
)
