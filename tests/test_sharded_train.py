"""Mesh-sharded training tests.

Multi-device cases run in a subprocess with 8 forced host-platform devices
(the main test process must keep seeing 1 device); pure spec/rule helpers
run in-process.

Parity contract (see DESIGN.md §6):

* dp    — forward loss on common params is BIT-IDENTICAL to single-device
          (no contraction is split); the training trajectory matches to
          float32 epsilon (the gradient all-reduce sums in a different
          order, inherent to any DP implementation).
* fsdp / tp — trajectory within tolerance (split contractions reorder fp
          reductions).
* checkpoints are layout-free: save on a 2x4 mesh, resume on 1x8 and on a
  single device.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.kernels import dispatch
from repro.parallel.sharding import (axis_rules, dp_rules, fsdp_rules,
                                     rules_for, safe_spec, single_pod_rules)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           JAX_PLATFORMS="cpu",
           PYTHONPATH=os.path.join(REPO, "src"))


def _run(code: str, timeout=1200):
    p = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    return p


_TRAIN_LIB = """
import contextlib, json
import jax, jax.numpy as jnp
from repro.configs.registry import get_config
from repro.models import build_model
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import (TrainLoopCfg, make_mesh_plan,
                                      make_train_step, run)
from repro.launch.mesh import make_layout_mesh

CFG = get_config("tinyllama-1.1b").reduced().replace(compress="asi")
API = build_model(CFG)
KEY = jax.random.PRNGKey(0)
DATA = LMStream(LMStreamCfg(vocab_size=CFG.vocab_size, seq_len=16,
                            global_batch=8, seed=0, branching=2))

def fresh_state(steps):
    params = API.init(KEY)
    asi = API.init_asi(KEY)
    mask = API.trainable_mask(params)
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 1, steps),
                         clip_norm=2.0)
    return params, opt, opt.init(params), asi, mask

def train(layout, steps=6, grad_accum=1, mesh_shape=None):
    params, opt, opt_state, asi, mask = fresh_state(steps)
    plan = None
    if layout:
        mesh = make_layout_mesh(layout, mesh_shape)
        plan = make_mesh_plan(CFG, mesh, layout, params, opt_state, asi,
                              DATA.batch(0))
        params, opt_state, asi = plan.shard_state(params, opt_state, asi)
    step_fn = make_train_step(lambda p, b, s: API.loss(p, b, s), opt,
                              trainable_mask=mask,
                              kernel_backend=CFG.kernel_backend,
                              plan=plan, grad_accum=grad_accum)
    ctx = plan.activate() if plan else contextlib.nullcontext()
    losses = []
    with ctx:
        for t in range(steps):
            b = DATA.batch(t)
            if plan:
                b = plan.shard_batch(b)
            params, opt_state, asi, m = step_fn(params, opt_state, asi, b,
                                                jnp.int32(t))
            losses.append(float(m["loss"]))
    return losses, params
"""


def test_dp_fsdp_tp_parity_8dev():
    code = _TRAIN_LIB + """
base, p0 = train(None)
dp, p1 = train("dp")
fsdp, _ = train("fsdp")
tp, _ = train("tp", mesh_shape=(2, 4))
acc, _ = train("dp", grad_accum=4)
pdiff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
print(json.dumps({"base": base, "dp": dp, "fsdp": fsdp, "tp": tp,
                  "acc": acc, "dp_param_maxdiff": pdiff}))
"""
    p = _run(code)
    out = json.loads(p.stdout.strip().splitlines()[-1])
    base = np.asarray(out["base"])
    # dp: forward loss on common params is bit-identical; the trajectory
    # tracks to f32 epsilon accumulation
    assert out["dp"][0] == out["base"][0], "dp forward loss must be bitwise"
    np.testing.assert_allclose(np.asarray(out["dp"]), base, rtol=1e-5)
    assert out["dp_param_maxdiff"] < 1e-5
    # fsdp / tp split contractions -> tolerance
    np.testing.assert_allclose(np.asarray(out["fsdp"]), base, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out["tp"]), base, rtol=1e-4)
    # grad accumulation = same mean gradient, accumulated in fp32
    np.testing.assert_allclose(np.asarray(out["acc"]), base, rtol=5e-4)
    # losses decrease over the run (training actually happens)
    assert out["dp"][-1] < out["dp"][0]


def test_checkpoint_reshards_across_meshes_8dev(tmp_path):
    """Save on a 2x4 tp mesh; resume on 1x8 tp and on a single device."""
    ckpt = str(tmp_path / "ckpt")
    code = _TRAIN_LIB + """
import numpy as np
CKPT = __CKPT__

def run_loop(layout, total, mesh_shape=None):
    params, opt, opt_state, asi, mask = fresh_state(total)
    plan = None
    if layout:
        mesh = make_layout_mesh(layout, mesh_shape)
        plan = make_mesh_plan(CFG, mesh, layout, params, opt_state, asi,
                              DATA.batch(0))
    step_fn = make_train_step(lambda p, b, s: API.loss(p, b, s), opt,
                              trainable_mask=mask, plan=plan)
    cfg = TrainLoopCfg(total_steps=total, ckpt_dir=CKPT, ckpt_every=2,
                       log_every=1)
    res = run(step_fn, params, opt_state, asi, DATA, cfg, plan=plan)
    return [h["loss"] for h in res.history], res.step

l1, s1 = run_loop("tp", 4, mesh_shape=(2, 4))       # fresh, saves step 2, 4
assert s1 == 4
import os, json as _json
meta = _json.load(open(os.path.join(CKPT, "step_00000004", "meta.json")))
l2, s2 = run_loop("tp", 8, mesh_shape=(1, 8))       # restores 4 on 1x8
assert s2 == 8
l3, s3 = run_loop(None, 12)                          # restores 8 unsharded
assert s3 == 12
print(_json.dumps({"l1": l1, "l2": l2, "l3": l3, "meta": meta}))
""".replace("__CKPT__", json.dumps(ckpt))
    p = _run(code)
    out = json.loads(p.stdout.strip().splitlines()[-1])
    # saving mesh recorded as provenance
    assert out["meta"]["mesh"] == {"data": 2, "model": 4}
    assert out["meta"]["layout"] == "tp"
    # each leg resumes where the previous stopped and keeps improving
    full = out["l1"] + out["l2"] + out["l3"]
    assert len(out["l1"]) == 4 and len(out["l2"]) == 4 and len(out["l3"]) == 4
    assert all(np.isfinite(full))
    assert full[-1] < full[0]
    # continuity: the first post-restore loss stays close to the last
    # pre-restore loss (same params, next batch)
    assert abs(out["l2"][0] - out["l1"][-1]) < 0.2
    assert abs(out["l3"][0] - out["l2"][-1]) < 0.2


def test_grad_accum_trajectory_matches_full_batch_singledev():
    """grad_accum is pure restructuring: mean-of-microbatch grads == full-
    batch grads (to fp accumulation), on a plain single-device step."""
    code = _TRAIN_LIB + """
base, _ = train(None, steps=4)
acc2, _ = train(None, steps=4, grad_accum=2)
acc4, _ = train(None, steps=4, grad_accum=4)
print(json.dumps({"base": base, "acc2": acc2, "acc4": acc4}))
"""
    p = _run(code)
    out = json.loads(p.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(out["acc2"], out["base"], rtol=5e-4)
    np.testing.assert_allclose(out["acc4"], out["base"], rtol=5e-4)


def test_collectives_roundtrip_8dev():
    """compressed_psum_tree on a forced 8-device mesh: full-rank compression
    round-trips to the exact mean; small leaves take the dense path."""
    code = """
import jax, jax.numpy as jnp, json
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.parallel import collectives as C

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(1)
gs = jax.random.normal(key, (8, 16, 12))          # (workers, d_in, d_out)
bias = jax.random.normal(jax.random.fold_in(key, 2), (8, 12))
states = C.init_states_for({"w": gs[0], "b": bias[0]}, key, rank=12)
assert set(states) == {"w"}                        # 1-D leaf stays dense

def f(g, b, q, e):
    grads = {"w": g[0], "b": b[0]}
    st = {"w": C.PowerSGDState(q=q, err=e[0])}
    out, ns = C.compressed_psum_tree(grads, st, "data")
    return out["w"][None], out["b"][None], ns["w"].q[None]

errs = jnp.zeros((8,) + gs.shape[1:])
w_hat, b_hat, q = jax.jit(lambda gs, b, q, e: shard_map(
    f, mesh=mesh, in_specs=(P("data"), P("data"), P(), P("data")),
    out_specs=(P("data"), P("data"), P("data")), check_rep=False)
    (gs, b, q, e))(gs, bias, states["w"].q, errs)

exact_w = gs.mean(0)
exact_b = bias.mean(0)
rel_w = float(jnp.linalg.norm(w_hat[0] - exact_w) / jnp.linalg.norm(exact_w))
rel_b = float(jnp.linalg.norm(b_hat[0] - exact_b) / jnp.linalg.norm(exact_b))
print(json.dumps({"rel_w": rel_w, "rel_b": rel_b}))
"""
    p = _run(code)
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["rel_w"] < 1e-4      # full-rank: near-exact round-trip
    assert out["rel_b"] < 1e-6      # dense path: exact mean


# --- in-process helper coverage (specs are pure data) ------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


def test_safe_spec_clamps_nondivisible_axes():
    m = FakeMesh({"data": 4, "model": 8})
    # non-dividing dim degrades to replication, dividing dims keep the axis
    assert safe_spec((6, 32), P("data", "model"), m) == P(None, "model")
    assert safe_spec((8, 30), P("data", "model"), m) == P("data", None)
    # tuple axes multiply their sizes (4*8=32 divides 64, not 48)
    assert safe_spec((64,), P(("data", "model")), m) == P(("data", "model"))
    assert safe_spec((48,), P(("data", "model")), m) == P(None)
    # spec longer than the shape: the out-of-range entry is dropped
    assert safe_spec((8,), P("data", "model"), m) == P("data", None)


def test_rules_for_layout_selection():
    m = FakeMesh({"data": 4, "model": 2})
    mp = FakeMesh({"pod": 2, "data": 4, "model": 2})
    assert rules_for(m, "dp") == dp_rules(False)
    assert rules_for(m, "fsdp") == fsdp_rules(False)
    assert rules_for(m, "tp") == single_pod_rules()
    assert rules_for(mp, "dp")["batch"] == ("pod", "data")
    assert rules_for(mp, "fsdp")["batch"] == ("pod", "data", "model")
    # dp replicates every weight axis
    r = rules_for(m, "dp")
    assert all(r[k] is None for k in
               ("heads", "kv", "mlp", "vocab", "experts", "model"))
    with pytest.raises(ValueError):
        rules_for(m, "zigzag")


def test_dispatch_vmem_cap_is_mesh_aware():
    """Inside a shard_local_kernels scope under TP rules, the VMEM cap
    applies to the local shard of dims the rules actually shard (out_axis),
    so globally wide ffns keep the fused backward kernel — while replicated
    output dims, and everything outside that scope (GSPMD jit gathers
    pallas operands to full width), keep the global width."""
    n = dispatch.GRAD_SKETCH_MAX_N
    wide = 4 * n
    m = FakeMesh({"data": 2, "model": 4})
    with dispatch.shard_local_kernels():
        assert dispatch.local_feature_dim(wide, "mlp") == wide   # no rules
        with axis_rules(m, single_pod_rules()):              # mlp -> model(4)
            assert dispatch.local_feature_dim(wide, "mlp") == n
            assert dispatch._grad_fits_vmem(wide, "mlp")
            assert not dispatch._grad_fits_vmem(8 * n, "mlp")
            # replicated output dims (o/down projections: out_axis=None)
            # are full-width on every device — never divided
            assert dispatch.local_feature_dim(wide, None) == wide
            assert not dispatch._grad_fits_vmem(wide, None)
            # unmapped logical axes and non-divisible dims fall back too
            assert dispatch.local_feature_dim(wide, "embed") == wide
            assert dispatch.local_feature_dim(wide + 1, "mlp") == wide + 1
        with axis_rules(m, dp_rules()):                      # no TP axis
            assert dispatch.local_feature_dim(wide, "mlp") == wide
            assert not dispatch._grad_fits_vmem(wide, "mlp")
    # outside the scope the premise (kernel sees shards) does not hold
    with axis_rules(m, single_pod_rules()):
        assert dispatch.local_feature_dim(wide, "mlp") == wide
        assert not dispatch._grad_fits_vmem(wide, "mlp")
