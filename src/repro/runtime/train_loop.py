"""Fault-tolerant training loop.

Design for 1000+ nodes (SPMD): every step is deterministic in (params, step)
— the data pipeline is a pure function of step — so recovery is exactly
"restore latest atomic checkpoint, continue".  Failure handling:

* crash/preemption  -> restart loop restores the latest checkpoint (tested
  via injected ``SimulatedFailure``);
* stragglers        -> within a pod, TPU SPMD is lock-step (no per-node
  stragglers); across pods, the loop records per-step wall-time watermarks
  and flags a persistently slow pod for eviction + elastic resume (the
  decision signal is implemented; the eviction itself belongs to the
  cluster manager);
* elastic rescale   -> checkpoints are layout-free (see checkpoint/elastic),
  so resuming on a different mesh Just Works: the loop restores logical
  arrays and re-places them for whatever MeshPlan the resuming job built
  (save on 2x4, resume on 1x8 or single-device — tested).

Mesh-sharded training: build a ``MeshPlan`` (``make_mesh_plan``) from a mesh
and a layout (``dp`` | ``fsdp`` | ``tp``), pass it to ``make_train_step`` and
``run``.  The plan carries the PartitionSpec trees for params / optimizer
state / ASI state / batches (from ``repro.parallel.partition``) plus the
logical-axis rules the model's ``logical_shard`` annotations resolve
against.  ``make_train_step`` turns the specs into jit in/out shardings with
buffer donation, so FSDP genuinely frees per-device parameter+optimizer
memory, and microbatch gradient accumulation (``grad_accum``) runs as a
``lax.scan`` inside the jitted step — composing the ASI activation-memory
win with large effective batches.
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.checkpoint import checkpointer, elastic
from repro.kernels import dispatch
from repro.optim.optimizers import Optimizer
from repro.parallel import partition
from repro.parallel.sharding import axis_rules, rules_for
from repro.telemetry import Recorder

Array = jax.Array


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainLoopCfg:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    max_restarts: int = 3
    straggler_factor: float = 3.0     # flag steps slower than factor x median
    fail_at_step: int = -1            # inject a failure once at this step
    keep_ckpts: int = 3


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One layout on one mesh: the rules + PartitionSpec trees the loop and
    the jitted step need to shard every array they touch."""
    mesh: Mesh
    layout: str                  # dp | fsdp | tp
    rules: dict                  # logical-axis rules for model annotations
    param_specs: Any
    opt_specs: Any
    asi_specs: Any
    batch_specs: Any

    def activate(self):
        """Context manager enabling the model's ``logical_shard`` calls —
        must wrap tracing (i.e. the first call) of the jitted step."""
        return axis_rules(self.mesh, self.rules)

    def shard_state(self, params, opt_state, asi_state):
        """device_put the training state with its plan shardings."""
        return (elastic.reshard(params, self.param_specs, self.mesh),
                elastic.reshard(opt_state, self.opt_specs, self.mesh),
                elastic.reshard(asi_state, self.asi_specs, self.mesh))

    def shard_batch(self, batch):
        return elastic.reshard(batch, self.batch_specs, self.mesh)

    def meta(self) -> dict:
        """Provenance recorded in checkpoint meta.json (restore never needs
        it — checkpoints are layout-free)."""
        return {"mesh": dict(self.mesh.shape), "layout": self.layout}


def make_mesh_plan(cfg, mesh: Mesh, layout: str, params, opt_state,
                   asi_state, batch) -> MeshPlan:
    """Build the spec trees for one (mesh, layout) from the concrete training
    state (or ``eval_shape`` structures — only shapes are read).

    ``partition.LAYOUT`` is a module global the spec builders read; it is
    restored afterwards so building a plan never leaks its layout into
    unrelated spec building (dryrun, serving, a second plan)."""
    prev = partition.LAYOUT
    partition.set_layout(layout)
    try:
        rules = rules_for(mesh, layout)
        return MeshPlan(
            mesh=mesh, layout=layout, rules=rules,
            param_specs=partition.param_specs(cfg, params, mesh),
            opt_specs=partition.opt_specs(cfg, opt_state, mesh),
            asi_specs=partition.asi_specs(asi_state, mesh),
            batch_specs=partition.batch_specs(cfg, batch, mesh))
    finally:
        partition.set_layout(prev)


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    trainable_mask=None, donate: bool = True,
                    kernel_backend: str | None = None,
                    plan: MeshPlan | None = None, grad_accum: int = 1):
    """loss_fn(params, batch, asi_state) -> (loss, (metrics, new_asi_state)).

    ``kernel_backend`` is the model's fused-ASI dispatch flag; passing it here
    resolves it once up front, so an invalid flag aborts before the first
    (expensive) compile instead of deep inside the traced step.

    With a ``plan``, the step is jitted with explicit in/out NamedShardings
    from the plan's spec trees (donation then recycles the sharded buffers
    in place — this is what makes FSDP actually free per-device memory).

    ``grad_accum > 1`` splits the batch into that many microbatches and runs
    them as a ``lax.scan`` inside the step: gradients accumulate in fp32,
    the ASI subspace state threads through the scan (each microbatch warm-
    starts the next, exactly like consecutive steps would), and the
    optimizer applies the mean gradient once.  Peak activation memory is
    that of ONE microbatch, so effective batch scales without touching the
    activation budget ASI already compressed.
    """
    if kernel_backend is not None:
        dispatch.resolve(kernel_backend)
    if grad_accum < 1:
        raise ValueError(f"grad_accum={grad_accum} must be >= 1")

    def grads_of(params, asi_state, batch):
        (loss, (metrics, new_asi)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, asi_state)
        metrics = dict(metrics)
        metrics["loss"] = loss
        # global gradient norm rides along on device; like every metric it
        # only hits the host at the log-step sync (telemetry stream)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return grads, (new_asi if new_asi is not None else asi_state), metrics

    def train_step(params, opt_state, asi_state, batch, step):
        if grad_accum == 1:
            grads, asi_state, metrics = grads_of(params, asi_state, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            if plan is not None:
                # keep the microbatch dim (now dim 1) on the batch axes; the
                # leading scan dim is replicated.  safe_spec degrades to
                # replication when B/grad_accum stops dividing the axes.
                from jax.sharding import NamedSharding, PartitionSpec as P
                from repro.parallel.sharding import safe_spec

                def constrain(x, s):
                    spec = safe_spec(x.shape, P(None, *tuple(s)), plan.mesh)
                    return jax.lax.with_sharding_constraint(
                        x, NamedSharding(plan.mesh, spec))
                micro = jax.tree.map(constrain, micro, plan.batch_specs)

            def body(carry, mb):
                acc, asi = carry
                g, asi, m = grads_of(params, asi, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, asi), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, asi_state), ms = jax.lax.scan(
                body, (zeros, asi_state), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), ms)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step,
                                               trainable_mask)
        return new_params, new_opt, asi_state, metrics

    jit_kw: dict = {"donate_argnums": (0, 1, 2) if donate else ()}
    if plan is not None:
        sh = lambda specs: partition.to_shardings(specs, plan.mesh)  # noqa: E731
        jit_kw["in_shardings"] = (sh(plan.param_specs), sh(plan.opt_specs),
                                  sh(plan.asi_specs), sh(plan.batch_specs),
                                  None)
        jit_kw["out_shardings"] = (sh(plan.param_specs), sh(plan.opt_specs),
                                   sh(plan.asi_specs), None)
    return jax.jit(train_step, **jit_kw)


class WindowedMedian:
    """Running median over the last ``window`` samples: O(log n) insert +
    O(window) evict, vs the O(n log n) full re-sort per step it replaces."""

    def __init__(self, window: int = 128):
        self.window = window
        self._fifo: collections.deque = collections.deque()
        self._sorted: list[float] = []

    def push(self, v: float):
        self._fifo.append(v)
        bisect.insort(self._sorted, v)
        if len(self._fifo) > self.window:
            old = self._fifo.popleft()
            del self._sorted[bisect.bisect_left(self._sorted, old)]

    def __len__(self):
        return len(self._fifo)

    def median(self) -> float:
        return self._sorted[len(self._sorted) // 2]


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    asi_state: Any
    step: int
    history: list
    restarts: int
    straggler_steps: list


def run(train_step, init_params, init_opt_state, init_asi_state, data,
        cfg: TrainLoopCfg, hooks: dict | None = None,
        plan: MeshPlan | None = None,
        telemetry: Recorder | None = None) -> TrainResult:
    """Restartable training.  ``data.batch(step)`` must be pure in step.

    With a ``plan`` the loop (a) device_puts the initial state with the
    plan's shardings, (b) re-places every restored checkpoint for the
    *current* mesh (``checkpointer.restore_sharded``) — which is what makes
    resuming on a different mesh Just Work — and (c) keeps the model's
    logical-axis rules active so ``logical_shard`` annotations resolve while
    the step traces.

    ``telemetry`` takes a recorder: step spans land in the event ring, and
    throughput + loss/grad-norm gauge streams are emitted on log steps only
    (telemetry introduces no extra device syncs — the log-step ``float()``
    stays the loop's single sync point)."""
    hooks = hooks or {}
    rec = telemetry if telemetry is not None else Recorder(enabled=False)
    ckpt_meta = plan.meta() if plan is not None else None
    ctx = plan.activate() if plan is not None else contextlib.nullcontext()

    with ctx, rec.span("train.run", total_steps=cfg.total_steps):
        return _run_inner(train_step, init_params, init_opt_state,
                          init_asi_state, data, cfg, hooks, plan, ckpt_meta,
                          rec)


def _run_inner(train_step, init_params, init_opt_state, init_asi_state, data,
               cfg: TrainLoopCfg, hooks, plan, ckpt_meta,
               rec: Recorder) -> TrainResult:
    restarts = 0
    history: list = []
    stragglers: list = []
    while True:
        try:
            start = checkpointer.latest_step(cfg.ckpt_dir)
            if start is None:
                params, opt_state, asi_state, step = (
                    init_params, init_opt_state, init_asi_state, 0)
                if plan is not None:
                    params, opt_state, asi_state = plan.shard_state(
                        params, opt_state, asi_state)
            else:
                tpl = {"params": init_params, "opt": init_opt_state,
                       "asi": init_asi_state}
                if plan is not None:
                    specs = {"params": plan.param_specs,
                             "opt": plan.opt_specs, "asi": plan.asi_specs}
                    tree, step, _ = checkpointer.restore_sharded(
                        cfg.ckpt_dir, tpl, specs, plan.mesh)
                else:
                    tree, step, _ = checkpointer.restore(cfg.ckpt_dir, tpl)
                params, opt_state, asi_state = (tree["params"], tree["opt"],
                                                tree["asi"])
            durations = WindowedMedian()
            while step < cfg.total_steps:
                if step == cfg.fail_at_step and restarts == 0:
                    raise SimulatedFailure(f"injected at step {step}")
                t0 = time.perf_counter()
                batch = data.batch(step)
                if plan is not None:
                    batch = plan.shard_batch(batch)
                if rec.profiler is not None:
                    # compile-vs-run split, once (behind --profile-trace)
                    rec.profiler.compile_split(
                        "train.step", train_step, params, opt_state,
                        asi_state, batch, jnp.int32(step))
                with rec.span("train.step", step=step):
                    params, opt_state, asi_state, metrics = train_step(
                        params, opt_state, asi_state, batch, jnp.int32(step))
                # dt times dispatch (plus any queue backpressure), not
                # device execution — the price of not forcing a per-step
                # sync.  The straggler watermark is therefore a coarse
                # between-syncs signal; the log-step float() below is the
                # only hard sync point.
                dt = time.perf_counter() - t0
                durations.push(dt)
                rec.observe("train.step_s", dt)
                rec.count("train.steps")
                med = durations.median()
                if len(durations) > 5 and dt > cfg.straggler_factor * med:
                    stragglers.append((step, dt, med))
                    rec.instant("train.straggler", step=step, dt_s=dt,
                                median_s=med)
                step += 1
                if step % cfg.log_every == 0 or step == cfg.total_steps:
                    # the only per-step device sync: metrics stay as async
                    # device arrays on non-log steps, preserving dispatch
                    # pipelining and buffer donation
                    metrics = {k: float(v) for k, v in metrics.items()}
                    history.append({"step": step, **metrics})
                    for k, v in metrics.items():
                        rec.set_gauge(f"train.{k}", v)
                    if med > 0:
                        rec.set_gauge("train.steps_per_s", 1.0 / med)
                    if "on_log" in hooks:
                        hooks["on_log"](step, metrics)
                if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                    with rec.span("train.checkpoint", step=step):
                        checkpointer.save(
                            cfg.ckpt_dir, step,
                            {"params": params, "opt": opt_state,
                             "asi": asi_state},
                            meta=ckpt_meta, keep=cfg.keep_ckpts)
            return TrainResult(params, opt_state, asi_state, step, history,
                               restarts, stragglers)
        except SimulatedFailure:
            restarts += 1
            rec.instant("train.restart", n=restarts)
            rec.count("train.restarts")
            if restarts > cfg.max_restarts:
                raise
            if "on_restart" in hooks:
                hooks["on_restart"](restarts)
