"""ASI-compressed linear layers via ``jax.custom_vjp``.

The trick: the *residuals* saved between forward and backward are the low-rank
factors (P̂, Q) instead of the full activation X, so XLA genuinely frees X
after the forward dot — this is the paper's activation-memory reduction,
realized natively in JAX.  The forward output is EXACT (compression only
changes what is stored); ∂L/∂x is EXACT (eq. 2 needs only W); ∂L/∂W is the
paper's low-rank estimate  Q·(P̂ᵀ·g)  (eq. 15's matrix analogue).

Both halves route through ``repro.kernels.dispatch``
(``LinearCompressionCfg.backend``): the forward streams X once through the
fused Y/P sketch kernel, the backward streams the cotangent g once through the
dual-accumulator g_x/R kernel — the HBM-traffic story of DESIGN.md §3.  The
``reference`` backend reproduces the plain-jnp contractions bit-for-bit.

Variants:
  * ``asi_linear``          — warm-started subspace iteration (the paper).
  * ``hosvd_linear``        — fixed-rank truncated-SVD storage (HOSVD_ε
                              baseline with ranks frozen for jit).
  * ``grouped_asi_linear``  — per-expert version for MoE (factors stacked on a
                              leading expert dim, vmapped iteration).

All return ``(y, new_state)`` so the warm-start state threads functionally
through the training step.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import calibration
from repro.core.asi import MatrixASIState, orthonormalize
from repro.kernels import dispatch

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LinearCompressionCfg:
    rank: int
    precision: jax.lax.Precision = jax.lax.Precision.DEFAULT
    backend: str = "auto"             # kernel dispatch: auto | pallas | reference
    out_axis: str | None = None       # logical name of the OUTPUT feature dim
                                      # ("mlp", "heads", ...) — lets mesh-aware
                                      # dispatch size the VMEM cap against the
                                      # per-TP-shard width; None = treat the
                                      # dim as replicated (conservative)


def _flatten(x: Array) -> Array:
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# ASI linear
# ---------------------------------------------------------------------------

def _fused_fwd(cfg: LinearCompressionCfg, x: Array, w: Array,
               b: Array | None, state: MatrixASIState):
    """Shared fwd: one pass over X yields Y and the warm-started sketch P,
    then Algorithm 2 finishes with P̂ = orth(P), Q = Xᵀ·P̂ (second pass)."""
    x2d = _flatten(x)
    y2d, p = dispatch.matmul_sketch(x2d, w.astype(x.dtype), state.q,
                                    backend=cfg.backend)
    p_hat = orthonormalize(p)
    q = x2d.T @ p_hat
    y = y2d.reshape(x.shape[:-1] + (w.shape[-1],))  # repro-lint: disable=residual-audit — the site OUTPUT, saved by downstream nonlinear vjps, not by this matmul (its input is the (tokens,r)+(k,r) sketch)
    if b is not None:
        y = y + b.astype(y.dtype)  # repro-lint: disable=residual-audit — bias-add vjp saves y for downstream consumers; same buffer as the site output above
    return y, p_hat, q


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array, b: Array | None,
                state: MatrixASIState):
    y, _, q = _fused_fwd(cfg, x, w, b, state)
    return y, MatrixASIState(q=q)


def asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array, b: Array | None,
               state: MatrixASIState):
    """y = x @ w (+ b);  stores only rank-r factors of x for bwd (r is the
    warm-start state's column count — per-layer ranks are therefore set by
    how the state was initialized, see ``init_asi_state(rank_plan=...)``).

    Under an active ``calibration.capture_sites`` context the site's input
    (and, via the tap added to y, its output cotangent) is recorded for the
    on-device planner; the tap sits OUTSIDE the custom_vjp boundary so its
    gradient is the true ∂L/∂y.
    """
    y, new_state = _asi_linear(cfg, x, w, b, state)
    cap = calibration.active()
    if cap is not None:
        y = cap.record("matrix", x, y)
    return y, new_state


def _asi_linear_vjp_fwd(cfg, x, w, b, state):
    y, p_hat, q = _fused_fwd(cfg, x, w, b, state)
    # Residuals: compressed factors only — X itself is NOT saved.
    res = (p_hat, q, w, x.shape, b is not None)
    return (y, MatrixASIState(q=q)), res


def _asi_linear_vjp_bwd(cfg, res, cts):
    g_y, _ = cts                                   # cotangent on new_state unused
    p_hat, q, w, x_shape, has_b = res
    g2d = g_y.reshape(-1, g_y.shape[-1])
    # One pass over g:  exact ∂L/∂x = g·Wᵀ (paper eq. 2) and the rank-r
    # reduction R = P̂ᵀ·g — then ∂L/∂W = Q·R  ~ 2Mr(N) + 2Kr(N) FLOPs.
    g_x2d, r = dispatch.matmul_grad_sketch(g2d, w, p_hat, backend=cfg.backend,
                                           out_axis=cfg.out_axis)
    g_x = g_x2d.reshape(x_shape)
    g_w = q.astype(g2d.dtype) @ r.astype(g2d.dtype)
    g_b = g2d.sum(axis=0) if has_b else None
    # state is an input we do not differentiate through: zero cotangent.
    g_state = jax.tree.map(jnp.zeros_like, MatrixASIState(q=q))
    return g_x, g_w.astype(w.dtype), g_b, g_state


_asi_linear.defvjp(_asi_linear_vjp_fwd, _asi_linear_vjp_bwd)


# ---------------------------------------------------------------------------
# HOSVD (fixed-rank truncated SVD) linear — the baseline, jit-friendly.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def hosvd_linear(cfg: LinearCompressionCfg, x: Array, w: Array, b: Array | None):
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    return y + b.astype(y.dtype) if b is not None else y


def _hosvd_linear_fwd(cfg, x, w, b):
    x2d = _flatten(x).astype(jnp.float32)
    # Full SVD every step — this is exactly the overhead ASI removes (eq. 11).
    u, s, vt = jnp.linalg.svd(x2d, full_matrices=False)
    r = min(cfg.rank, s.shape[0])
    p_hat = u[:, :r].astype(x.dtype)
    q = (vt[:r, :].T * s[:r]).astype(x.dtype)
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y, (p_hat, q, w, x.shape, b is not None)


def _hosvd_linear_bwd(cfg, res, g_y):
    p_hat, q, w, x_shape, has_b = res
    g2d = g_y.reshape(-1, g_y.shape[-1])
    g_x = (g2d @ w.T.astype(g2d.dtype)).reshape(x_shape)
    g_w = q.astype(g2d.dtype) @ (p_hat.astype(g2d.dtype).T @ g2d)
    g_b = g2d.sum(axis=0) if has_b else None
    return g_x, g_w.astype(w.dtype), g_b


hosvd_linear.defvjp(_hosvd_linear_fwd, _hosvd_linear_bwd)


# ---------------------------------------------------------------------------
# Grouped (per-expert) ASI linear for MoE.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GroupedASIState:
    q: Array      # (E, K, r)

    @staticmethod
    def init(key: Array, n_groups: int, k: int, rank: int,
             dtype=jnp.float32) -> "GroupedASIState":
        q = jax.random.normal(key, (n_groups, k, rank), jnp.float32).astype(dtype)
        return GroupedASIState(q=q)


def _grouped_fused_fwd(cfg: LinearCompressionCfg, x: Array, w: Array,
                       state: GroupedASIState):
    """One pass over each expert's activation slice: fused Y/P sketch, then
    per-expert orth + co-factor (vmapped Algorithm 2)."""
    y, p = dispatch.grouped_matmul_sketch(x, w.astype(x.dtype), state.q,
                                          backend=cfg.backend)

    def finish(xe, pe):
        p_hat = orthonormalize(pe)
        return p_hat, xe.T @ p_hat

    p_hat, q = jax.vmap(finish)(x, p)
    return y, p_hat, q


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array,
                        state: GroupedASIState):
    y, _, q = _grouped_fused_fwd(cfg, x, w, state)
    return y, GroupedASIState(q=q)


def grouped_asi_linear(cfg: LinearCompressionCfg, x: Array, w: Array,
                       state: GroupedASIState):
    """x (E, T, K) @ w (E, K, N) -> (E, T, N), ASI per expert.  Calibration
    capture mirrors ``asi_linear`` (kind='grouped', activation (E, T, K))."""
    y, new_state = _grouped_asi_linear(cfg, x, w, state)
    cap = calibration.active()
    if cap is not None:
        y = cap.record("grouped", x, y)
    return y, new_state


def _grouped_fwd(cfg, x, w, state):
    y, p_hat, q = _grouped_fused_fwd(cfg, x, w, state)
    return (y, GroupedASIState(q=q)), (p_hat, q, w)


def _grouped_bwd(cfg, res, cts):
    g_y, _ = cts
    p_hat, q, w = res
    # one pass over each expert's cotangent: exact g_x and R_e = P̂_eᵀ g_e,
    # then the per-expert low-rank weight grad  Q_e (K,r) @ R_e (r,N).
    g_x, r = dispatch.grouped_matmul_grad_sketch(g_y, w, p_hat,
                                                 backend=cfg.backend,
                                                 out_axis=cfg.out_axis)
    g_w = jnp.einsum("ekr,ern->ekn", q.astype(g_y.dtype),
                     r.astype(g_y.dtype))
    g_state = GroupedASIState(q=jnp.zeros_like(q))
    return g_x, g_w.astype(w.dtype), g_state


_grouped_asi_linear.defvjp(_grouped_fwd, _grouped_bwd)


# ---------------------------------------------------------------------------
# Plain dense reference (same signature family, for A/B in the trainer).
# ---------------------------------------------------------------------------

def dense_linear(x: Array, w: Array, b: Array | None = None) -> Array:
    y = jnp.tensordot(x, w.astype(x.dtype), axes=((-1,), (0,)))
    return y + b.astype(y.dtype) if b is not None else y
