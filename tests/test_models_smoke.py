"""Deliverable (f): per-arch REDUCED-config smoke tests — one forward/train
step on CPU asserting output shapes + no NaNs, plus a decode step per family.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, long_context_supported
from repro.configs.registry import ARCHS, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
             "targets": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jnp.ones((b, cfg.enc_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["embeds"] = 0.1 * jnp.ones((b, cfg.n_img_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    batch = _batch(cfg)

    def step(params, batch):
        (loss, (metrics, _)), grads = jax.value_and_grad(
            lambda p: api.loss(p, batch), has_aux=True)(params)
        return loss, grads

    loss, grads = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    cache = api.init_cache(2, 48)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: api.decode_step(p, c, t, jnp.int32(3)))(params, cache,
                                                                tok)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    # cache must actually change
    changed = any(bool(jnp.any(a != b)) for a, b in
                  zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_asi_finetune_step(arch):
    """The paper's technique must run on every assigned architecture
    (DESIGN.md §Arch-applicability)."""
    cfg = get_config(arch).reduced().replace(compress="asi", asi_rank=4,
                                             asi_last_k=1)
    api = build_model(cfg)
    params = api.init(KEY)
    st = api.init_asi(KEY)
    batch = _batch(cfg)

    def step(params, st):
        (loss, (_, new_st)), grads = jax.value_and_grad(
            lambda p: api.loss(p, batch, st), has_aux=True)(params)
        return loss, new_st

    loss, new_st = jax.jit(step)(params, st)
    assert bool(jnp.isfinite(loss))
    if st:   # warm-start state must update
        changed = any(bool(jnp.any(a != b)) for a, b in
                      zip(jax.tree.leaves(st), jax.tree.leaves(new_st)))
        assert changed


def test_long_context_skip_table():
    """long_500k runs exactly for SSM/hybrid/SWA archs (DESIGN.md table)."""
    expect_run = {"h2o-danube-3-4b", "jamba-1.5-large-398b", "mamba2-130m"}
    for arch in ARCHS:
        cfg = get_config(arch)
        assert long_context_supported(cfg) == (arch in expect_run), arch


def test_all_40_cells_defined():
    assert len(ARCHS) == 10 and len(SHAPES) == 4
