"""Docs checker: README.md / DESIGN.md must stay in sync with the code.

Three checks, run by the CI ``docs`` job (and locally via
``PYTHONPATH=src python scripts/check_docs.py``):

1. every ```python fenced block compiles (syntax; snippets with an
   intentional ellipsis are skipped);
2. every ``--flag`` used on a ``python -m <module>`` line inside a ```bash
   block is accepted by that module's argparse parser (checked against its
   ``--help`` output), and the module file exists;
3. every relative markdown link points at an existing file.

Exit status is non-zero on any failure, with one line per offence.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "DESIGN.md"]

# examples that document the public API surface: must compile and must not
# reach around repro.api into the launchers or runtime internals
PUBLIC_API_EXAMPLES = ["examples/embed_api.py",
                       "examples/scenario_domain_shift.py",
                       "examples/trace_serving.py"]
BANNED_IMPORT = re.compile(r"^\s*(?:from|import)\s+repro\.(launch|runtime)",
                           re.MULTILINE)

# modules whose --help we interrogate for flag checks
FLAGGED_MODULES = ("repro.launch.train", "repro.launch.serve",
                   "repro.launch.dryrun", "repro.launch.adapt",
                   "repro.launch.scenarios", "repro.analysis")

FENCE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)
LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)\)")


def fences(text: str):
    return [(m.group(1) or "", m.group(2)) for m in FENCE.finditer(text)]


def check_python_block(code: str, where: str, errors: list):
    if "..." in code or code.strip().startswith(">>>"):
        return
    try:
        compile(code, where, "exec")
    except SyntaxError as e:
        errors.append(f"{where}: python block does not compile: {e}")


def _help_text(module: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    p = subprocess.run([sys.executable, "-m", module, "--help"],
                       env=env, capture_output=True, text=True, timeout=300,
                       cwd=REPO)
    return p.stdout + p.stderr


def check_bash_block(code: str, where: str, errors: list,
                     help_cache: dict):
    # join backslash continuations so flags stay attached to their module
    joined = re.sub(r"\\\s*\n\s*", " ", code)
    for line in joined.splitlines():
        m = re.search(r"-m\s+([\w.]+)", line)
        if not m:
            continue
        module = m.group(1)
        candidates = []
        for base in (os.path.join(REPO, *module.split(".")),
                     os.path.join(REPO, "src", *module.split("."))):
            candidates += [base + ".py",                       # module
                           os.path.join(base, "__main__.py")]  # package CLI
        if not (any(os.path.exists(c) for c in candidates)
                or module == "pytest"):
            errors.append(f"{where}: module {module} not found in repo")
            continue
        flags = re.findall(r"(--[\w-]+)", line[m.end():])   # after the module
        if not flags or module not in FLAGGED_MODULES:
            continue
        if module not in help_cache:
            help_cache[module] = _help_text(module)
        for flag in flags:
            if flag not in help_cache[module]:
                errors.append(f"{where}: {module} does not accept {flag}")


def check_links(text: str, where: str, errors: list):
    for target in LINK.findall(text):
        if re.match(r"\w+://", target):
            continue
        if not os.path.exists(os.path.join(REPO, target)):
            errors.append(f"{where}: broken link -> {target}")


def check_api_example(rel_path: str, errors: list):
    path = os.path.join(REPO, rel_path)
    if not os.path.exists(path):
        errors.append(f"{rel_path}: public-API example missing")
        return
    with open(path) as f:
        src = f.read()
    try:
        compile(src, rel_path, "exec")
    except SyntaxError as e:
        errors.append(f"{rel_path}: does not compile: {e}")
    m = BANNED_IMPORT.search(src)
    if m:
        errors.append(f"{rel_path}: imports repro.{m.group(1)} — public-API "
                      "examples must go through repro.api only")


def main() -> int:
    errors: list[str] = []
    help_cache: dict[str, str] = {}
    for example in PUBLIC_API_EXAMPLES:
        check_api_example(example, errors)
    for doc in DOCS:
        path = os.path.join(REPO, doc)
        with open(path) as f:
            text = f.read()
        check_links(text, doc, errors)
        for i, (lang, code) in enumerate(fences(text)):
            where = f"{doc}#block{i}"
            if lang == "python":
                check_python_block(code, where, errors)
            elif lang in ("bash", "sh", "shell"):
                check_bash_block(code, where, errors, help_cache)
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(f"OK: {len(DOCS)} docs + {len(PUBLIC_API_EXAMPLES)} API "
              f"examples checked "
              f"({len(help_cache)} CLI parsers interrogated)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
