"""End-to-end training launcher.

Runs the fault-tolerant loop on any registered architecture (reduced configs
run on CPU; full configs target the production mesh).  This is the same step
function the dry-run lowers — one code path from laptop to pod.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --compress asi --ckpt-dir /tmp/ckpt

Mesh-sharded training: ``--layout {dp,fsdp,tp}`` builds a (data, model) mesh
over all visible devices (override the split with ``--mesh D,M``), shards
params / optimizer state / batches per ``repro.parallel.partition``, and
``--grad-accum N`` scans N microbatches per step.  Validate on CPU with

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 20 --layout fsdp --grad-accum 2

On a real cluster this binary is started once per host under the usual
jax.distributed initialization; XLA latency-hiding flags below overlap
collectives with compute.
"""
from __future__ import annotations

import os

# compute/comm overlap: latency-hiding scheduler (no-op on CPU, effective on
# TPU); set before jax import.
os.environ.setdefault("LIBTPU_INIT_ARGS",
                      "--xla_tpu_enable_latency_hiding_scheduler=true")

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.launch.mesh import make_layout_mesh
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.train_loop import (TrainLoopCfg, make_mesh_plan,
                                      make_train_step, run)


def build_data(cfg: ModelConfig, seq_len: int, global_batch: int, seed: int):
    base = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                global_batch=global_batch, seed=seed,
                                branching=2))
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return base

    class Wrapped:
        def batch(self, step):
            b = base.batch(step)
            n = b["tokens"].shape[0]
            if cfg.family == "encdec":
                b["frames"] = 0.1 * jnp.ones(
                    (n, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
            else:  # vlm
                b["embeds"] = 0.1 * jnp.ones(
                    (n, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            return b
    return Wrapped()


def main(argv=None):
    ap = argparse.ArgumentParser(
        epilog="Full flag matrix, quickstart and architecture map: README.md")
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress", default="none",
                    choices=("none", "asi", "hosvd"))
    ap.add_argument("--kernel-backend", default="auto",
                    choices=("auto", "pallas", "reference"),
                    help="fused ASI kernel dispatch (see repro.kernels.dispatch)")
    ap.add_argument("--asi-rank", type=int, default=None)
    ap.add_argument("--asi-last-k", type=int, default=None)
    ap.add_argument("--layout", default=None, choices=("dp", "fsdp", "tp"),
                    help="mesh-sharded training over all visible devices; "
                         "omit for the single-device step")
    ap.add_argument("--mesh", default=None, metavar="D,M",
                    help="data,model axis sizes overriding the --layout "
                         "default split (e.g. 2,4)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches accumulated per optimizer step "
                         "(lax.scan inside the jitted step)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a simulated node failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {"compress": args.compress,
                 "kernel_backend": args.kernel_backend}
    if args.asi_rank is not None:
        overrides["asi_rank"] = args.asi_rank
    if args.asi_last_k is not None:
        overrides["asi_last_k"] = args.asi_last_k
    cfg = cfg.replace(**overrides)

    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)
    asi_state = api.init_asi(key) if cfg.compress != "none" else {}
    mask = api.trainable_mask(params) if cfg.compress != "none" else None
    opt = make_optimizer(
        cfg.optimizer if cfg.optimizer != "adafactor" else "adamw",
        warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps),
        clip_norm=2.0)                      # paper: L2 clip threshold 2.0
    opt_state = opt.init(params)
    data = build_data(cfg, args.seq_len, args.batch, args.seed)
    if args.grad_accum < 1:
        ap.error(f"--grad-accum {args.grad_accum} must be >= 1")
    if args.batch % args.grad_accum != 0:
        ap.error(f"--batch {args.batch} must divide by "
                 f"--grad-accum {args.grad_accum}")
    if args.mesh is not None and args.layout is None:
        ap.error("--mesh requires --layout (it only shapes a layout's mesh)")
    shape = None
    if args.mesh is not None:
        try:
            shape = tuple(int(x) for x in args.mesh.split(","))
        except ValueError:
            shape = ()
        if len(shape) != 2:
            ap.error(f"--mesh {args.mesh!r} must be two comma-separated "
                     f"ints: data,model (e.g. 2,4)")
    plan = None
    if args.layout is not None:
        mesh = make_layout_mesh(args.layout, shape)
        plan = make_mesh_plan(cfg, mesh, args.layout, params, opt_state,
                              asi_state, data.batch(0))
        print(json.dumps({"mesh": dict(mesh.shape), "layout": args.layout,
                          "n_devices": mesh.size,
                          "grad_accum": args.grad_accum}))
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=mask,
                              kernel_backend=cfg.kernel_backend,
                              plan=plan, grad_accum=args.grad_accum)
    loop_cfg = TrainLoopCfg(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every,
                            fail_at_step=args.fail_at)
    res = run(step_fn, params, opt_state, asi_state, data, loop_cfg,
              hooks={"on_log": lambda s, m: print(
                  json.dumps({"step": s, **{k: round(v, 4)
                                            for k, v in m.items()}}))},
              plan=plan)
    print(json.dumps({"final_step": res.step, "restarts": res.restarts,
                      "stragglers": len(res.straggler_steps),
                      "final_loss": round(res.history[-1]["loss"], 4)}))


if __name__ == "__main__":
    main()
