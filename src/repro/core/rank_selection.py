"""Offline rank selection (paper §3.3): activation perplexity + budget search.

Pipeline (run ONCE before training, exactly as the paper prescribes):

1. For each explained-variance threshold ε_j in the grid E (paper uses
   {0.4,…,0.9}) and each fine-tuned layer i, decompose a calibration
   activation with HOSVD_ε, compute the approximate weight gradient, and
   record the *gradient* perplexity  P[i,j] = ‖∂L/∂W_i − ≈∂L/∂W_i‖_F (eq. 7)
   plus the resulting per-mode ranks R[i,j,:] and memory M[i,j] (eq. 5).

2. Pick one threshold index per layer minimizing Σ P subject to Σ M ≤ B
   (eq. 8-9).  The paper uses recursive backtracking (and flags it as a
   limitation); we provide both the faithful backtracking (with
   branch-and-bound pruning) and a beyond-paper quantized-knapsack DP that is
   polynomial and exact up to memory quantization.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hosvd as hosvd_lib
from repro.core.asi import tucker_storage_elems, matrix_storage_elems

Array = jax.Array

DEFAULT_EPS_GRID = (0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


@dataclasses.dataclass
class LayerCalibration:
    """Calibration capture for one fine-tuned layer."""
    name: str
    activation: np.ndarray         # the stored input A_i (any rank >= 2)
    grad_out: np.ndarray           # ∂L/∂A_{i+1} at the same step
    kind: str = "linear"           # 'linear' | 'conv'
    weight_grad_fn: Callable | None = None   # (a, g) -> exact dW (conv case)


@dataclasses.dataclass
class PerplexityTable:
    names: list[str]
    eps_grid: tuple[float, ...]
    perplexity: np.ndarray         # (N, E)
    memory: np.ndarray             # (N, E)  elements
    ranks: np.ndarray              # (N, E, n_modes)  (padded with 0 for linear)


def _linear_exact_grad(a: np.ndarray, g: np.ndarray) -> np.ndarray:
    a2, g2 = a.reshape(-1, a.shape[-1]), g.reshape(-1, g.shape[-1])
    return a2.T @ g2


def _linear_lowrank_grad(a: np.ndarray, g: np.ndarray, rank: int) -> np.ndarray:
    a2 = a.reshape(-1, a.shape[-1]).astype(np.float32)
    g2 = g.reshape(-1, g.shape[-1]).astype(np.float32)
    u, s, vt = np.linalg.svd(a2, full_matrices=False)
    r = min(rank, s.shape[0])
    # dW ≈ (U_r S_r V_rᵀ)ᵀ g = V_r S_r (U_rᵀ g)
    return (vt[:r].T * s[:r]) @ (u[:, :r].T @ g2)


def estimate_perplexity(layers: Sequence[LayerCalibration],
                        eps_grid: Sequence[float] = DEFAULT_EPS_GRID
                        ) -> PerplexityTable:
    """Step 1+2 of §3.3 on captured calibration tensors."""
    n, e = len(layers), len(eps_grid)
    max_modes = max(ly.activation.ndim for ly in layers)
    perp = np.zeros((n, e))
    mem = np.zeros((n, e))
    ranks = np.zeros((n, e, max_modes), dtype=np.int64)
    for i, ly in enumerate(layers):
        a = np.asarray(ly.activation, dtype=np.float32)
        g = np.asarray(ly.grad_out, dtype=np.float32)
        if ly.kind == "linear":
            exact = _linear_exact_grad(a, g)
            a2 = a.reshape(-1, a.shape[-1])
            _, s, _ = np.linalg.svd(a2, full_matrices=False)
            for j, eps in enumerate(eps_grid):
                energy = s ** 2
                cum = np.cumsum(energy) / max(energy.sum(), 1e-30)
                r = int(np.searchsorted(cum, eps) + 1)
                approx = _linear_lowrank_grad(a, g, r)
                perp[i, j] = float(np.linalg.norm(exact - approx))
                mem[i, j] = matrix_storage_elems(a2.shape[0], a2.shape[1], r)
                ranks[i, j, 0] = r
        else:   # conv: 4-mode HOSVD_ε
            assert ly.weight_grad_fn is not None, "conv calibration needs weight_grad_fn"
            exact = np.asarray(ly.weight_grad_fn(a, g))
            for j, eps in enumerate(eps_grid):
                core, factors, rs = hosvd_lib.hosvd(jnp.asarray(a), eps)
                a_hat = core
                for m, u in enumerate(factors):
                    a_hat = jnp.moveaxis(jnp.moveaxis(a_hat, m, -1) @ u.T, -1, m)
                approx = np.asarray(ly.weight_grad_fn(np.asarray(a_hat), g))
                perp[i, j] = float(np.linalg.norm(exact - approx))
                mem[i, j] = tucker_storage_elems(a.shape, rs)
                ranks[i, j, :4] = rs
    return PerplexityTable(names=[ly.name for ly in layers],
                           eps_grid=tuple(eps_grid),
                           perplexity=perp, memory=mem, ranks=ranks)


# ---------------------------------------------------------------------------
# Budget-constrained selection (eq. 8-9).
# ---------------------------------------------------------------------------

def select_ranks_backtracking(perplexity: np.ndarray, memory: np.ndarray,
                              budget: float) -> list[int]:
    """Paper-faithful recursive backtracking with branch-and-bound pruning.

    Returns the per-layer threshold index j minimizing Σ P s.t. Σ M ≤ budget.
    Raises ValueError when even the smallest-memory choice exceeds the budget.
    """
    n, e = perplexity.shape
    min_mem_suffix = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        min_mem_suffix[i] = min_mem_suffix[i + 1] + memory[i].min()
    min_perp_suffix = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        min_perp_suffix[i] = min_perp_suffix[i + 1] + perplexity[i].min()
    if min_mem_suffix[0] > budget:
        raise ValueError(
            f"budget {budget:.3g} infeasible: minimum memory {min_mem_suffix[0]:.3g}")

    best = {"perp": np.inf, "choice": None}
    choice = [0] * n

    def recurse(i: int, used_mem: float, used_perp: float):
        if used_perp + min_perp_suffix[i] >= best["perp"]:
            return                                   # bound prune
        if i == n:
            best["perp"] = used_perp
            best["choice"] = list(choice)
            return
        order = np.argsort(perplexity[i])            # try best-perplexity first
        for j in order:
            m = memory[i, j]
            if used_mem + m + min_mem_suffix[i + 1] > budget:
                continue                             # feasibility prune
            choice[i] = int(j)
            recurse(i + 1, used_mem + m, used_perp + perplexity[i, j])

    recurse(0, 0.0, 0.0)
    assert best["choice"] is not None
    return best["choice"]


def select_ranks_knapsack(perplexity: np.ndarray, memory: np.ndarray,
                          budget: float, n_bins: int = 4096) -> list[int]:
    """Beyond-paper: quantized multiple-choice knapsack DP (poly-time).

    Addresses the paper's stated limitation (Appendix C) that backtracking is
    brute-force.  Memory is quantized to ``n_bins`` levels; DP is exact on the
    quantized problem.  Quantization errs conservatively (ceil), so the true
    memory of the returned choice never exceeds the budget.
    """
    if budget <= 0:
        raise ValueError(f"budget {budget:.3g} infeasible: must be positive")
    n, e = perplexity.shape
    scale = budget / n_bins
    q = np.minimum(np.ceil(memory / max(scale, 1e-30)).astype(np.int64), n_bins + 1)
    INF = np.inf
    dp = np.full(n_bins + 1, INF)
    dp[0] = 0.0
    back = np.full((n, n_bins + 1), -1, dtype=np.int64)
    for i in range(n):
        ndp = np.full(n_bins + 1, INF)
        for j in range(e):
            c = q[i, j]
            if c > n_bins:
                continue
            shifted = np.full(n_bins + 1, INF)
            shifted[c:] = dp[:n_bins + 1 - c] + perplexity[i, j]
            better = shifted < ndp
            ndp = np.where(better, shifted, ndp)
            back[i][better] = j
        dp = ndp
    if not np.isfinite(dp).any():
        raise ValueError("budget infeasible under quantization")
    b = int(np.argmin(dp))
    choice = []
    for i in range(n - 1, -1, -1):
        j = int(back[i, b])
        choice.append(j)
        b -= int(q[i, j])
    choice.reverse()
    return choice


def apply_selection(table: PerplexityTable, choice: Sequence[int]) -> dict:
    """Materialize {layer_name: {'rank(s)': ..., 'memory': ..., 'eps': ...}}."""
    out = {}
    for i, name in enumerate(table.names):
        j = choice[i]
        out[name] = {
            "eps": table.eps_grid[j],
            "ranks": [int(r) for r in table.ranks[i, j] if r > 0],
            "memory_elems": float(table.memory[i, j]),
            "perplexity": float(table.perplexity[i, j]),
        }
    return out
