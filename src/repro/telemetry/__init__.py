"""Unified telemetry layer (DESIGN.md §13): span/counter/gauge/histogram
primitives over an injectable clock, a bounded ring-buffer
:class:`Recorder`, JSONL + Chrome-trace exporters, and a ``jax.profiler``
bridge.  Threaded through train/serve/adapt via ``Session(telemetry=...)``
and the ``--telemetry`` / ``--profile-trace`` launch flags.

Pure host-side and import-light on purpose: importing this package pulls
no runtime modules, and a disabled ``Recorder`` costs a few dict lookups
per hot-loop step.
"""
from repro.telemetry.export import (chrome_trace, export_chrome_trace,
                                    export_jsonl, read_jsonl,
                                    validate_jsonl_file)
from repro.telemetry.record import (Counter, Gauge, Histogram, ManualClock,
                                    Recorder)

__all__ = [
    "Recorder", "ManualClock", "Counter", "Gauge", "Histogram",
    "export_jsonl", "read_jsonl", "validate_jsonl_file",
    "chrome_trace", "export_chrome_trace",
]
