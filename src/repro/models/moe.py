"""Token-choice top-k MoE with capacity-based scatter dispatch.

Dispatch is the Switch/GShard cumsum-position scheme realized with scatter/
gather (no (T, E, C) one-hot einsum — that tensor is TB-scale at our shapes).
Experts are einsum-grouped (E, C, d) x (E, d, ff) so the expert dimension
shards cleanly over the 'model'/'experts' mesh axis (expert parallelism).

ASI integration: in fine-tune mode each expert FFN stores its activation
slice compressed with a per-expert warm-started factor (GroupedASIState).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compressed_linear import (GroupedASIState,
                                          LinearCompressionCfg,
                                          grouped_asi_linear)
from repro.models.layers import initializer
from repro.parallel.sharding import logical_shard

Array = jax.Array


def moe_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": initializer(k1, (d, e), dtype),
        "gate": initializer(k2, (e, d, f), dtype),
        "up": initializer(k3, (e, d, f), dtype),
        "down": initializer(k4, (e, f, d), dtype),
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_tok * cfg.capacity_factor
            / cfg.n_experts)
    return max(8, -(-c // 8) * 8)       # round up to a multiple of 8


def moe_apply(params: dict, x: Array, cfg: ModelConfig,
              asi_state: dict | None = None):
    """x (B, S, d) -> (y, aux_loss, new_asi_state).

    GShard-style grouped dispatch: each batch row is its own dispatch group
    (capacity positions via a cumsum *within the row*), so scatter/gather
    indices never cross the batch dim and GSPMD keeps the whole dispatch
    sharded over the data axes — no all-gather of the token buffer.  The
    expert dim of the (B, E, C, d) buffer shards over 'model' (EP).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    weights, sel = jax.lax.top_k(probs, k)                       # (B, S, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch):  E * Σ_e f_e · p_e
    density = jnp.mean(jax.nn.one_hot(sel[..., 0], e, dtype=jnp.float32),
                       (0, 1))
    p_mean = probs.mean((0, 1))
    aux = e * jnp.sum(density * p_mean) * cfg.router_aux_coef

    cap = _capacity(cfg, s)                                      # per row
    flat_sel = sel.reshape(b, s * k)                             # (B, S·k)
    oh = jax.nn.one_hot(flat_sel, e, dtype=jnp.int32)            # (B, S·k, E)
    pos = jnp.cumsum(oh, axis=1) - 1
    pos_sel = jnp.take_along_axis(pos, flat_sel[..., None], 2)[..., 0]
    keep = pos_sel < cap                                         # (B, S·k)
    tok_idx = jnp.repeat(jnp.arange(s), k)                       # (S·k,)
    w_flat = weights.reshape(b, s * k) * keep

    # dispatch: (B, E, C, d) buffer via per-row scatter (batch stays sharded)
    src = x[:, tok_idx] * keep[..., None].astype(x.dtype)        # (B, S·k, d)
    pos_c = jnp.clip(pos_sel, 0, cap - 1)

    def row_scatter(xr, er, pr):
        return jnp.zeros((e, cap, d), x.dtype).at[er, pr].add(xr)

    buf = jax.vmap(row_scatter)(src, flat_sel, pos_c)            # (B, E, C, d)
    buf = logical_shard(buf, "batch", "experts", None, None)

    # expert SwiGLU
    new_state: dict = {}

    def glin(name, inp, w):
        # TP shards the EXPERT dim here (when divisible), not the per-expert
        # ffn width — each device holds full-width blocks of its experts, so
        # the VMEM cap must see the global width (out_axis=None)
        ccfg = LinearCompressionCfg(rank=cfg.asi_rank,
                                    backend=cfg.kernel_backend,
                                    out_axis=None)
        if asi_state is not None and name in asi_state:
            flat = jnp.swapaxes(inp, 0, 1).reshape(e, b * cap, -1)
            y, ns = grouped_asi_linear(ccfg, flat, w, asi_state[name])
            new_state[name] = ns
            return jnp.swapaxes(y.reshape(e, b, cap, -1), 0, 1)
        return jnp.einsum("becd,edf->becf", inp, w.astype(inp.dtype))

    g = glin("gate", buf, params["gate"])
    u = glin("up", buf, params["up"])
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u)
    h = logical_shard(h, "batch", "experts", None, None)
    out_buf = glin("down", h, params["down"])                    # (B, E, C, d)

    # combine: per-row gather
    def row_gather(ob, er, pr):
        return ob[er, pr]                                        # (S·k, d)

    gathered = jax.vmap(row_gather)(out_buf, flat_sel, pos_c)
    contrib = gathered.astype(jnp.float32) * w_flat[..., None]
    y = contrib.reshape(b, s, k, d).sum(axis=2).astype(x.dtype)
    return y, aux, (new_state if asi_state is not None else None)


def moe_asi_state_init(key: Array, cfg: ModelConfig, n_tokens: int,
                       dtype=jnp.float32, ranks: dict | None = None) -> dict:
    """Per-expert ASI factors for gate/up (input dim d) and down (input ff).

    ``ranks`` optionally overrides the per-site rank (shared across experts
    — the grouped state is one (E, K, r) stack per site)."""
    k1, k2, k3 = jax.random.split(key, 3)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    r = lambda name: (ranks or {}).get(name, cfg.asi_rank)
    return {
        "gate": GroupedASIState.init(k1, e, d, r("gate"), dtype),
        "up": GroupedASIState.init(k2, e, d, r("up"), dtype),
        "down": GroupedASIState.init(k3, e, f, r("down"), dtype),
    }
