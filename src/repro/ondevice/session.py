"""DeviceSession: one set of weights, serving and adapting concurrently.

The session owns the params and hands the *same object* to (a) the
continuous-batching ``Engine`` (decode traffic) and (b) a memory-budgeted
ASI fine-tuning step.  Interleaving rides the engine's retirement hook:
every ``adapt_every`` finished requests the session runs ``burst_steps``
training steps on a replay batch, swaps the updated params into the engine,
and returns control to the decode loop — in-flight requests keep their
slots, positions, and KV rows, and continue decoding under the new weights.
That is "training while serving" with zero engine restarts.

Replay buffer: retired requests' token streams (prompt + generation) land in
a ring; batches are assembled at a **fixed shape** (batch x seq_len+1,
sequences tiled to length) so the jitted train step never recompiles as
traffic varies — on-device there is no XLA budget for shape churn.

Counters: per-burst adaptation loss (quality — should fall as the model
fits its own traffic), and the loss on a frozen probe batch (forgetting —
drift of the pre-adaptation task).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.serve_loop import Engine, Request, ServeCfg
from repro.telemetry import Recorder

Array = jax.Array


class ReplayBuffer:
    """Ring buffer of retired token streams with fixed-shape batch assembly.

    This is the FIFO baseline policy: at capacity, ``add`` evicts the oldest
    stream (strict add order).  Alternative policies (reservoir,
    phase-stratified — ``repro.scenarios.replay``) subclass it and override
    only the storage/selection hooks; the fixed-shape batch-assembly
    contract (``sample_batch`` shape never depends on fill level) is shared
    and must hold for every policy — the jitted train step relies on it.
    """

    policy = "fifo"

    def __init__(self, capacity: int, seq_len: int, seed: int = 0):
        self.capacity = capacity
        self.seq_len = seq_len
        self.current_phase = 0          # scenario runners advance this
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._rows())

    def set_phase(self, phase: int):
        """Tag subsequent ``add``s with ``phase`` (used by stratified
        policies; the base FIFO ring ignores it)."""
        self.current_phase = int(phase)

    def add(self, tokens: Sequence[int]):
        toks = [int(t) for t in tokens]
        if len(toks) >= 2:                       # need one (input, target) pair
            self._store(toks, self.current_phase)

    # --- policy hooks -------------------------------------------------------

    def _store(self, toks: list[int], phase: int):
        self._buf.append(toks)

    def _rows(self) -> Sequence[list[int]]:
        """The stored streams as an indexable sequence."""
        return self._buf

    def _select_indices(self, batch_size: int) -> np.ndarray:
        return self._rng.integers(0, len(self._rows()), size=batch_size)

    # --- fixed-shape assembly (shared by every policy) ----------------------

    def sample_batch(self, batch_size: int) -> dict[str, Array]:
        """Fixed-shape {'tokens','targets'} (batch, seq_len); short streams
        are tiled to length so no masking/padding enters the loss."""
        stored = self._rows()
        if not stored:
            raise ValueError("replay buffer is empty")
        idx = self._select_indices(batch_size)
        need = self.seq_len + 1
        rows = np.empty((batch_size, need), np.int32)
        for r, i in enumerate(idx):
            seq = stored[i]
            reps = -(-need // len(seq))
            rows[r] = (seq * reps)[:need]
        return {"tokens": jnp.asarray(rows[:, :-1]),
                "targets": jnp.asarray(rows[:, 1:])}


@dataclasses.dataclass
class SessionCfg:
    adapt_every: int = 4          # retired requests per adaptation burst
    burst_steps: int = 1          # train steps per burst
    total_steps: int = 8          # adaptation-step budget for the session
    batch_size: int = 2
    seq_len: int = 32
    replay_size: int = 64


@dataclasses.dataclass
class SessionReport:
    serve_stats: Any
    adapt_losses: list            # per-step adaptation loss, burst order
    probe_losses: list            # probe loss after each burst (index 0 =
                                  # before any adaptation)
    steps: int = 0
    bursts: int = 0
    retired: int = 0
    adapt_wall_s: float = 0.0

    @property
    def first_loss(self) -> float | None:
        return self.adapt_losses[0] if self.adapt_losses else None

    @property
    def last_loss(self) -> float | None:
        return self.adapt_losses[-1] if self.adapt_losses else None

    @property
    def probe_drift(self) -> float | None:
        """Forgetting counter: probe-loss change since before adaptation."""
        if len(self.probe_losses) < 2:
            return None
        return self.probe_losses[-1] - self.probe_losses[0]

    def summary(self) -> dict:
        return {
            "retired": self.retired, "bursts": self.bursts,
            "adapt_steps": self.steps,
            "adapt_loss_first": self.first_loss,
            "adapt_loss_last": self.last_loss,
            "probe_loss_before": (self.probe_losses[0]
                                  if self.probe_losses else None),
            "probe_loss_after": (self.probe_losses[-1]
                                 if self.probe_losses else None),
            "probe_drift": self.probe_drift,
            "adapt_wall_s": round(self.adapt_wall_s, 3),
            "tokens_per_s": getattr(self.serve_stats, "tokens_per_s", 0.0),
        }


class DeviceSession:
    """Interleave serving and budget-planned ASI adaptation on one device.

    ``train_step`` must be a ``make_train_step`` product built with
    ``donate=False`` (the engine still holds references to the params) and
    an ``asi_state`` whose per-site ranks came from the planner.
    """

    def __init__(self, api, params, train_step, opt_state, asi_state,
                 serve_cfg: ServeCfg, cfg: SessionCfg,
                 probe_batch: dict | None = None, seed: int = 0,
                 telemetry: Recorder | None = None):
        self.api = api
        self.params = params
        self.opt_state = opt_state
        self.asi_state = asi_state
        self.cfg = cfg
        self._train_step = train_step
        # one recorder spans serving and adaptation: burst spans interleave
        # with the engine's request lifecycle on a single timeline
        self.tele = telemetry if telemetry is not None \
            else Recorder(enabled=False)
        self.engine = Engine(api, params, serve_cfg, seed=seed,
                             telemetry=telemetry)
        self.replay = ReplayBuffer(cfg.replay_size, cfg.seq_len, seed=seed)
        self._probe_batch = probe_batch
        self._eval_loss = jax.jit(
            lambda p, b, s: api.loss(p, b, s)[0])
        self.report = SessionReport(serve_stats=None, adapt_losses=[],
                                    probe_losses=[])
        self._step_count = 0
        self._since_burst = 0
        # scenario hook: called as on_burst(self) after every completed burst
        # (post params-swap, post probe measurement) — the scenario runner
        # records its per-phase probe losses and elastic-budget checks here
        self.on_burst = None

    # --- counters -----------------------------------------------------------

    def reset_counters(self):
        """Zero the report and the step budget (e.g. after a warm-up pass
        that pre-compiled the engine and the train step)."""
        self.report = SessionReport(serve_stats=None, adapt_losses=[],
                                    probe_losses=[])
        self._step_count = 0
        self._since_burst = 0

    def probe_loss(self) -> float | None:
        if self._probe_batch is None:
            return None
        return float(self._eval_loss(self.params, self._probe_batch,
                                     self.asi_state))

    # --- adaptation ---------------------------------------------------------

    def adapt_steps(self, n: int) -> list[float]:
        """Run up to ``n`` fixed-shape replay steps; updates the engine's
        params in place (next decode step serves the new weights)."""
        rec = self.tele
        losses = []
        t0 = time.perf_counter()
        with rec.span("adapt.burst", burst=self.report.bursts + 1,
                      budget=n):
            for _ in range(n):
                if (len(self.replay) == 0
                        or self._step_count >= self.cfg.total_steps):
                    break
                batch = self.replay.sample_batch(self.cfg.batch_size)
                self.params, self.opt_state, self.asi_state, metrics = \
                    self._train_step(self.params, self.opt_state,
                                     self.asi_state, batch,
                                     jnp.int32(self._step_count))
                losses.append(metrics["loss"])   # device array; convert
                self._step_count += 1            # after the loop
            # one sync for the whole burst (also makes adapt_wall_s honest:
            # device_get blocks until every queued step has finished)
            losses = [float(v) for v in jax.device_get(losses)]
            self.engine.params = self.params      # weights live for decode
        self.report.adapt_wall_s += time.perf_counter() - t0
        self.report.adapt_losses.extend(losses)
        self.report.steps = self._step_count
        rec.count("adapt.steps", len(losses))
        for v in losses:
            rec.observe("adapt.loss", v)
        if losses:
            self.report.bursts += 1
            rec.count("adapt.bursts")
            rec.set_gauge("adapt.loss_last", losses[-1])
            pl = self.probe_loss()
            if pl is not None:
                self.report.probe_losses.append(pl)
                rec.set_gauge("adapt.probe_loss", pl)
            if self.on_burst is not None:
                self.on_burst(self)
        return losses

    # --- serving ------------------------------------------------------------

    def _on_retire(self, req: Request):
        self.report.retired += 1
        self.replay.add(list(req.prompt) + list(req.out))
        self._since_burst += 1
        if self._since_burst >= self.cfg.adapt_every:
            self._since_burst = 0
            self.adapt_steps(self.cfg.burst_steps)

    def run(self, requests: list[Request],
            drain_steps: bool = True) -> SessionReport:
        """Serve ``requests`` with interleaved adaptation bursts; optionally
        drain the remaining adaptation-step budget afterwards."""
        pl = self.probe_loss()
        if pl is not None and not self.report.probe_losses:
            self.report.probe_losses.append(pl)
        self.engine.run(requests, on_retire=self._on_retire)
        self.report.serve_stats = self.engine.last_stats
        while (drain_steps and len(self.replay)
               and self._step_count < self.cfg.total_steps):
            self.adapt_steps(self.cfg.burst_steps)
        return self.report
