"""Continuous-batching decode serving.

``Engine`` keeps one KV/SSM cache of ``max_batch`` rows alive for the whole
request stream and drives all active rows in lock-step:

* **prefill** — a whole prompt runs through the model in one jitted call
  (``ModelAPI.prefill``), and its batch-1 cache is scattered into a free slot
  of the shared cache (``_write_slot``).  Freed rows are reused by later
  admissions; the cache is allocated once per ``run``, never per wave.
* **decode** — one jitted ``_step`` advances every slot together.  Each slot
  carries its own position counter (per-slot ``pos`` threads through
  ``decode_step`` into the attention cache writes/masks), its own
  remaining-token budget, and an active flag; finished slots are frozen by
  masking, so retirement and admission never trigger recompilation.
* **sampling** — on device, inside the jitted step: greedy ``argmax`` or
  temperature sampling via per-slot ``jax.random.categorical``.  The only
  per-step host transfer is the sampled-token vector and the
  finished-this-step mask (two ``(max_batch,)`` vectors).

The scheduler (plain Python around the jitted calls) retires finished
requests, admits pending ones into freed slots, and records throughput
counters (tokens/s, per-request time-to-first-token) in ``Engine.last_stats``.

``SequentialEngine`` preserves the original one-request-at-a-time loop
(per-token Python prefill, host-side argmax) as the A/B baseline for
``benchmarks/serve_throughput.py`` and the batch=1 parity tests.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    embeds: Any = None            # vlm prefix embeds / encdec audio frames,
                                  # shape (1, n, d) — threaded into prefill
    ttft_s: float | None = None   # time-to-first-token, set by Engine.run


@dataclasses.dataclass
class ServeCfg:
    max_batch: int = 4
    max_len: int = 128
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early


@dataclasses.dataclass
class ServeStats:
    """Throughput/latency counters for one ``Engine.run``."""
    requests: int = 0
    generated_tokens: int = 0
    prefill_calls: int = 0
    decode_steps: int = 0
    wall_s: float = 0.0
    tokens_per_s: float = 0.0
    ttft_mean_s: float = 0.0
    ttft_max_s: float = 0.0


def _mk_stats(results: list[Request], gen: int, prefills: int, steps: int,
              wall: float) -> ServeStats:
    ttfts = [r.ttft_s for r in results if r.ttft_s is not None]
    return ServeStats(
        requests=len(results), generated_tokens=gen,
        prefill_calls=prefills, decode_steps=steps, wall_s=wall,
        tokens_per_s=gen / wall if wall > 0 else 0.0,
        ttft_mean_s=float(np.mean(ttfts)) if ttfts else 0.0,
        ttft_max_s=float(np.max(ttfts)) if ttfts else 0.0)


def _prefix_len(req: Request, family: str) -> int:
    """How many decoder positions ``req.embeds`` occupies: vlm prefix embeds
    sit in front of the prompt; encdec frames feed the encoder (zero)."""
    if req.embeds is None or family == "encdec":
        return 0
    return req.embeds.shape[1]


class Engine:
    """Single-host continuous-batching engine over a ModelAPI."""

    def __init__(self, model_api, params, cfg: ServeCfg, seed: int = 0):
        self.api = model_api
        self.params = params
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self.last_stats = ServeStats()
        self._prefill_jit: dict = {}      # (prompt_len, embeds_shape) -> fn
        B, temp, eos, max_len = (cfg.max_batch, cfg.temperature, cfg.eos_id,
                                 cfg.max_len)
        # Donating the cache/state lets XLA update the (large) KV buffers in
        # place each step; CPU ignores donation, so only request it off-CPU.
        donate = jax.default_backend() != "cpu"

        def sample(logits: Array, key: Array) -> Array:
            """(n, V) logits -> (n,) sampled tokens, on device."""
            if temp > 0:
                keys = jax.random.split(key, logits.shape[0])
                return jax.vmap(
                    lambda k, l: jax.random.categorical(k, l / temp)
                )(keys, logits).astype(jnp.int32)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def step_fn(params, cache, state, key):
            """Advance all slots one token.  Frozen (inactive) slots keep
            their position/budget; their sampled token is discarded."""
            logits, cache = model_api.decode_step(params, cache,
                                                  state["tok"], state["pos"])
            tok = sample(logits, key)
            pos = jnp.where(state["active"], state["pos"] + 1, state["pos"])
            rem = jnp.where(state["active"], state["rem"] - 1, state["rem"])
            done = (tok == eos) | (rem <= 0) | (pos + 1 >= max_len)
            finished = state["active"] & done
            tok = jnp.where(state["active"], tok, state["tok"])
            state = {"tok": tok, "pos": pos, "rem": rem,
                     "active": state["active"] & ~done}
            return cache, state, tok, finished

        def admit_fn(state, slot, logits, pos0, rem0, key):
            """Occupy ``slot``: sample the first token from the prefill
            logits and install the slot's counters."""
            tok0 = sample(logits, key)[0]
            done0 = (tok0 == eos) | (rem0 - 1 <= 0) | (pos0 + 1 >= max_len)
            state = {"tok": state["tok"].at[slot].set(tok0),
                     "pos": state["pos"].at[slot].set(pos0),
                     "rem": state["rem"].at[slot].set(rem0 - 1),
                     "active": state["active"].at[slot].set(~done0)}
            return state, tok0, done0

        def write_slot(cache, one, slot):
            """Scatter a batch-1 prefill cache into row ``slot`` of the
            shared cache (slot reuse: the freed row is simply overwritten)."""
            return jax.tree.map(
                lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                    c, o.astype(c.dtype), slot, axis=1), cache, one)

        self._step = jax.jit(step_fn,
                             donate_argnums=(1, 2) if donate else ())
        self._admit = jax.jit(admit_fn)
        self._write_slot = jax.jit(write_slot,
                                   donate_argnums=(0,) if donate else ())
        self._B = B

    # Each distinct (prompt length, embeds shape) compiles its own prefill;
    # the memo is bounded (LRU-ish: oldest insertion evicted) so a long-lived
    # engine over naturally varying lengths cannot grow compile state without
    # bound.  Length-bucketing with right-padding would bound compiles harder
    # but is not exactness-preserving for SSM/conv states (pad tokens enter
    # the recurrence), so we keep exact per-length prefill.
    _PREFILL_MEMO_MAX = 64

    def _prefill(self, req: Request):
        """Jitted whole-prompt prefill, cached per (length, embeds-shape)."""
        key = (len(req.prompt), None if req.embeds is None
               else tuple(req.embeds.shape))
        fn = self._prefill_jit.get(key)
        if fn is None:
            while len(self._prefill_jit) >= self._PREFILL_MEMO_MAX:
                self._prefill_jit.pop(next(iter(self._prefill_jit)))
            max_len = self.cfg.max_len
            if req.embeds is None:
                fn = jax.jit(lambda p, t: self.api.prefill(p, t, max_len))
            else:
                fn = jax.jit(
                    lambda p, t, e: self.api.prefill(p, t, max_len, e))
            self._prefill_jit[key] = fn
        toks = jnp.asarray([req.prompt], jnp.int32)
        if req.embeds is None:
            return fn(self.params, toks)
        return fn(self.params, toks, jnp.asarray(req.embeds))

    def run(self, requests: list[Request], on_retire=None) -> list[Request]:
        """Serve ``requests``; returns them in completion order.  Counters
        for the run land in ``self.last_stats``.

        ``on_retire(req)`` is called once per request the moment it
        finishes, letting consumers stream completions (e.g. the on-device
        ``DeviceSession`` feeding its replay buffer) without copying this
        loop.  The callback runs between jitted steps, so it may mutate
        ``self.params`` (live weight swaps) — in-flight slots keep decoding
        under whatever params the next step reads."""
        cfg = self.cfg
        B = self._B
        family = getattr(self.api.cfg, "family", "")
        for r in requests:
            if family == "encdec" and r.embeds is None:
                raise ValueError(f"request {r.uid}: encdec serving needs "
                                 "encoder frames in Request.embeds")
            if len(r.prompt) + _prefix_len(r, family) + 1 > cfg.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt ({len(r.prompt)} tokens "
                    f"+ {_prefix_len(r, family)} prefix) does not fit "
                    f"max_len={cfg.max_len} with room to generate")
        t0 = time.perf_counter()
        # zero-budget requests complete immediately (matches the sequential
        # engine, whose generate loop never runs for them)
        results: list[Request] = [r for r in requests if r.max_new_tokens <= 0]
        for r in results:
            r.done = True
            if on_retire is not None:
                on_retire(r)
        pending = collections.deque(r for r in requests
                                    if r.max_new_tokens > 0)
        slots: list[Request | None] = [None] * B
        cache = self.api.init_cache(B, cfg.max_len)
        state = {"tok": jnp.zeros((B,), jnp.int32),
                 "pos": jnp.zeros((B,), jnp.int32),
                 "rem": jnp.zeros((B,), jnp.int32),
                 "active": jnp.zeros((B,), bool)}
        gen = prefills = steps = 0

        def _retire(req: Request):
            req.done = True
            results.append(req)
            if on_retire is not None:
                on_retire(req)

        while pending or any(s is not None for s in slots):
            # --- admission: fill every free slot from the queue ------------
            for slot in range(B):
                while slots[slot] is None and pending:
                    req = pending.popleft()
                    logits, pcache = self._prefill(req)
                    cache = self._write_slot(cache, pcache, slot)
                    self.key, sub = jax.random.split(self.key)
                    pos0 = len(req.prompt) + _prefix_len(req, family)
                    state, tok0, done0 = self._admit(
                        state, slot, logits, pos0, req.max_new_tokens, sub)
                    prefills += 1
                    tok0_h, done0_h = jax.device_get((tok0, done0))
                    req.out.append(int(tok0_h))
                    req.ttft_s = time.perf_counter() - t0
                    gen += 1
                    if bool(done0_h):
                        _retire(req)          # slot stays free for the next
                    else:
                        slots[slot] = req
            if not any(s is not None for s in slots):
                continue
            # --- lock-step decode over all active slots --------------------
            self.key, sub = jax.random.split(self.key)
            cache, state, tok, finished = self._step(self.params, cache,
                                                     state, sub)
            steps += 1
            tok_h, fin_h = jax.device_get((tok, finished))
            for slot, req in enumerate(slots):
                if req is None:
                    continue
                req.out.append(int(tok_h[slot]))
                gen += 1
                if bool(fin_h[slot]):
                    _retire(req)
                    slots[slot] = None

        self.last_stats = _mk_stats(results, gen, prefills, steps,
                                    time.perf_counter() - t0)
        return results


class SequentialEngine:
    """The original strictly sequential loop: one slot at a time, a fresh
    cache per wave, per-token Python prefill, and a host argmax round-trip
    per generated token.  Kept as the A/B baseline — the continuous engine
    must beat this in tokens/s and match it token-for-token at batch=1."""

    def __init__(self, model_api, params, cfg: ServeCfg, seed: int = 0):
        self.api = model_api
        self.params = params
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self.last_stats = ServeStats()
        self._decode = jax.jit(
            lambda p, c, t, pos: model_api.decode_step(p, c, t, pos))

    def _prefill_one(self, cache, slot: int, prompt: Sequence[int]):
        """Feed a prompt token-by-token into one batch slot."""
        toks = list(prompt)
        logits = None
        for pos, t in enumerate(toks):
            tok_vec = self._slot_tokens(slot, t)
            logits, cache = self._decode(self.params, cache, tok_vec,
                                         jnp.int32(pos))
        return cache, logits, len(toks)

    def _slot_tokens(self, slot: int, tok: int) -> Array:
        v = np.zeros((self.cfg.max_batch,), np.int32)
        v[slot] = tok
        return jnp.asarray(v)

    def run(self, requests: list[Request], on_retire=None) -> list[Request]:
        t0 = time.perf_counter()
        pending = list(requests)
        results = []
        gen = steps = 0
        while pending:
            active = pending[: self.cfg.max_batch]
            pending = pending[len(active):]
            cache = self.api.init_cache(self.cfg.max_batch, self.cfg.max_len)
            for slot, req in enumerate(active):
                cache, logits, pos = self._prefill_one(cache, slot, req.prompt)
                for _ in range(req.max_new_tokens):
                    row = logits[slot]
                    if self.cfg.temperature > 0:
                        self.key, sub = jax.random.split(self.key)
                        # per-token sync is the point of this A/B baseline:
                        # it measures what Engine's batched device_get avoids
                        tok = int(jax.random.categorical(  # repro-lint: disable=jit-purity
                            sub, row / self.cfg.temperature))
                    else:
                        tok = int(jnp.argmax(row))  # repro-lint: disable=jit-purity
                    req.out.append(tok)
                    gen += 1
                    if req.ttft_s is None:
                        req.ttft_s = time.perf_counter() - t0
                    if tok == self.cfg.eos_id or pos + 1 >= self.cfg.max_len:
                        break
                    logits, cache = self._decode(
                        self.params, cache, self._slot_tokens(slot, tok),
                        jnp.int32(pos))
                    steps += 1
                    pos += 1
                req.done = True
                results.append(req)
                if on_retire is not None:
                    on_retire(req)
        self.last_stats = _mk_stats(results, gen, 0, steps,
                                    time.perf_counter() - t0)
        return results
