"""Continuous-batching serving engine tests: batch=1 parity with the legacy
sequential path, batch-composition independence (slot staggering/reuse),
admission beyond max_batch, eos early-stop, sampling determinism, and the
serve launcher CLI."""
import jax
import pytest

from repro.configs.registry import get_config
from repro.launch import serve as serve_cli
from repro.models import build_model
from repro.runtime.serve_loop import (Engine, Request, SequentialEngine,
                                      ServeCfg)

KEY = jax.random.PRNGKey(0)
PROMPTS = [[1, 2, 3], [5, 6, 7, 8], [9, 10, 11, 12, 13], [3, 1]]


def _reqs(prompts=PROMPTS, max_new=6):
    return [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _outs(done):
    return {r.uid: r.out for r in done}


def _api(arch, **replace):
    cfg = get_config(arch).reduced()
    if replace:
        cfg = cfg.replace(**replace)
    api = build_model(cfg)
    return api, api.init(KEY)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m"])
def test_batched_engine_matches_sequential_at_batch1(arch):
    """Greedy, batch=1: the new engine must reproduce the legacy sequential
    engine token-for-token on a fixed prompt set."""
    api, params = _api(arch)
    new = Engine(api, params, ServeCfg(max_batch=1, max_len=32)).run(_reqs())
    old = SequentialEngine(api, params,
                           ServeCfg(max_batch=1, max_len=32)).run(_reqs())
    assert _outs(new) == _outs(old)


def test_batch_composition_does_not_change_outputs():
    """Greedy outputs must be identical whether a request decodes alone or
    staggered against other slots at different positions (slot isolation +
    per-slot position counters)."""
    api, params = _api("tinyllama-1.1b")
    alone = Engine(api, params, ServeCfg(max_batch=1, max_len=32)).run(_reqs())
    packed = Engine(api, params,
                    ServeCfg(max_batch=4, max_len=32)).run(_reqs())
    assert _outs(packed) == _outs(alone)


def test_admission_beyond_max_batch_and_slot_reuse():
    """7 requests through 2 slots: every slot row is reused by later
    admissions and all requests complete with their full budgets."""
    api, params = _api("tinyllama-1.1b")
    prompts = [[1 + i, 2, 3 + i] for i in range(7)]
    # uneven budgets force retirements at different steps → reused slots
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3 + (i % 3))
            for i, p in enumerate(prompts)]
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32))
    done = eng.run(reqs)
    assert len(done) == 7
    assert all(len(r.out) == 3 + (r.uid % 3) for r in done)
    assert eng.last_stats.prefill_calls == 7
    # reused rows must not leak the previous occupant: each request's output
    # equals its solo (fresh-cache) run
    solo = Engine(api, params, ServeCfg(max_batch=1, max_len=32)).run(
        [Request(uid=i, prompt=list(p), max_new_tokens=3 + (i % 3))
         for i, p in enumerate(prompts)])
    assert _outs(done) == _outs(solo)


def test_swa_ring_wraps_with_staggered_slots():
    """Sliding-window arch: decode past the window so the per-slot ring
    buffers wrap at different steps; must still match the sequential path
    (includes a prompt longer than the window → prefill ring alignment)."""
    api, params = _api("h2o-danube-3-4b")        # window 16 reduced
    prompts = [list(range(1, 21)), [2, 3, 4]]    # S=20 > window, S=3
    def mk():
        return [Request(uid=i, prompt=list(p), max_new_tokens=10)
                for i, p in enumerate(prompts)]
    new = Engine(api, params, ServeCfg(max_batch=2, max_len=40)).run(mk())
    old = SequentialEngine(api, params,
                           ServeCfg(max_batch=2, max_len=40)).run(mk())
    assert _outs(new) == _outs(old)


def test_eos_early_stop_frees_slot():
    api, params = _api("tinyllama-1.1b")
    probe = Engine(api, params, ServeCfg(max_batch=1, max_len=32)).run(
        _reqs([PROMPTS[0]], max_new=6))
    out = probe[0].out
    assert len(out) == 6
    eos = out[2]                      # stop as soon as this token appears
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32, eos_id=eos))
    done = eng.run(_reqs([PROMPTS[0], PROMPTS[1]], max_new=6))
    r0 = _outs(done)[0]
    assert r0 == out[: out.index(eos) + 1]      # truncated at first eos
    assert done[-1].done


def test_temperature_sampling_deterministic_given_seed():
    api, params = _api("tinyllama-1.1b")
    scfg = ServeCfg(max_batch=2, max_len=32, temperature=0.8)
    a = Engine(api, params, scfg, seed=7).run(_reqs(max_new=5))
    b = Engine(api, params, scfg, seed=7).run(_reqs(max_new=5))
    assert _outs(a) == _outs(b)


def test_greedy_ignores_seed():
    api, params = _api("tinyllama-1.1b")
    scfg = ServeCfg(max_batch=2, max_len=32, temperature=0.0)
    a = Engine(api, params, scfg, seed=1).run(_reqs(max_new=4))
    b = Engine(api, params, scfg, seed=99).run(_reqs(max_new=4))
    assert _outs(a) == _outs(b)


def test_stats_counters_surface_throughput():
    api, params = _api("tinyllama-1.1b")
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32))
    done = eng.run(_reqs(max_new=4))
    s = eng.last_stats
    assert s.requests == len(PROMPTS)
    assert s.generated_tokens == sum(len(r.out) for r in done) == 16
    assert s.tokens_per_s > 0
    assert all(r.ttft_s is not None and r.ttft_s <= s.wall_s for r in done)
    assert 0 < s.ttft_mean_s <= s.ttft_max_s <= s.wall_s


def test_zero_budget_request_generates_nothing():
    """max_new_tokens=0 must yield an empty output (sequential-engine
    semantics), not the admission-sampled first token."""
    api, params = _api("tinyllama-1.1b")
    reqs = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=0),
            Request(uid=1, prompt=[4, 5], max_new_tokens=3)]
    done = Engine(api, params, ServeCfg(max_batch=2, max_len=32)).run(reqs)
    outs = _outs(done)
    assert outs[0] == [] and len(outs[1]) == 3
    assert all(r.done for r in done)


def test_prompt_longer_than_max_len_rejected():
    api, params = _api("tinyllama-1.1b")
    eng = Engine(api, params, ServeCfg(max_batch=1, max_len=8))
    with pytest.raises(ValueError):
        eng.run([Request(uid=0, prompt=list(range(1, 9)), max_new_tokens=2)])


def test_int8_kv_cache_serving():
    """Prefill must quantize primed rows so the scattered tree matches the
    int8 cache layout (k/v + scales)."""
    api, params = _api("tinyllama-1.1b", kv_cache_dtype="int8")
    done = Engine(api, params, ServeCfg(max_batch=2, max_len=32)).run(
        _reqs(max_new=4))
    assert all(len(r.out) == 4 for r in done)


def test_encdec_request_without_frames_rejected():
    api, params = _api("whisper-medium")
    eng = Engine(api, params, ServeCfg(max_batch=1, max_len=16))
    with pytest.raises(ValueError, match="encoder frames"):
        eng.run([Request(uid=0, prompt=[1, 2], max_new_tokens=2)])


def test_encdec_serving_with_frames():
    """Whisper: frames feed the encoder; decoder slots still stagger."""
    api, params = _api("whisper-medium")
    cfg = api.cfg
    frames = jax.random.normal(KEY, (1, cfg.enc_len, cfg.d_model))
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=16))
    reqs = [Request(uid=i, prompt=[1, 2 + i], max_new_tokens=3,
                    embeds=frames * (1.0 + 0.5 * i)) for i in range(3)]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.out) == 3 for r in done)


# --- serve launcher CLI ---------------------------------------------------------


def test_serve_cli_reduced_flag_is_toggleable():
    ap = serve_cli.build_parser()
    assert ap.parse_args(["--arch", "tinyllama-1.1b"]).reduced is True
    assert ap.parse_args(["--arch", "tinyllama-1.1b",
                          "--reduced"]).reduced is True
    assert ap.parse_args(["--arch", "tinyllama-1.1b",
                          "--no-reduced"]).reduced is False


def test_serve_cli_end_to_end(capsys):
    done = serve_cli.main(["--arch", "tinyllama-1.1b", "--reduced",
                           "--requests", "3", "--max-new", "4",
                           "--max-batch", "2", "--max-len", "32"])
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    assert "tokens_per_s" in capsys.readouterr().out
