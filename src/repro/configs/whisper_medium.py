"""whisper-medium — encoder-decoder; conv/audio frontend is a STUB per the
assignment (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]  24L(enc)+24L(dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865, GELU MLPs, LayerNorm+bias, learned positions."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    enc_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    use_bias=True,
    learned_pos=True,
)
