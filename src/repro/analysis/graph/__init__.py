"""graph-lint: the jaxpr/HLO-level rule plane (DESIGN.md §14).

The ast plane (§11) reasons about what the *source* says; these rules
reason about what JAX actually *traces and compiles*, closing the blind
spots inherent to taint analysis (helpers it cannot inline, custom_vjp
it cannot see through).  Four rule families:

- ``residual-audit``   — enumerate the train-step vjp residuals per
  registry family, classify each by shape/site, reconcile ASI factor
  bytes against the analytic ledger (0% gap), and flag any dense
  ``(B, S, d)`` activation save at its producing source line.
- ``collectives-audit`` — compile the dp/fsdp/tp train steps on a
  forced-host-device mesh and gate per-kind collective counts against
  ``parallel.partition.COMM_SIGNATURE``.
- ``donation-audit``   — verify every buffer declared donated in the
  train/serve jits is actually aliased in the lowered module
  (``tf.aliasing_output``); a dead donation is a silent 2x on the
  buffers the paper's memory claims count.
- ``recompile-audit``  — hash abstract call signatures across shape
  sweeps (prefill chunks, grad-accum, rank plans) and flag weak-type /
  python-scalar leaks that would fragment the jit cache.

All rules run device-free except collectives-audit, which needs a real
multi-device backend and therefore compiles in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from repro.analysis.graph import collectives_audit  # noqa: F401
from repro.analysis.graph import donation_audit  # noqa: F401
from repro.analysis.graph import recompile_audit  # noqa: F401
from repro.analysis.graph import residual_audit  # noqa: F401
