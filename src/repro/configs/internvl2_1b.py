"""internvl2-1b — InternViT (STUB frontend) + qwen2-0.5b-class LM backbone.
[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; 256 image-patch embeddings prepended per the stub contract."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    n_img_tokens=256,
    qkv_bias=True,
    rope_theta=1000000.0,
    act="silu",
)
