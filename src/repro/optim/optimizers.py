"""Optimizers as pure pytree transforms (no optax dependency).

* ``sgdm``      — SGD + momentum: the paper's fine-tuning setup.
* ``adamw``     — decoupled weight decay Adam: default pretraining choice.
* ``adafactor`` — factored second moments: keeps optimizer HBM ~0 for the
                  398B jamba config (see DESIGN.md §6).

All support a trainable-``mask`` pytree (True = update): frozen params get
neither updates nor weight decay — required for the paper's frozen-backbone
fine-tuning so decay cannot erode the pretrained weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params, step, mask)


def _masked(new, old, mask):
    if mask is None:
        return new
    return jax.tree.map(
        lambda n, o, m: jnp.where(m, n, o) if not isinstance(m, bool)
        else (n if m else o), new, old, mask)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgdm(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0,
         clip_norm: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params)}

    def update(grads, state, params, step, mask=None):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        mu = _masked(mu, state["mu"], mask)

        def upd(p, m):
            d = m + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        new_params = _masked(jax.tree.map(upd, params, mu), params, mask)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, clip_norm: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"mu": jax.tree.map(z, params), "nu": jax.tree.map(z, params)}

    def update(grads, state, params, step, mask=None):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        mu = _masked(mu, state["mu"], mask)
        nu = _masked(nu, state["nu"], mask)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t

        def upd(p, m, v):
            d = (m / c1) / (jnp.sqrt(v / c2) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        new_params = _masked(jax.tree.map(upd, params, mu, nu), params, mask)
        return new_params, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              clip_norm: float = 0.0) -> Optimizer:
    """Factored second moments over the trailing two dims (stacked leading
    scan dims keep their own factors), RMS-scaled updates (Shazeer&Stern)."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def z(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"f": jax.tree.map(z, params)}

    def update(grads, state, params, step, mask=None):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta * f["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                nf = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            d = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), nf

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_f = treedef.flatten_up_to(state["f"])
        outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_f = treedef.unflatten([o[1] for o in outs])
        new_params = _masked(new_params, params, mask)
        # keep factored stats only where trainable
        if mask is not None:
            new_f = jax.tree.map(
                lambda nf, of: nf, new_f, state["f"])
        return new_params, {"f": new_f}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    return {"sgdm": sgdm, "adamw": adamw, "adafactor": adafactor}[name](lr_fn, **kw)
