"""Paper Table 1 (+Table 2 structure): activation memory & training FLOPs of
vanilla / gradient-filter / HOSVD_eps / ASI when fine-tuning the last #Layers
convolutions of the paper's models, via the closed-form cost model
(Appendix A, eqs. 5/11/13-19) on the exact layer shapes.

Validated claims:
  * ASI memory ≈ HOSVD memory ≪ vanilla (up to the 120x regime at low rank)
  * HOSVD per-step FLOPs explode (the 1988-vs-19 GFLOPs effect)
  * ASI total step FLOPs < vanilla (R_S up to 1.86x)
"""
from __future__ import annotations

from repro.core import flops as F
from repro.core.gradient_filter import pooled_storage_elems

from benchmarks.paper_shapes import ASI_RANKS, PAPER_MODELS

BYTES = 4


def table_rows():
    rows = []
    for model, layers in PAPER_MODELS.items():
        for n_layers in (2, 4):
            sel = layers[:n_layers]
            van_mem = sum(F.vanilla_activation_elems(cd) for cd in sel) * BYTES
            van_fl = sum(F.vanilla_forward_flops(cd)
                         + F.vanilla_backward_weight_flops(cd) for cd in sel)
            gf_mem = sum(pooled_storage_elems(
                (cd.b, cd.c_in, cd.h, cd.w), 2) for cd in sel) * BYTES
            asi_mem = sum(F.tucker_activation_elems(cd, ASI_RANKS)
                          for cd in sel) * BYTES
            asi_fl = sum(F.vanilla_forward_flops(cd)
                         + F.asi_overhead_flops(cd, ASI_RANKS)
                         + F.asi_backward_weight_flops(cd, ASI_RANKS)
                         for cd in sel)
            ho_fl = sum(F.vanilla_forward_flops(cd)
                        + F.hosvd_overhead_flops(cd)
                        + F.asi_backward_weight_flops(cd, ASI_RANKS)
                        for cd in sel)
            rows.append({
                "model": model, "layers": n_layers,
                "vanilla_mem_mb": van_mem / 2**20,
                "gradfilter_mem_mb": gf_mem / 2**20,
                "asi_mem_mb": asi_mem / 2**20,
                "vanilla_gflops": van_fl / 1e9,
                "hosvd_gflops": ho_fl / 1e9,
                "asi_gflops": asi_fl / 1e9,
                "mem_ratio": van_mem / asi_mem,
                "speedup_vs_hosvd": ho_fl / asi_fl,
                "speedup_vs_vanilla": van_fl / asi_fl,
            })
    return rows


def run(verbose=True):
    rows = table_rows()
    if verbose:
        hdr = (f"{'model':14s} {'#L':>3s} {'van MB':>8s} {'GF MB':>7s} "
               f"{'ASI MB':>7s} {'van GF':>8s} {'HOSVD GF':>9s} "
               f"{'ASI GF':>7s} {'R_C':>7s} {'R_S':>5s}")
        print(hdr)
        for r in rows:
            print(f"{r['model']:14s} {r['layers']:3d} "
                  f"{r['vanilla_mem_mb']:8.2f} {r['gradfilter_mem_mb']:7.2f} "
                  f"{r['asi_mem_mb']:7.3f} {r['vanilla_gflops']:8.1f} "
                  f"{r['hosvd_gflops']:9.1f} {r['asi_gflops']:7.1f} "
                  f"{r['mem_ratio']:7.1f} {r['speedup_vs_vanilla']:5.2f}")
    # paper-claim assertions (structure-level reproduction)
    for r in rows:
        assert r["asi_mem_mb"] < 0.1 * r["vanilla_mem_mb"]
        assert r["hosvd_gflops"] > 5 * r["vanilla_gflops"]
        assert r["asi_gflops"] < r["vanilla_gflops"]
    return rows


if __name__ == "__main__":
    run()
