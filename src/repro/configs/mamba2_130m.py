"""mamba2-130m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]  24L d_model=768 d_ff=0 vocab=50280,
ssm_state=128, headdim=64 (-> 24 SSD heads at expand=2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,            # no attention heads; SSD heads derive from d_inner
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    act="silu",
)
