"""Deterministic synthetic data pipelines.

Everything is a pure function of (seed, step, host) so any host can
regenerate any batch — this is what makes checkpoint-restart and elastic
rescaling exact: no data-loader state to persist, just the step counter.

* LM stream: order-1 Markov chain over the vocab (a fixed random transition
  structure), so models can LEARN it — used by the convergence tests that
  compare vanilla vs ASI vs HOSVD training, mirroring the paper's accuracy
  comparisons on a task we can run on CPU.
* Image stream: per-class Gaussian blobs + noise for the convnet repro.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMStreamCfg:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # successors per token (lower = easier task)
    table_seed: int | None = None   # Markov-table seed; None -> ``seed``.
                                    # Re-seeding only this swaps the chain's
                                    # dynamics while the sampling stream
                                    # (start tokens, successor choices) stays
                                    # fixed — the scenario harness's domain
                                    # shift is exactly such a table swap.


def transition_table(cfg: LMStreamCfg) -> np.ndarray:
    """The stream's order-1 Markov successor table, (vocab, branching)."""
    rng = np.random.default_rng(cfg.seed if cfg.table_seed is None
                                else cfg.table_seed)
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.vocab_size, cfg.branching)).astype(np.int32)


_transition_table = transition_table          # back-compat alias


class LMStream:
    """Markov-chain token stream; ``batch(step)`` is pure in (seed, step)."""

    def __init__(self, cfg: LMStreamCfg, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.table = jnp.asarray(transition_table(cfg))

    def batch(self, step: int) -> dict[str, Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step),
            self.host_id)
        k0, k1 = jax.random.split(key)
        b, s, v = self.local_batch, self.cfg.seq_len, self.cfg.vocab_size
        start = jax.random.randint(k0, (b,), 0, v)
        choices = jax.random.randint(k1, (b, s), 0, self.cfg.branching)

        def step_fn(tok, choice):
            nxt = self.table[tok, choice]
            return nxt, nxt

        _, seq = jax.lax.scan(
            lambda c, ch: step_fn(c, ch), start, choices.T)
        seq = jnp.concatenate([start[None], seq], 0).T        # (b, s+1)
        return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}


@dataclasses.dataclass(frozen=True)
class ImageStreamCfg:
    num_classes: int
    hw: int = 32
    global_batch: int = 64
    seed: int = 0
    noise: float = 0.6
    proto_seed: int | None = None   # class-prototype seed; None -> ``seed``.
                                    # Re-seeding only this moves the class
                                    # blobs (a vision domain shift) while the
                                    # label/noise stream stays fixed.


class ImageStream:
    """Class-conditional Gaussian-blob images (NCHW)."""

    def __init__(self, cfg: ImageStreamCfg, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.local_batch = cfg.global_batch // n_hosts
        self.host_id = host_id
        rng = np.random.default_rng(cfg.seed if cfg.proto_seed is None
                                    else cfg.proto_seed)
        self.prototypes = jnp.asarray(
            rng.normal(size=(cfg.num_classes, 3, cfg.hw, cfg.hw))
            .astype(np.float32))

    def batch(self, step: int) -> dict[str, Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed + 1), step),
            self.host_id)
        k0, k1 = jax.random.split(key)
        labels = jax.random.randint(k0, (self.local_batch,), 0,
                                    self.cfg.num_classes)
        noise = jax.random.normal(
            k1, (self.local_batch, 3, self.cfg.hw, self.cfg.hw)) * self.cfg.noise
        return {"images": self.prototypes[labels] + noise, "labels": labels}
