"""On-device adaptation: budget-driven train-while-serve.

The subsystem that turns the repo from "a trainer plus a server" into the
paper's actual deployment story — learning on the device under a hard
activation-memory budget:

* ``ledger``  — per-layer activation-memory accounting (analytical bytes for
  vanilla / HOSVD / ASI-shortcut training + measured numbers from compiled
  programs) for every model family in the registry;
* ``planner`` — captures calibration activations on real batches and drives
  ``core.rank_selection`` (paper §3.3) to choose per-layer ranks under a
  ``--mem-budget-mb`` budget, emitting a plan ``make_train_step`` consumes;
* ``session`` — a ``DeviceSession`` interleaving the continuous-batching
  serving engine with memory-budgeted ASI fine-tuning steps fed from a
  replay buffer of retired requests.

CLI: ``python -m repro.launch.adapt`` (see README flag matrix).
"""
from repro.ondevice.ledger import Ledger, LedgerRow, SiteSpec, build_ledger
from repro.ondevice.planner import AdaptPlan, build_plan, capture_calibration
from repro.ondevice.session import DeviceSession, ReplayBuffer, SessionCfg

__all__ = [
    "Ledger", "LedgerRow", "SiteSpec", "build_ledger",
    "AdaptPlan", "build_plan", "capture_calibration",
    "DeviceSession", "ReplayBuffer", "SessionCfg",
]
