"""Telemetry battery (DESIGN.md §13).

* deterministic span trees under an injected ``ManualClock``;
* ring-buffer bounding with explicit drop counters;
* JSONL and Chrome-trace exporter schema round-trips;
* request-lifecycle event parity against ``ServeStats`` (TTFT/token/
  preemption counts derived from the event stream equal the stats view —
  both are fed by the same recorder, observed two ways);
* adaptation-burst and replan span emission through a full scenario run;
* telemetry-contract lint fixtures: violating / clean / suppressed.
"""
import functools
import io
import json
import os
import sys
import textwrap

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.analysis import rules  # noqa: E402,F401  (registers lint rules)
from repro.analysis.core import run_lint  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.runtime.serve_loop import (Engine, Request,  # noqa: E402
                                      SequentialEngine, ServeCfg)
from repro.telemetry import (ManualClock, Recorder,  # noqa: E402
                             chrome_trace, export_jsonl, read_jsonl,
                             validate_jsonl_file)
from repro.telemetry.export import jsonl_lines  # noqa: E402

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# primitives under an injected clock
# --------------------------------------------------------------------------

def test_span_tree_deterministic_under_manual_clock():
    rec = Recorder(clock=ManualClock(start=0.0, tick=1.0))
    with rec.span("outer", cache="paged"):
        with rec.span("inner", step=3):
            rec.instant("mark", uid=7)
    ev = list(rec.events)
    assert [(e["kind"], e["name"], e["ts"]) for e in ev] == [
        ("B", "outer", 0.0), ("B", "inner", 1.0), ("I", "mark", 2.0),
        ("E", "inner", 3.0), ("E", "outer", 4.0)]
    outer_b, inner_b = ev[0], ev[1]
    assert outer_b["parent"] == 0                  # root span
    assert inner_b["parent"] == outer_b["id"]      # nested under outer
    assert inner_b["attrs"] == {"step": 3}
    assert ev[2]["attrs"] == {"uid": 7}
    # same program, same clock => byte-identical stream
    rec2 = Recorder(clock=ManualClock(start=0.0, tick=1.0))
    with rec2.span("outer", cache="paged"):
        with rec2.span("inner", step=3):
            rec2.instant("mark", uid=7)
    assert list(rec2.events) == ev


def test_ring_buffer_bounds_and_counts_drops():
    rec = Recorder(clock=ManualClock(), capacity=8)
    for i in range(20):
        rec.instant(f"e{i}")
    assert len(rec.events) == 8
    assert rec.dropped == 12
    assert [e["name"] for e in rec.events] == [f"e{i}" for i in range(12, 20)]


def test_disabled_recorder_keeps_aggregates_drops_events():
    rec = Recorder(clock=ManualClock(), enabled=False)
    with rec.span("s"):
        rec.instant("i")
        rec.count("c", 3)
        rec.observe("h", 0.5)
        rec.set_gauge("g", 7.0)
    assert list(rec.events) == []                  # event plane off
    assert rec.counter("c").value == 3             # aggregates still flow
    assert rec.hist("h").values == [0.5]
    assert rec.gauge("g").value == 7.0 and rec.gauge("g").peak == 7.0


def test_gauge_peak_resets_to_floor():
    g = Recorder(clock=ManualClock()).gauge("x")
    g.set(5.0)
    g.set(2.0)
    assert g.value == 2.0 and g.peak == 5.0
    g.reset_peak(floor=2.0)
    assert g.peak == 2.0


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def _sample_recorder() -> Recorder:
    rec = Recorder(clock=ManualClock(start=0.0, tick=0.5))
    with rec.span("run", n=2):
        rec.set_gauge("pool", 4)
        with rec.span("step"):
            rec.instant("tick", uid=0)
    rec.count("tokens", 6)
    rec.observe("ttft_s", 0.25)
    return rec


def test_jsonl_round_trip(tmp_path):
    rec = _sample_recorder()
    path = str(tmp_path / "out.jsonl")
    export_jsonl(rec, path)
    with open(path) as f:
        lines = [json.loads(line) for line in f]
    assert all(line["v"] == 1 for line in lines)
    assert lines[0]["kind"] == "H" and lines[0]["schema"] == "repro.telemetry"
    assert lines[-1]["kind"] == "M"
    events, metrics, dropped = read_jsonl(path)
    assert [e["kind"] for e in events] == ["B", "G", "B", "I", "E", "E"]
    assert dropped == 0
    assert metrics["tokens"] == 6
    assert metrics["ttft_s.count"] == 1
    assert metrics["pool"] == 4 and metrics["pool.peak"] == 4
    errors, summary = validate_jsonl_file(path)
    assert errors == []
    assert summary["unclosed_spans"] == 0
    assert summary["by_kind"] == {"B": 2, "E": 2, "I": 1, "G": 1}


def test_jsonl_validator_rejects_malformed(tmp_path):
    good = "\n".join(jsonl_lines(_sample_recorder()))
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write(good + "\n")
        f.write(json.dumps({"v": 1, "kind": "B", "ts": 0.0}) + "\n")  # no id
        f.write(json.dumps({"v": 9, "kind": "I", "ts": 0.0,
                            "name": "x"}) + "\n")
    errors, _ = validate_jsonl_file(bad)
    assert errors and "missing field" in errors[0]
    with pytest.raises(ValueError, match="schema version"):
        read_jsonl(io.StringIO(json.dumps({"v": 2, "kind": "H",
                                           "schema": "s"})))


def test_chrome_trace_schema():
    rec = _sample_recorder()
    trace = chrome_trace(rec, process_name="unit")
    evs = trace["traceEvents"]
    assert evs[0] == {"ph": "M", "pid": 1, "name": "process_name",
                      "args": {"name": "unit"}}
    slices = [e for e in evs if e["ph"] == "X"]
    # B/E pairs pair up into complete slices with microsecond ts/dur
    names = {e["name"]: e for e in slices}
    assert set(names) == {"run", "step"}
    assert names["step"]["ts"] == pytest.approx(1.0e6)
    assert names["step"]["dur"] == pytest.approx(1.0e6)
    assert names["run"]["args"] == {"n": 2}
    insts = [e for e in evs if e["ph"] == "i"]
    assert insts[0]["name"] == "tick" and insts[0]["s"] == "t"
    counters = [e for e in evs if e["ph"] == "C"]
    assert counters[0]["name"] == "pool"
    assert counters[0]["args"] == {"value": 4}
    json.dumps(trace)                              # loadable by the viewer


def test_chrome_trace_renders_unclosed_spans():
    rec = Recorder(clock=ManualClock())
    rec.span("never_closed").__enter__()
    evs = chrome_trace(rec)["traceEvents"]
    open_slices = [e for e in evs
                   if e["ph"] == "X" and e["name"] == "never_closed"]
    assert open_slices and open_slices[0]["dur"] == 0


# --------------------------------------------------------------------------
# request-lifecycle parity vs ServeStats
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _api(arch="tinyllama-1.1b"):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    return api, api.init(KEY)


def _reqs(specs):
    return [Request(uid=i, prompt=[1 + (i * 5 + j) % 37 for j in range(pl)],
                    max_new_tokens=mn, arrival_step=ar)
            for i, (pl, mn, ar) in enumerate(specs)]


def _lifecycle(events, name):
    return [e for e in events if e["kind"] == "I" and e["name"] == name]


def test_paged_engine_lifecycle_matches_stats():
    """The acceptance check: a paged trace run's event stream re-derives
    TTFT observations, token counts and preemptions that exactly match the
    ``last_stats`` view (small pool so preemption fires)."""
    api, params = _api()
    rec = Recorder(capacity=1 << 14)
    eng = Engine(api, params,
                 ServeCfg(max_batch=4, max_len=32, cache="paged",
                          page_block=4, pool_blocks=10), telemetry=rec)
    done = eng.run(_reqs([(3, 18, 0), (4, 18, 0), (5, 18, 0), (2, 18, 0)]))
    st = eng.last_stats
    ev = list(rec.events)

    retired = _lifecycle(ev, "serve.request.retired")
    first = _lifecycle(ev, "serve.request.first_token")
    assert len(retired) == st.requests == 4
    assert sum(e["attrs"]["tokens"] for e in retired) == st.generated_tokens
    assert st.generated_tokens == sum(len(r.out) for r in done)
    assert len(_lifecycle(ev, "serve.request.preempted")) == st.preemptions
    assert st.preemptions > 0
    assert len(_lifecycle(ev, "serve.request.queued")) == 4
    assert len(_lifecycle(ev, "serve.request.admitted")) >= 4  # re-admits

    # TTFT: one first_token per request; event attrs are the histogram
    assert len(first) == st.requests
    ttfts = [e["attrs"]["ttft_s"] for e in first]
    assert float(np.mean(ttfts)) == st.ttft_mean_s
    assert float(np.percentile(ttfts, 50)) == st.ttft_p50_s

    # aggregate plane agrees with both
    assert rec.counter("serve.tokens").value == st.generated_tokens
    assert rec.counter("serve.preemptions").value == st.preemptions
    assert rec.gauge("serve.kv.used_blocks").peak == st.peak_used_blocks

    # decode steps are spans; kv occupancy was sampled every step
    steps = [e for e in ev
             if e["kind"] == "B" and e["name"] == "serve.decode_step"]
    assert len(steps) == st.decode_steps
    assert len([e for e in ev if e["kind"] == "G"
                and e["name"] == "serve.kv.used_blocks"]) >= len(steps)


def test_sequential_engine_emits_lifecycle():
    api, params = _api()
    rec = Recorder()
    eng = SequentialEngine(api, params, ServeCfg(max_batch=2, max_len=32),
                           telemetry=rec)
    eng.run(_reqs([(3, 4, 0), (4, 4, 0), (5, 4, 0)]))
    st = eng.last_stats
    ev = list(rec.events)
    retired = _lifecycle(ev, "serve.request.retired")
    assert len(retired) == st.requests == 3
    assert sum(e["attrs"]["tokens"] for e in retired) == st.generated_tokens
    runs = [e for e in ev if e["kind"] == "B" and e["name"] == "serve.run"]
    assert runs and runs[0]["attrs"]["cache"] == "sequential"


def test_engine_without_recorder_still_derives_stats():
    """No-telemetry engines use an internal disabled recorder: stats stay
    exact and the event plane stays empty."""
    api, params = _api()
    eng = Engine(api, params, ServeCfg(max_batch=2, max_len=32))
    eng.run(_reqs([(3, 4, 0), (4, 4, 0)]))
    assert eng.last_stats.requests == 2
    assert eng.last_stats.generated_tokens == 8
    assert list(eng.tele.events) == []


# --------------------------------------------------------------------------
# adaptation spans through a scenario run
# --------------------------------------------------------------------------

def test_scenario_emits_burst_and_replan_spans():
    """A forced-replan scenario run emits adapt.burst spans (from the
    DeviceSession), adapt.replan_check/adapt.replan spans and ledger drift
    gauges (from the elastic hook), all on one recorder."""
    from repro.scenarios import run_scenario

    rec = Recorder(capacity=1 << 15)
    r = run_scenario(telemetry=rec, scenario="domain-shift",
                     arch="tinyllama_1_1b", reduced=True, seed=0,
                     mem_budget_mb=0.05, budget_schedule=(0.05, 0.045),
                     drift_threshold=-1.0, waves_per_phase=2, rate=4.0,
                     steps=16, adapt_every=2, batch=2, seq_len=16)
    ev = list(rec.events)
    spans = [e["name"] for e in ev if e["kind"] == "B"]
    assert "adapt.burst" in spans
    assert "adapt.replan_check" in spans
    assert "adapt.replan" in spans
    assert rec.counter("adapt.replans").value == len(r.replans) == 1
    assert rec.counter("adapt.bursts").value == len(r.burst_phase)
    drift = [e for e in ev if e["kind"] == "G"
             and e["name"] == "adapt.ledger.drift"]
    assert drift, "ledger drift gauge never sampled"
    # ledger_checks rounds to 4 decimals; the gauge keeps full precision
    assert round(drift[0]["value"], 4) == r.ledger_checks[0]["drift"]
    # serving and adaptation interleave on one timeline
    assert "serve.run" in spans


# --------------------------------------------------------------------------
# telemetry-contract lint rule
# --------------------------------------------------------------------------

_VIOLATING = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp


    @jax.jit
    def step(x, rec):
        y = jnp.sum(x)
        rec.observe("loss", y)
        return y


    def serve_loop(xs, rec):
        for x in xs:
            v = jnp.mean(x)
            rec.set_gauge("v", v)
""")

_CLEAN = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp


    @jax.jit
    def step(x):
        return jnp.sum(x)


    def serve_loop(xs, rec):
        for x in xs:
            v = jnp.mean(x)
        h = float(jax.device_get(v))
        rec.set_gauge("v", h)
""")

_SUPPRESSED = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp


    def serve_loop(xs, rec):
        for x in xs:
            v = jnp.mean(x)
            rec.set_gauge("v", v)  # repro-lint: disable=telemetry-contract
""")


def _lint_fixture(tmp_path, source):
    mod = tmp_path / "src" / "repro" / "runtime" / "fixture_mod.py"
    mod.parent.mkdir(parents=True, exist_ok=True)
    mod.write_text(source)
    return run_lint(root=str(tmp_path), select=["telemetry-contract"])


def test_contract_flags_violations(tmp_path):
    found = _lint_fixture(tmp_path, _VIOLATING)
    live = [f for f in found if not f.suppressed]
    assert len(live) == 2
    assert any("inside traced code" in f.message for f in live)
    assert any("device value inside a loop body" in f.message for f in live)


def test_contract_passes_clean_code(tmp_path):
    assert _lint_fixture(tmp_path, _CLEAN) == []


def test_contract_respects_suppression(tmp_path):
    found = _lint_fixture(tmp_path, _SUPPRESSED)
    assert found and all(f.suppressed for f in found)


def test_contract_clean_at_head():
    """The shipped tree has zero unsuppressed telemetry-contract findings."""
    found = run_lint(select=["telemetry-contract"])
    assert [f for f in found if not f.suppressed] == []
