"""Validate an exported telemetry JSONL stream against the v1 schema.

Usage::

    python -m repro.telemetry out.jsonl

Exits 0 and prints a one-line JSON summary (event counts, dropped, open
spans) when the stream is well-formed; exits 1 listing schema errors
otherwise.  CI's ``telemetry-smoke`` job runs this against the stream a
``launch/serve --telemetry`` e2e emits.
"""
import argparse
import json
import sys

from repro.telemetry.export import validate_jsonl_file


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Validate a repro telemetry JSONL export (schema v1).")
    p.add_argument("path", help="JSONL file written via --telemetry")
    p.add_argument("--min-events", type=int, default=0,
                   help="fail unless the stream holds at least this many "
                        "events (default 0)")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    errors, summary = validate_jsonl_file(args.path)
    for err in errors:
        print(f"SCHEMA FAIL {err}", file=sys.stderr)
    if not errors and summary.get("events", 0) < args.min_events:
        print(f"SCHEMA FAIL only {summary.get('events', 0)} events "
              f"(< --min-events {args.min_events})", file=sys.stderr)
        errors = ["too few events"]
    print(json.dumps({"ok": not errors, "path": args.path, **summary}))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
