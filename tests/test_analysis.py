"""repro-lint: each rule fires on a violating fixture, stays quiet on its
clean twin, and honors suppressions; the real tree is clean at HEAD; the
partition-coverage sweep runs every config x layout without device arrays."""
import json
import shutil
import textwrap

import pytest

from repro.analysis import core
from repro.analysis import partition_coverage
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.core import FileContext

REPO_ROOT = core.find_repo_root()


def _ctx(tmp_path, rel, src) -> FileContext:
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(src))
    return FileContext.parse(str(path), str(tmp_path))


def _run(rule_name: str, ctx: FileContext):
    """Run one file-scope rule over one fixture, stamping suppressions the
    way the driver does."""
    scope, fn, _doc = core.RULES[rule_name]
    assert scope == "file"
    out = []
    for f in fn(ctx):
        f.suppressed = ctx.is_suppressed(f.rule, f.line)
        out.append(f)
    return out


def _unsuppressed(findings):
    return [f for f in findings if not f.suppressed]


# ---------------------------------------------------------------------------
# residual-contract
# ---------------------------------------------------------------------------

_VJP_DENSE = """\
    import jax
    import jax.numpy as jnp

    @jax.custom_vjp
    def f(x, w):
        return x @ w

    def f_fwd(x, w):
        y = x @ w
        res = (x, w){suffix}
        return y, res

    def f_bwd(res, g):
        x, w = res
        return (g @ w.T, x.T @ g)

    f.defvjp(f_fwd, f_bwd)
"""

_VJP_CLEAN = """\
    import jax
    import jax.numpy as jnp

    @jax.custom_vjp
    def f(x, w):
        return x @ w

    def f_fwd(x, w):
        y = x @ w
        p = jnp.dot(x.T, y)      # contraction: rank-r, not a dense save
        res = (p, w)
        return y, res

    def f_bwd(res, g):
        p, w = res
        return (g @ w.T, p)

    f.defvjp(f_fwd, f_bwd)
"""


def test_residual_contract_flags_dense_activation_save(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/fx.py",
               _VJP_DENSE.format(suffix=""))
    found = _run("residual-contract", ctx)
    assert any("x" in f.message for f in _unsuppressed(found)), found


def test_residual_contract_quiet_on_contracted_save(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/fx.py", _VJP_CLEAN)
    assert _unsuppressed(_run("residual-contract", ctx)) == []


def test_residual_contract_suppression(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/fx.py",
               _VJP_DENSE.format(
                   suffix="  # repro-lint: disable=residual-contract"))
    found = _run("residual-contract", ctx)
    assert found and all(f.suppressed for f in found)


def test_residual_contract_arity_mismatch(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/fx.py", """\
        import jax

        @jax.custom_vjp
        def f(x, w):
            return x @ w

        def f_fwd(x, w):
            p = jax.numpy.dot(x.T, x)
            return x @ w, (p, w)

        def f_bwd(res, g):
            p, w = res
            return (g @ w.T,)        # one cotangent for two diff args

        f.defvjp(f_fwd, f_bwd)
        """)
    found = _unsuppressed(_run("residual-contract", ctx))
    assert any("cotangent" in f.message or "returns" in f.message
               for f in found), found


def test_residual_contract_out_of_scope(tmp_path):
    # same dense save outside core/, models/, kernels/: not this rule's beat
    ctx = _ctx(tmp_path, "src/repro/runtime/fx.py",
               _VJP_DENSE.format(suffix=""))
    assert _run("residual-contract", ctx) == []


# ---------------------------------------------------------------------------
# jit-purity: traced bodies
# ---------------------------------------------------------------------------

def test_jit_purity_flags_host_effects_in_traced_code(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/p.py", """\
        import time
        import jax

        @jax.jit
        def step(x):
            t = time.time()
            print(x)
            return x * t
        """)
    msgs = [f.message for f in _unsuppressed(_run("jit-purity", ctx))]
    assert any("time." in m for m in msgs), msgs
    assert any("print" in m for m in msgs), msgs


def test_jit_purity_reaches_helpers_through_call_graph(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/p.py", """\
        import time
        import jax

        def helper(x):
            return x * time.time()

        @jax.jit
        def step(x):
            return helper(x)
        """)
    found = _unsuppressed(_run("jit-purity", ctx))
    assert any("helper" in f.message for f in found), found


def test_jit_purity_quiet_on_pure_traced_code(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/p.py", """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            y = jnp.tanh(x)
            if y.ndim > 1:            # shape branch: resolved at trace time
                y = y.sum(axis=-1)
            return y
        """)
    assert _unsuppressed(_run("jit-purity", ctx)) == []


def test_jit_purity_suppression(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/core/p.py", """\
        import jax

        @jax.jit
        def step(x):
            print(x)  # repro-lint: disable=jit-purity
            return x
        """)
    found = _run("jit-purity", ctx)
    assert found and all(f.suppressed for f in found)


# ---------------------------------------------------------------------------
# jit-purity: loop syncs
# ---------------------------------------------------------------------------

_LOOP = """\
    import jax
    import jax.numpy as jnp

    def run(n):
        out = []
        for i in range(n):
            v = jnp.sum(jnp.ones((3,)) * i)
            {line}
        return out
"""


def test_loop_sync_flags_per_iteration_float(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/runtime/loop.py",
               _LOOP.format(line="out.append(float(v))"))
    found = _unsuppressed(_run("jit-purity", ctx))
    assert any("loop body" in f.message for f in found), found


def test_loop_sync_exempts_log_guard(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/runtime/loop.py", _LOOP.format(
        line="out.append(float(v))\n"
             "        if i % 10 == 0:\n"
             "            out.append(float(v))"))
    # only the unguarded conversion (first line) fires, not the guarded one
    found = _unsuppressed(_run("jit-purity", ctx))
    assert len(found) == 1, found


def test_loop_sync_exempts_device_get_batches(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/runtime/loop.py", _LOOP.format(
        line="h = jax.device_get(v)\n        out.append(float(h))"))
    assert _unsuppressed(_run("jit-purity", ctx)) == []


def test_loop_sync_out_of_scope(tmp_path):
    # the same pattern in models/ is trace-time code, not a serving loop
    ctx = _ctx(tmp_path, "src/repro/models/loop.py",
               _LOOP.format(line="out.append(float(v))"))
    assert _unsuppressed(_run("jit-purity", ctx)) == []


# ---------------------------------------------------------------------------
# partition-coverage: AST half (out_axis declarations)
# ---------------------------------------------------------------------------

def _out_axis_findings(ctx):
    out = []
    for f in partition_coverage._check_out_axes([ctx]):
        f.suppressed = ctx.is_suppressed(f.rule, f.line)
        out.append(f)
    return out


def test_out_axis_missing_is_flagged(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/models/m.py", """\
        from repro.core.compressed_linear import LinearCompressionCfg
        cfg = LinearCompressionCfg(rank=4)
        """)
    found = _unsuppressed(_out_axis_findings(ctx))
    assert any("explicit out_axis" in f.message for f in found), found


def test_out_axis_unknown_name_is_flagged(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/models/m.py", """\
        from repro.core.compressed_linear import LinearCompressionCfg
        cfg = LinearCompressionCfg(rank=4, out_axis="bogus")
        """)
    found = _unsuppressed(_out_axis_findings(ctx))
    assert any("vocabulary" in f.message for f in found), found


def test_out_axis_clean_and_conditional(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/models/m.py", """\
        from repro.core.compressed_linear import LinearCompressionCfg
        a = LinearCompressionCfg(rank=4, out_axis="mlp")
        b = LinearCompressionCfg(rank=4, out_axis=None)
        c = LinearCompressionCfg(
            rank=4, out_axis="mlp" if True else None)  # test strings ignored
        """)
    assert _unsuppressed(_out_axis_findings(ctx)) == []


def test_out_axis_suppression(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/models/m.py", """\
        from repro.core.compressed_linear import LinearCompressionCfg
        cfg = LinearCompressionCfg(rank=4)  # repro-lint: disable=partition-coverage
        """)
    found = _out_axis_findings(ctx)
    assert found and all(f.suppressed for f in found)


# ---------------------------------------------------------------------------
# partition-coverage: import half (config x layout sweep, device-free)
# ---------------------------------------------------------------------------

def test_partition_matchers_extracted():
    import os
    matchers = partition_coverage._rule_matchers(
        os.path.join(REPO_ROOT, *partition_coverage.PARTITION.split("/")))
    names = set().union(*(names for _line, names in matchers))
    assert {"embed", "wq", "down"} <= names


def test_partition_coverage_sweep_all_configs_all_layouts():
    """Every registry config x {dp, fsdp, tp} resolves every >=2-d param to
    a rule (or the blessed replicated set) — via eval_shape on an
    AbstractMesh, so no device arrays are ever materialized."""
    findings = list(partition_coverage._check_coverage(REPO_ROOT))
    assert findings == [], [f.message for f in findings]


def test_partition_coverage_catches_unknown_leaf(tmp_path, monkeypatch):
    # shrink the blessed set: the bias leaves must resurface as findings
    monkeypatch.setattr(partition_coverage, "REPLICATED_OK", frozenset())
    findings = list(partition_coverage._check_coverage(REPO_ROOT))
    assert any("matches no _param_rule branch" in f.message
               for f in findings), "detector is blind to uncovered leaves"


# ---------------------------------------------------------------------------
# pallas-contract
# ---------------------------------------------------------------------------

_PALLAS = """\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def call(x):
        return pl.pallas_call(
            kernel,
            grid=(4, 4),
            in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 8), lambda {lam_args}: ({lam_body})),
            out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
        )(x)
"""


def test_pallas_contract_flags_index_map_arity(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS.format(lam_args="i", lam_body="i, 0"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("index_map takes 1 args" in f.message for f in found), found


def test_pallas_contract_flags_operand_count(tmp_path):
    src = _PALLAS.format(lam_args="i, j", lam_body="i, j").replace(
        "in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))]",
        "in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
        "                      pl.BlockSpec((8, 8), lambda i, j: (i, j))]")
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py", src)
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("2 in_specs" in f.message and "1 operands" in f.message
               for f in found), found


def test_pallas_contract_clean(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS.format(lam_args="i, j", lam_body="i, j"))
    assert _unsuppressed(_run("pallas-contract", ctx)) == []


def test_pallas_contract_dslice_stride(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py", """\
        from jax.experimental import pallas as pl

        def kernel(o_ref, *, bn):
            col = pl.dslice(3 * (bn + 1), bn)   # step != width
            o_ref[:, col] = 0.0
        """)
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("dslice" in f.message for f in found), found


_PALLAS_PREFETCH = """\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kernel(tbl_ref, pos_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def call(tbl, pos, x):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(4, 4),
            in_specs=[pl.BlockSpec((8, 8),
                                   lambda i, j, tbl, pos: (tbl[i, j], j))],
            out_specs=pl.BlockSpec((8, 8), lambda {lam_args}: ({lam_body})),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
        )({operands})
"""


def test_pallas_contract_prefetch_grid_spec_clean(tmp_path):
    """Scalar-prefetch geometry: index_maps take grid + prefetch args and
    the prefetch operands ride in front of the BlockSpec'd ones."""
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_PREFETCH.format(lam_args="i, j, tbl, pos",
                                       lam_body="i, j",
                                       operands="tbl, pos, x"))
    assert _unsuppressed(_run("pallas-contract", ctx)) == []


def test_pallas_contract_prefetch_flags_index_map_arity(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_PREFETCH.format(lam_args="i, j", lam_body="i, j",
                                       operands="tbl, pos, x"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("index_map takes 2 args" in f.message
               and "2 scalar-prefetch refs" in f.message
               for f in found), found


def test_pallas_contract_prefetch_flags_operand_count(tmp_path):
    # forgetting to pass the scalar operands ahead of the array ones
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_PREFETCH.format(lam_args="i, j, tbl, pos",
                                       lam_body="i, j", operands="x"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("1 in_specs" in f.message and "1 operands" in f.message
               for f in found), found


_PALLAS_ALIAS = """\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kernel(row_ref, pool_in_ref, blk_ref, o_ref):
        o_ref[...] = blk_ref[...]

    def call(row, pool, blocks):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                pl.BlockSpec((1, 8, 8), lambda j, row: (j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 8, 8), lambda j, row: (row[j], 0, 0)),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
            {alias_kw}
        )(row, pool, blocks)
"""


def test_pallas_alias_clean_when_declared(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_ALIAS.format(alias_kw="input_output_aliases={1: 0},"))
    assert _unsuppressed(_run("pallas-contract", ctx)) == []


def test_pallas_alias_missing_is_flagged(tmp_path):
    # out_shape reuses pool.shape but pool is never aliased: a full copy
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_ALIAS.format(alias_kw=""))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("input_output_aliases={1: 0}" in f.message
               for f in found), found


def test_pallas_alias_input_out_of_range(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_ALIAS.format(alias_kw="input_output_aliases={7: 0},"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("names input 7" in f.message and "3 operands" in f.message
               for f in found), found


def test_pallas_alias_output_out_of_range(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_ALIAS.format(
                   alias_kw="input_output_aliases={1: 3},"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("names output 3" in f.message for f in found), found


def test_pallas_alias_scalar_prefetch_is_flagged(tmp_path):
    # aliasing the scalar-prefetch row operand makes no sense
    ctx = _ctx(tmp_path, "src/repro/kernels/k.py",
               _PALLAS_ALIAS.format(alias_kw="input_output_aliases={0: 0},"))
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("scalar-prefetch operand" in f.message for f in found), found


def test_pallas_contract_cap_containment(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/models/z.py", """\
        from repro.kernels.dispatch import GRAD_SKETCH_MAX_N

        def fits(n):
            return n <= GRAD_SKETCH_MAX_N
        """)
    found = _unsuppressed(_run("pallas-contract", ctx))
    assert any("GRAD_SKETCH_MAX_N" in f.message for f in found), found


# ---------------------------------------------------------------------------
# shim-contract
# ---------------------------------------------------------------------------

_SHIM = """\
    import warnings
    {imp}

    def __getattr__(name):
        warnings.warn("moved", DeprecationWarning, stacklevel=2)
        {body}
"""


def test_shim_contract_flags_top_level_import(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/launch/s.py", _SHIM.format(
        imp="from repro import api", body="return getattr(api, name)"))
    found = _unsuppressed(_run("shim-contract", ctx))
    assert any("repro.api" in f.message for f in found), found


def test_shim_contract_clean_lazy_import(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/launch/s.py", _SHIM.format(
        imp="from repro.configs.registry import ARCHS",
        body="from repro import api\n        return getattr(api, name)"))
    assert _unsuppressed(_run("shim-contract", ctx)) == []


def test_shim_contract_ignores_non_shims(tmp_path):
    ctx = _ctx(tmp_path, "src/repro/launch/s.py",
               "from repro import api\n\n\ndef main():\n    return api\n")
    assert _run("shim-contract", ctx) == []


# ---------------------------------------------------------------------------
# whole-tree invariants and output formats
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def head_findings():
    return core.run_lint(root=REPO_ROOT)


def test_tree_is_clean_at_head(head_findings):
    bad = [f for f in head_findings if not f.suppressed]
    assert bad == [], "\n" + core.render_text(bad)


def test_suppressed_findings_keep_audit_trail(head_findings):
    # the blessed per-token baseline syncs stay visible in the report
    assert any(f.suppressed and f.rule == "jit-purity"
               for f in head_findings)


def test_json_schema(head_findings):
    doc = json.loads(core.render_json(head_findings, REPO_ROOT))
    assert doc["version"] == 2
    assert set(doc) == {"version", "root", "plane", "rules", "findings",
                        "counts", "total"}
    assert doc["plane"] == "ast"
    assert set(doc["rules"]) == {"residual-contract", "jit-purity",
                                 "partition-coverage", "pallas-contract",
                                 "shim-contract", "telemetry-contract"}
    graph_doc = json.loads(core.render_json([], REPO_ROOT, plane="graph"))
    assert set(graph_doc["rules"]) == {"residual-audit", "collectives-audit",
                                       "donation-audit", "recompile-audit"}
    for f in doc["findings"]:
        assert set(f) == {"rule", "path", "line", "message", "col",
                          "suppressed"}
        assert isinstance(f["line"], int) and f["line"] >= 0
    assert doc["total"] == sum(doc["counts"].values())
    assert doc["total"] == sum(1 for f in doc["findings"]
                               if not f["suppressed"])


def test_cli_select_and_exit_code(capsys):
    from repro.analysis.__main__ import main
    rc = main(["--format", "json", "--select", "shim-contract",
               "--root", REPO_ROOT])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0 and doc["total"] == 0


def test_cli_unknown_rule_errors():
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main(["--select", "no-such-rule", "--root", REPO_ROOT])


def test_parse_error_is_reported(tmp_path):
    bad = tmp_path / "src" / "repro" / "core"
    bad.mkdir(parents=True)
    (bad / "broken.py").write_text("def f(:\n")
    findings = core.run_lint(root=str(tmp_path), select=["jit-purity"])
    assert any(f.rule == "parse-error" for f in findings)
