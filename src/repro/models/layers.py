"""Shared building blocks: norms, embeddings, rotary embeddings, MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compressed_linear import (LinearCompressionCfg, asi_linear,
                                          dense_linear, hosvd_linear)
from repro.core.asi import MatrixASIState
from repro.parallel.sharding import logical_shard

Array = jax.Array


def initializer(key: Array, shape, dtype, scale: float = 0.02) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)  # repro-lint: disable=residual-audit — rsqrt vjp keeps the normalized x; norms are outside ASI's matmul sites
    return (x * scale.astype(jnp.float32)).astype(dt)  # repro-lint: disable=residual-audit — scale-mul vjp keeps x̂ (needed for d scale); inherent to any norm


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)  # repro-lint: disable=residual-audit — variance vjp keeps the centered x; inherent to layer norm
    x = (x - mu) * jax.lax.rsqrt(var + eps)  # repro-lint: disable=residual-audit — normalize vjp keeps (x - mu); inherent to layer norm
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)  # repro-lint: disable=residual-audit — affine vjp keeps x̂ (needed for d scale)


def norm_apply(params: dict, x: Array, cfg: ModelConfig) -> Array:
    if "bias" in params:
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps)


def norm_init(cfg: ModelConfig, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.use_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# --- rotary embeddings --------------------------------------------------------

def rope_tables(positions: Array, head_dim: int, theta: float):
    """cos/sin tables for given integer positions (any shape)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (..., S, H, hd) with cos/sin (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # cos/sin arrive as (..., S, half); add the head axis when needed
    c, s = cos, sin
    if c.ndim == x.ndim - 1:
        c, s = c[..., None, :], s[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --- MLP ----------------------------------------------------------------------

def mlp_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if cfg.act == "silu":       # SwiGLU
        p["gate"] = initializer(k1, (cfg.d_model, cfg.d_ff), dtype)
        p["up"] = initializer(k2, (cfg.d_model, cfg.d_ff), dtype)
    else:                        # GELU
        p["up"] = initializer(k2, (cfg.d_model, cfg.d_ff), dtype)
        if cfg.use_bias:
            p["up_b"] = jnp.zeros((cfg.d_ff,), dtype)
    p["down"] = initializer(k3, (cfg.d_ff, cfg.d_model), dtype)
    if cfg.use_bias:
        p["down_b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def mlp_apply(params: dict, x: Array, cfg: ModelConfig,
              asi_state: dict | None = None):
    """Returns (y, new_asi_state).  When ``asi_state`` is given the up/gate/
    down projections store ASI-compressed activations (paper §3.4)."""
    new_state = {}

    def lin(name, inp, w, b=None):
        # up/gate emit the TP-sharded d_ff ("mlp") dim; down emits the
        # replicated d_model dim (out_axis=None)
        ccfg = LinearCompressionCfg(rank=cfg.asi_rank,
                                    backend=cfg.kernel_backend,
                                    out_axis="mlp" if name != "down" else None)
        if asi_state is not None and name in asi_state:
            if cfg.compress == "hosvd":     # per-step SVD baseline
                new_state[name] = asi_state[name]
                return hosvd_linear(ccfg, inp, w, b)
            y, ns = asi_linear(ccfg, inp, w, b, asi_state[name])
            new_state[name] = ns
            return y
        return dense_linear(inp, w, b)

    if cfg.act == "silu":
        g = lin("gate", x, params["gate"])
        u = lin("up", x, params["up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u  # repro-lint: disable=residual-audit — gated-mul vjp keeps both gate branches; the adjacent matmuls are the ASI sites
    else:
        u = lin("up", x, params["up"], params.get("up_b"))
        h = jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(u.dtype)  # repro-lint: disable=residual-audit — gelu vjp keeps its pre-activation; nonlinearity, not a matmul site
    h = logical_shard(h, "batch", None, "mlp")
    y = lin("down", h, params["down"], params.get("down_b"))
    return y, (new_state if asi_state is not None else None)


def embed_init(key: Array, cfg: ModelConfig, dtype) -> Array:
    return initializer(key, (cfg.vocab_size, cfg.d_model), dtype, scale=1.0)


def unembed_init(key: Array, cfg: ModelConfig, dtype) -> Array:
    return initializer(key, (cfg.d_model, cfg.vocab_size), dtype)
