"""residual-contract: custom_vjp forwards must not save dense activations.

The paper's activation-memory reduction exists only if the residual tuple a
``custom_vjp`` fwd returns carries the sketched factors (P̂, Q / Tucker
core+factors) and *never* the full-width activation X.  The rule runs a
name/shape-provenance (taint) analysis over each fwd body in ``core/``,
``models/`` and ``kernels/``:

* taint seeds: the fwd's differentiable activation-like parameters (anything
  not named like a weight/bias/state/config);
* taint propagates through shape-preserving ops (reshape / astype /
  transpose / ``.T`` / elementwise arithmetic / slicing) and through calls
  to *local* helpers (inlined one level, memoized);
* taint is severed by contractions (``@``, ``jnp.dot``, ``einsum``,
  ``dispatch.*``), decompositions (``svd``, ``orthonormalize``,
  ``tucker_asi_step``) and any other imported call — their outputs are
  rank-reduced or otherwise not the dense activation;
* a tainted element inside the returned residual tuple is a finding.

It also checks the registration arithmetic: fwd must mirror the primal's
signature and return a 2-tuple, and bwd must return one cotangent per
differentiable primal argument.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import (Finding, FileContext, call_name, const_int,
                                 dotted_name, rule)

SCOPES = ("src/repro/core/", "src/repro/models/", "src/repro/kernels/")

# fwd parameters that are not activations (weights/state/config/randomness)
_NON_ACTIVATION = re.compile(
    r"^(w|b|weight|bias|state|params?|cfg|config|key|rng|.*_state|.*_cfg)$")

# shape-preserving methods: receiver taint flows to the result
_PROPAGATE_METHODS = {"reshape", "astype", "transpose", "swapaxes",
                      "moveaxis", "ravel", "flatten", "squeeze", "copy"}
# shape-preserving free functions (taint = OR of argument taints)
_PROPAGATE_FUNCS = {
    "jnp.reshape", "jnp.transpose", "jnp.swapaxes", "jnp.moveaxis",
    "jnp.asarray", "jnp.pad", "jnp.expand_dims", "jnp.squeeze", "jnp.flip",
    "jnp.roll", "jnp.concatenate", "jnp.stack", "jnp.split", "jnp.where",
    "jnp.broadcast_to", "jax.numpy.reshape", "tuple", "list",
}
_SHAPE_ATTRS = {"shape", "dtype", "ndim", "size", "at"}
_MAX_INLINE_DEPTH = 3


def _collect_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    fns: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            fns.setdefault(node.name, node)
    return fns


def _decorator_custom_vjp(fn: ast.FunctionDef):
    """Return the nondiff_argnums tuple if ``fn`` is a custom_vjp primal."""
    for dec in fn.decorator_list:
        name = dotted_name(dec)
        if name in ("jax.custom_vjp", "custom_vjp"):
            return ()
        if isinstance(dec, ast.Call) and call_name(dec) in (
                "partial", "functools.partial"):
            if dec.args and dotted_name(dec.args[0]) in (
                    "jax.custom_vjp", "custom_vjp"):
                for kw in dec.keywords:
                    if kw.arg == "nondiff_argnums" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        vals = tuple(const_int(e) for e in kw.value.elts)
                        if all(v is not None for v in vals):
                            return vals
                return ()
    return None


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args]


def _own_returns(fn: ast.FunctionDef) -> list[ast.Return]:
    """Return statements belonging to ``fn`` itself (not nested defs)."""
    out: list[ast.Return] = []
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Return):
            out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _any(t) -> bool:
    return any(_any(x) for x in t) if isinstance(t, tuple) else bool(t)


class _Taint:
    """Flow-insensitive-per-branch, order-sensitive taint evaluator."""

    def __init__(self, fns: dict[str, ast.FunctionDef]):
        self.fns = fns
        self._memo: dict = {}

    # -- function-level -----------------------------------------------------

    def run(self, fn: ast.FunctionDef, arg_taints: dict[str, bool],
            depth: int = 0):
        """Execute ``fn`` and return (env, return_taint)."""
        env: dict[str, object] = dict(arg_taints)
        ret = self._exec(fn.body, env, depth)
        return env, ret

    def call_fn(self, name: str, arg_taints: list, kw_taints: dict,
                depth: int) -> object:
        fn = self.fns.get(name)
        if fn is None or depth >= _MAX_INLINE_DEPTH:
            return False
        params = _param_names(fn)
        key = (name, tuple(bool(_any(t)) for t in arg_taints),
               tuple(sorted((k, bool(_any(v)))
                            for k, v in kw_taints.items())))
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = False           # cycle guard
        env = {p: False for p in params}
        for p, t in zip(params, arg_taints):
            env[p] = t
        env.update({k: v for k, v in kw_taints.items() if k in env})
        ret = self._exec(fn.body, env, depth + 1)
        self._memo[key] = ret
        return ret

    def _exec(self, body: list, env: dict, depth: int) -> object:
        ret: object = False
        for stmt in body:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                self._assign(stmt, env, depth)
            elif isinstance(stmt, ast.Return):
                if stmt.value is not None:
                    ret = self.eval(stmt.value, env, depth)
            elif isinstance(stmt, ast.If):
                e1, e2 = dict(env), dict(env)
                r1 = self._exec(stmt.body, e1, depth)
                r2 = self._exec(stmt.orelse, e2, depth)
                for k in set(e1) | set(e2):
                    env[k] = self._merge(e1.get(k, False), e2.get(k, False))
                ret = self._merge(ret, self._merge(r1, r2))
            elif isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    self._bind(stmt.target,
                               self.eval(stmt.iter, env, depth), env)
                r = self._exec(stmt.body + stmt.orelse, env, depth)
                ret = self._merge(ret, r)
            elif isinstance(stmt, (ast.With,)):
                r = self._exec(stmt.body, env, depth)
                ret = self._merge(ret, r)
            elif isinstance(stmt, ast.Try):
                r = self._exec(stmt.body + stmt.finalbody, env, depth)
                ret = self._merge(ret, r)
            # Expr / FunctionDef / Assert / Raise: no bindings we track
        return ret

    def _merge(self, a, b):
        if isinstance(a, tuple) and isinstance(b, tuple) and len(a) == len(b):
            return tuple(self._merge(x, y) for x, y in zip(a, b))
        return a if _any(a) else b

    def _assign(self, stmt, env: dict, depth: int):
        if isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name):
                cur = env.get(stmt.target.id, False)
                if isinstance(stmt.op, ast.MatMult):
                    env[stmt.target.id] = False
                else:
                    env[stmt.target.id] = _any(cur) or _any(
                        self.eval(stmt.value, env, depth))
            return
        value = stmt.value
        if value is None:
            return
        taint = self.eval(value, env, depth)
        targets = stmt.targets if isinstance(stmt, ast.Assign) else [
            stmt.target]
        for tgt in targets:
            self._bind(tgt, taint, env)

    def _bind(self, tgt, taint, env: dict):
        if isinstance(tgt, ast.Name):
            env[tgt.id] = taint
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            if isinstance(taint, tuple) and len(taint) == len(tgt.elts):
                for e, t in zip(tgt.elts, taint):
                    self._bind(e, t, env)
            else:
                for e in tgt.elts:
                    self._bind(e, _any(taint), env)
        # attribute/subscript targets: not tracked

    # -- expressions --------------------------------------------------------

    def eval(self, node: ast.AST, env: dict, depth: int) -> object:
        if isinstance(node, ast.Name):
            return env.get(node.id, False)
        if isinstance(node, (ast.Tuple, ast.List)):
            return tuple(self.eval(e, env, depth) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.eval(node.value, env, depth)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False
            return _any(self.eval(node.value, env, depth))
        if isinstance(node, ast.Subscript):
            return _any(self.eval(node.value, env, depth))
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.MatMult):
                return False
            return _any(self.eval(node.left, env, depth)) or _any(
                self.eval(node.right, env, depth))
        if isinstance(node, ast.UnaryOp):
            return _any(self.eval(node.operand, env, depth))
        if isinstance(node, ast.IfExp):
            return self._merge(self.eval(node.body, env, depth),
                               self.eval(node.orelse, env, depth))
        if isinstance(node, (ast.Compare, ast.BoolOp)):
            return False
        if isinstance(node, ast.Call):
            return self._call(node, env, depth)
        return False

    def _call(self, node: ast.Call, env: dict, depth: int) -> object:
        arg_taints = [self.eval(a, env, depth) for a in node.args]
        kw_taints = {kw.arg: self.eval(kw.value, env, depth)
                     for kw in node.keywords if kw.arg}
        name = call_name(node)
        # transform wrappers applied inline:  jax.vmap(local)(x, p)
        if isinstance(node.func, ast.Call):
            inner = node.func
            iname = call_name(inner)
            if iname in ("jax.vmap", "vmap", "jax.checkpoint", "jax.remat",
                         "partial", "functools.partial") and inner.args:
                target = dotted_name(inner.args[0])
                if target in self.fns:
                    pre = [self.eval(a, env, depth) for a in inner.args[1:]]
                    return self.call_fn(target, pre + arg_taints, kw_taints,
                                        depth)
            return False
        if name is None:
            return False
        if name in self.fns:                      # local helper: inline
            return self.call_fn(name, arg_taints, kw_taints, depth)
        if name in _PROPAGATE_FUNCS:
            merged = False
            for t in arg_taints + list(kw_taints.values()):
                merged = merged or _any(t)
            return merged
        # method call on an expression: x.reshape(...) etc.
        if isinstance(node.func, ast.Attribute):
            recv = node.func.value
            if node.func.attr in _PROPAGATE_METHODS:
                return _any(self.eval(recv, env, depth))
        # anything else (contractions, decompositions, imported code) severs
        return False


def _src(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:                                       # pragma: no cover
        return "<expr>"


@rule("residual-contract",
      doc="custom_vjp residuals must be sketched factors, never dense "
          "activations; fwd/bwd arities must match the primal")
def check_residuals(ctx: FileContext):
    if not any(ctx.rel.startswith(s) for s in SCOPES):
        return
    fns = _collect_functions(ctx.tree)
    primals: dict[str, tuple] = {}
    for fn in fns.values():
        nondiff = _decorator_custom_vjp(fn)
        if nondiff is not None:
            primals[fn.name] = nondiff

    registrations = []           # (primal_name, fwd_name, bwd_name, lineno)
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "defvjp" and len(node.args) >= 2):
            primal = dotted_name(node.func.value)
            fwd, bwd = dotted_name(node.args[0]), dotted_name(node.args[1])
            registrations.append((primal, fwd, bwd, node.lineno))

    registered = {r[0] for r in registrations}
    for pname, fn in ((n, fns[n]) for n in primals if n in fns):
        if pname not in registered:
            yield Finding("residual-contract", ctx.rel, fn.lineno,
                          f"custom_vjp primal {pname!r} has no defvjp "
                          "registration in this module")

    taint = _Taint(fns)
    for primal, fwd_name, bwd_name, lineno in registrations:
        if primal not in primals:
            continue
        nondiff = primals[primal]
        pparams = _param_names(fns[primal])
        n_diff = len(pparams) - len(nondiff)
        fwd, bwd = fns.get(fwd_name), fns.get(bwd_name)
        if fwd is None or bwd is None:
            continue

        # --- arity contracts ---------------------------------------------
        fparams = _param_names(fwd)
        if len(fparams) != len(pparams):
            yield Finding("residual-contract", ctx.rel, fwd.lineno,
                          f"{fwd_name} takes {len(fparams)} args but primal "
                          f"{primal} takes {len(pparams)} — fwd must mirror "
                          "the primal signature")
        bparams = _param_names(bwd)
        if len(bparams) != len(nondiff) + 2:
            yield Finding("residual-contract", ctx.rel, bwd.lineno,
                          f"{bwd_name} takes {len(bparams)} args; expected "
                          f"{len(nondiff) + 2} (nondiff args + residuals + "
                          "cotangents)")
        for ret in _own_returns(bwd):
            if isinstance(ret.value, ast.Tuple) and \
                    len(ret.value.elts) != n_diff:
                yield Finding(
                    "residual-contract", ctx.rel, ret.lineno,
                    f"{bwd_name} returns {len(ret.value.elts)} cotangents "
                    f"but primal {primal} has {n_diff} differentiable args")

        # --- dense-residual taint ------------------------------------------
        seeds = {p: bool(i not in nondiff
                         and not _NON_ACTIVATION.match(p))
                 for i, p in enumerate(fparams)}
        env, _ = taint.run(fwd, seeds)
        for ret in _own_returns(fwd):
            if not isinstance(ret.value, ast.Tuple):
                continue
            if len(ret.value.elts) != 2:
                yield Finding(
                    "residual-contract", ctx.rel, ret.lineno,
                    f"{fwd_name} must return (output, residuals) — got a "
                    f"{len(ret.value.elts)}-tuple")
                continue
            res_node = ret.value.elts[1]
            res_taint = taint.eval(res_node, env, 0)
            # resolve a bare name to its element structure for reporting
            if isinstance(res_node, ast.Name):
                for stmt in ast.walk(fwd):
                    if (isinstance(stmt, ast.Assign)
                            and any(isinstance(t, ast.Name)
                                    and t.id == res_node.id
                                    for t in stmt.targets)
                            and isinstance(stmt.value, ast.Tuple)):
                        res_node = stmt.value
                        break
            if isinstance(res_taint, tuple) and isinstance(res_node,
                                                           ast.Tuple):
                for i, (el, t) in enumerate(zip(res_node.elts, res_taint)):
                    if _any(t):
                        # anchor at the element so a suppression sits next
                        # to the tuple that saves it, not the return
                        yield Finding(
                            "residual-contract", ctx.rel, el.lineno,
                            f"{fwd_name} residual element {i} "
                            f"({_src(el)}) carries a full-width activation "
                            "— save sketched factors (P̂, Q) instead")
            elif _any(res_taint):
                yield Finding(
                    "residual-contract", ctx.rel, res_node.lineno,
                    f"{fwd_name} residuals ({_src(res_node)}) carry a "
                    "full-width activation — save sketched factors instead")
