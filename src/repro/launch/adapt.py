"""On-device adaptation launcher — a thin argparse shim over ``repro.api``.

The paper's deployment loop as one command — ledger feasibility, §3.3
calibration + budget search, then train-while-serve from a replay buffer of
retired requests:

  PYTHONPATH=src python -m repro.launch.adapt --arch tinyllama-1.1b \
      --reduced --mem-budget-mb 0.05 --steps 10 --adapt-every 2 \
      --requests 8 --max-new 8

Output is JSON lines: the analytical ledger, the plan (per-layer ε/rank
under ``--mem-budget-mb``), then serving and adaptation counters; the
adapted weights are checkpointed with session provenance.  All wiring lives
in ``repro.api.Session.adapter``; embed that instead of calling ``main()``
programmatically (which is deprecated).
"""
from __future__ import annotations

import argparse
import json

from repro import api


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        epilog="Full flag matrix: README.md; subsystem design: DESIGN.md §8")
    api.add_arch_argument(ap)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-sized config (--no-reduced = full arch)")
    ap.add_argument("--mem-budget-mb", type=float, required=True,
                    help="activation-memory budget for the fine-tuned tail; "
                         "the planner chooses per-layer ranks under it")
    ap.add_argument("--steps", type=int, default=10,
                    help="total adaptation steps for the session")
    ap.add_argument("--adapt-every", type=int, default=4,
                    help="retired requests per adaptation burst")
    ap.add_argument("--burst-steps", type=int, default=1,
                    help="train steps per burst")
    ap.add_argument("--replay-size", type=int, default=64,
                    help="replay-buffer capacity (retired token streams)")
    ap.add_argument("--batch", type=int, default=2,
                    help="adaptation batch size (fixed shape, no recompiles)")
    ap.add_argument("--seq-len", type=int, default=32,
                    help="adaptation sequence length (fixed shape)")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="calibration batches for the §3.3 perplexity table")
    ap.add_argument("--rank-select", default="knapsack",
                    choices=("knapsack", "backtracking"),
                    help="budget search: quantized DP or paper backtracking")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--kernel-backend", default="auto",
                    choices=("auto", "pallas", "reference"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_adapt_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    api.add_telemetry_arguments(ap)
    return ap


def main(argv=None):
    api.warn_programmatic_use(__name__, argv)
    args = build_parser().parse_args(argv)
    with api.telemetry_recorder(args) as rec:
        sess = api.Session.from_config(args.arch, reduced=args.reduced,
                                       seed=args.seed, compress="asi",
                                       kernel_backend=args.kernel_backend,
                                       telemetry=rec)
        if sess.cfg.family == "encdec":
            raise SystemExit("encdec serving needs audio frames; on-device "
                             "adaptation currently targets decoder-only "
                             "archs")
        adapter = sess.adapter(
            mem_budget_mb=args.mem_budget_mb, steps=args.steps,
            adapt_every=args.adapt_every, burst_steps=args.burst_steps,
            replay_size=args.replay_size, batch=args.batch,
            seq_len=args.seq_len, calib_batches=args.calib_batches,
            rank_select=args.rank_select, lr=args.lr,
            max_batch=args.max_batch, max_len=args.max_len,
            temperature=args.temperature)
        print(json.dumps(adapter.ledger_report()))
        print(json.dumps(adapter.plan_report()))
        if not adapter.plan_respects_budget:
            raise SystemExit("planner produced a plan the ledger prices over "
                             "budget — this is a bug, not a user error")
        adapter.device_session()              # wires ASI ranks + optimizer
        if sess.optimizer_substitution is not None:
            print(json.dumps(
                {"optimizer_substitution": sess.optimizer_substitution}))
        report = adapter.run(api.demo_requests(args.requests, args.max_new))
        s = report.serve_stats
        print(json.dumps({"serving": {
            "requests": s.requests, "generated_tokens": s.generated_tokens,
            "decode_steps": s.decode_steps,
            "tokens_per_s": round(s.tokens_per_s, 1),
            "ttft_mean_s": round(s.ttft_mean_s, 4)}}))
        print(json.dumps({"adaptation": report.summary()}))
        sess.save(args.ckpt_dir, meta={"plan": adapter.plan.summary()})
        print(json.dumps({"ckpt_dir": args.ckpt_dir,
                          "ckpt_step": report.steps}))
    return report


if __name__ == "__main__":
    main()
