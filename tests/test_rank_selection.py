"""Rank-selection tests: perplexity estimation + budget search (paper §3.3)."""
import itertools

import numpy as np
import pytest

from repro.core.rank_selection import (LayerCalibration, apply_selection,
                                       estimate_perplexity,
                                       select_ranks_backtracking,
                                       select_ranks_knapsack)

RNG = np.random.default_rng(0)


def _calib_layers(n=4, lowrank=True):
    layers = []
    for i in range(n):
        if lowrank:
            a = (RNG.normal(size=(48, 5)) @ RNG.normal(size=(5, 32))
                 ).astype(np.float32).reshape(8, 6, 32)
            a += 0.05 * RNG.normal(size=a.shape).astype(np.float32)
        else:
            a = RNG.normal(size=(8, 6, 32)).astype(np.float32)
        g = RNG.normal(size=(8, 6, 16)).astype(np.float32)
        layers.append(LayerCalibration(name=f"l{i}", activation=a, grad_out=g))
    return layers


def test_perplexity_decreases_with_eps():
    """Paper Fig. 6: higher explained variance -> lower gradient perplexity."""
    t = estimate_perplexity(_calib_layers(), (0.5, 0.7, 0.9, 0.99))
    for row in t.perplexity:
        assert row[0] >= row[-1]
        assert all(np.diff(row) <= 1e-6)


def test_memory_increases_with_eps():
    t = estimate_perplexity(_calib_layers(), (0.5, 0.7, 0.9, 0.99))
    for row in t.memory:
        assert all(np.diff(row) >= 0)


def test_backtracking_is_optimal_vs_bruteforce():
    t = estimate_perplexity(_calib_layers(3), (0.5, 0.7, 0.9, 0.99))
    budget = float(np.sort(t.memory, axis=1)[:, 2].sum())
    best = select_ranks_backtracking(t.perplexity, t.memory, budget)
    # exhaustive check
    best_p = np.inf
    for combo in itertools.product(range(4), repeat=3):
        mem = sum(t.memory[i, j] for i, j in enumerate(combo))
        if mem <= budget:
            p = sum(t.perplexity[i, j] for i, j in enumerate(combo))
            best_p = min(best_p, p)
    got = sum(t.perplexity[i, j] for i, j in enumerate(best))
    assert abs(got - best_p) < 1e-9


def test_knapsack_feasible_and_near_optimal():
    t = estimate_perplexity(_calib_layers(4), (0.5, 0.7, 0.9, 0.99))
    budget = float(np.sort(t.memory, axis=1)[:, 2].sum())
    bt = select_ranks_backtracking(t.perplexity, t.memory, budget)
    ks = select_ranks_knapsack(t.perplexity, t.memory, budget)
    mem_ks = sum(t.memory[i, j] for i, j in enumerate(ks))
    assert mem_ks <= budget            # quantization is conservative
    p_bt = sum(t.perplexity[i, j] for i, j in enumerate(bt))
    p_ks = sum(t.perplexity[i, j] for i, j in enumerate(ks))
    assert p_ks <= p_bt * 1.25 + 1e-6  # near-optimal under quantization


def test_infeasible_budget_raises():
    t = estimate_perplexity(_calib_layers(2), (0.5, 0.9))
    with pytest.raises(ValueError):
        select_ranks_backtracking(t.perplexity, t.memory,
                                  float(t.memory.min(1).sum()) - 1)


def test_backtracking_knapsack_agree_on_shared_instances():
    """DP vs exact backtracking on shared instances across a budget sweep:
    the DP is always feasible, never beats the exact optimum, and is no
    worse than the exact optimum of the budget shrunk by the quantization
    slack (ceil rounds each of the n items up by at most one bin)."""
    t = estimate_perplexity(_calib_layers(5), (0.4, 0.6, 0.8, 0.95))
    lo = float(t.memory.min(1).sum())
    hi = float(t.memory.max(1).sum())
    n, n_bins = t.memory.shape[0], 1 << 15
    for frac in (0.05, 0.25, 0.5, 0.75, 1.0):
        budget = lo + frac * (hi - lo)
        bt = select_ranks_backtracking(t.perplexity, t.memory, budget)
        ks = select_ranks_knapsack(t.perplexity, t.memory, budget,
                                   n_bins=n_bins)
        p_bt = sum(t.perplexity[i, j] for i, j in enumerate(bt))
        p_ks = sum(t.perplexity[i, j] for i, j in enumerate(ks))
        m_ks = sum(t.memory[i, j] for i, j in enumerate(ks))
        assert m_ks <= budget                     # conservative quantization
        assert p_ks >= p_bt - 1e-9                # exact is optimal
        slack = n * budget / n_bins
        shrunk = select_ranks_backtracking(t.perplexity, t.memory,
                                           budget - slack)
        p_shrunk = sum(t.perplexity[i, j] for i, j in enumerate(shrunk))
        assert p_ks <= p_shrunk + 1e-9, (frac, p_ks, p_shrunk)


def test_zero_budget_raises_for_both():
    t = estimate_perplexity(_calib_layers(2), (0.5, 0.9))
    with pytest.raises(ValueError):
        select_ranks_backtracking(t.perplexity, t.memory, 0.0)
    with pytest.raises(ValueError):
        select_ranks_knapsack(t.perplexity, t.memory, 0.0)


def test_infeasibly_tight_budget_raises_for_knapsack():
    t = estimate_perplexity(_calib_layers(3), (0.5, 0.9))
    tight = float(t.memory.min(1).sum()) - 1
    with pytest.raises(ValueError):
        select_ranks_knapsack(t.perplexity, t.memory, tight)


def test_apply_selection_structure():
    t = estimate_perplexity(_calib_layers(2), (0.5, 0.9))
    budget = float(t.memory[:, 1].sum())
    sel = apply_selection(t, select_ranks_backtracking(
        t.perplexity, t.memory, budget))
    assert set(sel) == {"l0", "l1"}
    for v in sel.values():
        assert v["ranks"] and v["memory_elems"] > 0


def test_conv_calibration_path():
    """4-mode HOSVD perplexity on a conv layer (weight_grad_fn route)."""
    import jax
    import jax.numpy as jnp
    from repro.core.compressed_conv import conv2d

    a = RNG.normal(size=(4, 3, 8, 8)).astype(np.float32)
    g = RNG.normal(size=(4, 5, 8, 8)).astype(np.float32)

    def wgrad(a_, g_):
        f = lambda w: conv2d(jnp.asarray(a_), w)
        _, vjp = jax.vjp(f, jnp.zeros((5, 3, 3, 3)))
        return np.asarray(vjp(jnp.asarray(g_))[0])

    layers = [LayerCalibration(name="c0", activation=a, grad_out=g,
                               kind="conv", weight_grad_fn=wgrad)]
    t = estimate_perplexity(layers, (0.5, 0.9))
    assert t.perplexity[0, 0] >= t.perplexity[0, 1] - 1e-5
    assert (t.ranks[0, 0, :4] > 0).all()
