"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.jsonl.

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS, the useful-compute ratio, and a one-line lever.

Term sources (see EXPERIMENTS.md §Methodology):
  compute    = analytic executed FLOPs (flops_model) / (chips x 197e12)
  memory     = analytic fused HBM bytes (flops_model) / (chips x 819e9)
               [HLO bytes-accessed reported as the unfused upper bound]
  collective = per-device collective operand bytes from partitioned HLO / 50e9
"""
from __future__ import annotations

import json
import sys

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch import flops_model
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

LEVERS = {
    ("compute", "train"): "raise per-chip batch / cut remat recompute",
    ("compute", "prefill"): "causal block-skip in attention (flash kernel)",
    ("compute", "decode"): "batch more sequences per step",
    ("memory", "train"): "cut saved-activation traffic (ASI compression / "
                         "remat policy)",
    ("memory", "prefill"): "fuse projections; keep KV writes streaming",
    ("memory", "decode"): "weights dominate: quantize or batch more tokens "
                          "per weight read",
    ("collective", "train"): "compress DP gradient all-reduce (PowerSGD/ASI)"
                             "; overlap with bwd",
    ("collective", "prefill"): "shard KV heads not seq; all-gather once",
    ("collective", "decode"): "keep TP collectives in bf16; widen model axis"
                              " only to HBM need",
}


def enrich(row: dict) -> dict:
    cfg = get_config(row["arch"])
    compress = row.get("compress", "none")
    if compress != "none":
        cfg = cfg.replace(compress=compress)
    if row.get("remat"):
        cfg = cfg.replace(remat=row["remat"])
    if row.get("param_dtype"):
        cfg = cfg.replace(param_dtype=row["param_dtype"])
    if row.get("kv_cache_dtype"):
        cfg = cfg.replace(kv_cache_dtype=row["kv_cache_dtype"])
    shape = SHAPES[row["shape"]]
    chips = row["n_devices"]
    # recompute analytic terms with the CURRENT cost model (stored values may
    # predate model fixes); collectives stay as parsed from the HLO.
    mem_bytes = flops_model.cell_hbm_bytes(cfg, shape, compress)
    row["an_mem_s"] = mem_bytes / chips / HBM_BW
    row["an_compute_s"] = flops_model.cell_flops(cfg, shape, compress) \
        / chips / PEAK_FLOPS
    row["useful_ratio"] = row["model_flops"] / (
        row["an_compute_s"] * chips * PEAK_FLOPS)
    coll_s = row["collective_s"]
    if not row.get("unroll", True):
        # rolled layer scan: per-layer collectives counted once -> scale by
        # the period count (approximation, noted in §Methodology)
        from repro.launch.flops_model import period_pattern
        n_p = cfg.n_layers // len(period_pattern(cfg))
        coll_s *= n_p
        row["coll_scaled_by"] = n_p
    row["coll_s"] = coll_s
    terms = {"compute": row["an_compute_s"], "memory": row["an_mem_s"],
             "collective": row["coll_s"]}
    row["dominant2"] = max(terms, key=terms.get)
    bound = max(terms.values())
    t_useful = row["model_flops"] / (chips * PEAK_FLOPS)
    row["roofline_frac"] = t_useful / bound if bound else 0.0
    row["lever"] = LEVERS.get((row["dominant2"], shape.kind), "-")
    return row


def load(path="results/dryrun.jsonl"):
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (d["arch"], d["shape"], bool(d.get("multi_pod")),
                   d.get("compress", "none"), d.get("remat") or "full",
                   bool(d.get("fsdp")))
            rows[key] = d                     # last write wins (reruns)
    return rows


def table(path="results/dryrun.jsonl", multi_pod=False, compress="none",
          out=sys.stdout):
    rows = load(path)
    hdr = ("| arch | shape | comp(s) | mem(s) | coll(s) | dominant | "
           "useful | roofline | lever |")
    print(hdr, file=out)
    print("|" + "---|" * 9, file=out)
    for (arch, shape, mp, comp, remat, fsdp), d in sorted(rows.items()):
        if mp != multi_pod or comp != compress:
            continue
        if d.get("status") == "skipped":
            print(f"| {arch} | {shape} | - | - | - | skipped "
                  f"(sub-quadratic n/a) | - | - | - |", file=out)
            continue
        if d.get("status") != "ok":
            print(f"| {arch} | {shape} | - | - | - | {d.get('status')} | - |"
                  f" - | - |", file=out)
            continue
        e = enrich(dict(d))
        print(f"| {arch} | {shape} | {e['an_compute_s']:.2e} | "
              f"{e['an_mem_s']:.2e} | {e['coll_s']:.2e} | {e['dominant2']} | "
              f"{e['useful_ratio']:.2f} | {e['roofline_frac']:.3f} | "
              f"{e['lever']} |", file=out)


def dryrun_table(path="results/dryrun.jsonl", out=sys.stdout):
    rows = load(path)
    print("| arch | shape | mesh | status | compile(s) | args GB/dev | "
          "temp GB/dev | coll GB/dev | coll ops |", file=out)
    print("|" + "---|" * 9, file=out)
    for (arch, shape, mp, comp, remat, fsdp), d in sorted(rows.items()):
        if comp != "none":
            continue
        mesh = "2x16x16" if mp else "16x16"
        if d.get("status") != "ok":
            print(f"| {arch} | {shape} | {mesh} | {d.get('status')} | - | - |"
                  f" - | - | - |", file=out)
            continue
        mem = d.get("memory", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        print(f"| {arch} | {shape} | {mesh} | ok | {d.get('t_compile_s')} | "
              f"{args_gb:.2f} | {temp_gb:.2f} | "
              f"{d['collective_bytes_per_device']/1e9:.2f} | "
              f"{d['collective_ops']} |", file=out)


if __name__ == "__main__":
    print("## Dry-run (single-pod)")
    dryrun_table()
    print("\n## Roofline single-pod")
    table(multi_pod=False)
    print("\n## Roofline multi-pod")
    table(multi_pod=True)
