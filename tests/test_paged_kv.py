"""Block-table mechanics in isolation (no model): allocation/growth/free,
reuse after retirement, the fragmentation bound, admission back-pressure on
pool exhaustion, and partial-block masking in the paged-attention kernel
against a hand-rolled dense softmax on the raw arrays."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.kernels.paged_attention import (paged_attention,  # noqa: E402
                                           paged_attention_ref)
from repro.runtime.paged_kv import TRASH_BLOCK, PagedKVManager  # noqa: E402


# --- constructor contracts --------------------------------------------------

def test_rejects_bad_geometry():
    with pytest.raises(ValueError, match="block_size"):
        PagedKVManager(8, 0, 2, 16)
    with pytest.raises(ValueError, match="divide"):
        PagedKVManager(8, 5, 2, 16)
    with pytest.raises(ValueError, match="trash"):
        PagedKVManager(1, 4, 2, 16)


def test_fresh_table_points_at_trash():
    mgr = PagedKVManager(9, 4, 2, 16)
    assert TRASH_BLOCK == 0
    assert (mgr.table == TRASH_BLOCK).all()
    assert mgr.free_blocks == 8 and mgr.used_blocks == 0


# --- allocate / append / free ----------------------------------------------

def test_admit_allocates_covering_blocks():
    mgr = PagedKVManager(9, 4, 2, 16)
    assert mgr.blocks_for(1) == 1 and mgr.blocks_for(4) == 1
    assert mgr.blocks_for(5) == 2
    assert mgr.admit(0, 6)
    assert len(mgr.owned_blocks(0)) == 2
    # the table row maps logical -> physical, rest stays trash
    assert list(mgr.table[0, :2]) == mgr.owned_blocks(0)
    assert (mgr.table[0, 2:] == TRASH_BLOCK).all()
    assert TRASH_BLOCK not in mgr.owned_blocks(0)
    assert mgr.used_blocks == 2 and mgr.peak_used_blocks == 2


def test_ensure_grows_one_block_at_a_time():
    mgr = PagedKVManager(9, 4, 2, 16)
    assert mgr.admit(0, 3)
    assert mgr.ensure(0, 3)                   # position 3 in block 0: no-op
    assert len(mgr.owned_blocks(0)) == 1
    assert mgr.ensure(0, 4)                   # crosses into block 1
    assert len(mgr.owned_blocks(0)) == 2
    with pytest.raises(ValueError, match="beyond max_len"):
        mgr.ensure(0, 16)


def test_release_returns_blocks_and_resets_row():
    mgr = PagedKVManager(9, 4, 2, 16)
    mgr.admit(0, 10)
    owned = mgr.owned_blocks(0)
    freed = mgr.release(0)
    assert freed == owned
    assert (mgr.table[0] == TRASH_BLOCK).all()
    assert mgr.free_blocks == 8
    # double free is a bug, not back-pressure
    mgr._free.extend(freed)
    with pytest.raises(AssertionError, match="double free"):
        mgr.release(0)


def test_double_admit_raises():
    mgr = PagedKVManager(9, 4, 2, 16)
    mgr.admit(0, 4)
    with pytest.raises(ValueError, match="already owns"):
        mgr.admit(0, 4)


def test_blocks_reused_after_retirement():
    """LIFO free list: a retired slot's blocks are handed to the very next
    admission."""
    mgr = PagedKVManager(9, 4, 2, 16)
    mgr.admit(0, 8)
    freed = mgr.release(0)
    mgr.admit(1, 8)
    assert mgr.owned_blocks(1) == freed[::-1]


def test_fragmentation_bounded_by_block_size():
    mgr = PagedKVManager(33, 4, 4, 32)
    for used in range(1, 33):
        mgr.admit(2, used)
        waste = mgr.internal_fragmentation(2, used)
        assert 0 <= waste <= mgr.block_size - 1, (used, waste)
        mgr.release(2)


# --- exhaustion back-pressure ----------------------------------------------

def test_admission_backpressure_allocates_nothing():
    mgr = PagedKVManager(5, 4, 2, 16)          # 4 usable blocks
    assert mgr.admit(0, 12)                    # takes 3
    assert not mgr.can_admit(8)
    assert mgr.admit(1, 8) is False            # needs 2, only 1 free
    assert mgr.owned_blocks(1) == []           # atomic: nothing allocated
    assert mgr.free_blocks == 1
    mgr.release(0)
    assert mgr.admit(1, 8)                     # retirement unblocks it


def test_ensure_exhaustion_returns_false():
    mgr = PagedKVManager(3, 4, 2, 16)          # 2 usable blocks
    mgr.admit(0, 4)
    mgr.admit(1, 4)
    assert mgr.ensure(0, 4) is False           # pool dry: caller preempts
    assert len(mgr.owned_blocks(0)) == 1       # no partial growth


def test_peak_tracks_high_water_mark():
    mgr = PagedKVManager(9, 4, 2, 16)
    mgr.admit(0, 16)
    mgr.release(0)
    mgr.admit(1, 4)
    assert mgr.used_blocks == 1
    assert mgr.peak_used_blocks == 4


# --- partial-block masking --------------------------------------------------

def _rand_paged(seed, B=2, L=4, bs=4, kv=2, g=2, hd=8, n_blocks=9):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, kv, g, hd)).astype(np.float32)
    k = rng.standard_normal((n_blocks, bs, kv, hd)).astype(np.float32)
    v = rng.standard_normal((n_blocks, bs, kv, hd)).astype(np.float32)
    # distinct physical blocks per slot, deliberately out of order
    table = np.array([[3, 1, 7, 5], [8, 2, 4, 6]][:B], np.int32)[:, :L]
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(table)


def _dense_oracle(q, k, v, table, pos):
    """Gather the paged layout into dense rows and attend with a plain
    numpy softmax over positions <= pos."""
    q, k, v, table = map(np.asarray, (q, k, v, table))
    B, kv, g, hd = q.shape
    bs = k.shape[1]
    out = np.zeros_like(q)
    for b in range(B):
        kk = k[table[b]].reshape(-1, kv, hd)[: pos[b] + 1]
        vv = v[table[b]].reshape(-1, kv, hd)[: pos[b] + 1]
        for h in range(kv):
            s = (q[b, h] @ kk[:, h].T) / np.sqrt(hd)
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, h] = p @ vv[:, h]
    return out


@pytest.mark.parametrize("pos", [[0, 0], [2, 5], [3, 14], [15, 7]])
def test_reference_masks_partial_blocks(pos):
    """Attention must stop exactly at ``pos`` — positions in the same block
    beyond it (garbage or stale retired-slot data) contribute nothing."""
    q, k, v, table = _rand_paged(0)
    pos = jnp.asarray(pos, jnp.int32)
    got = paged_attention_ref(q, k, v, table, pos)
    want = _dense_oracle(q, k, v, table, pos)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_reference_ignores_trash_blocks():
    """Unallocated logical blocks point at the trash block; as long as the
    position mask excludes them, their contents must not matter."""
    q, k, v, table = _rand_paged(1)
    pos = jnp.asarray([3, 3], jnp.int32)       # only block 0 of each slot
    a = paged_attention_ref(q, k, v, table, pos)
    poisoned = jnp.asarray(np.where(
        np.arange(k.shape[0])[:, None, None, None] == TRASH_BLOCK,
        1e6, np.asarray(k)).astype(np.float32))
    b = paged_attention_ref(q, poisoned, v,
                            table.at[:, 1:].set(TRASH_BLOCK), pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("pos", [[0, 4], [3, 15], [11, 2]])
def test_kernel_interpret_matches_reference(pos):
    q, k, v, table = _rand_paged(2)
    pos = jnp.asarray(pos, jnp.int32)
    got = paged_attention(q, k, v, table, pos, interpret=True)
    want = paged_attention_ref(q, k, v, table, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_kernel_single_kv_head_mqa_geometry():
    q, k, v, table = _rand_paged(3, kv=1, g=4)
    pos = jnp.asarray([6, 13], jnp.int32)
    got = paged_attention(q, k, v, table, pos, interpret=True)
    want = _dense_oracle(q, k, v, table, pos)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
