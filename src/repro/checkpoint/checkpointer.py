"""Atomic step checkpoints for arbitrary pytrees.

Layout:  <dir>/step_<N>/shard_<proc>.npz + meta.json, written to a tmp dir
and atomically renamed — a crash mid-write never corrupts the latest
checkpoint, which is what the restart loop relies on.  On multi-host each
process writes only its addressable shards (here: one process = everything);
``meta.json`` records the logical layout so ``elastic.py`` can reshard on
resume onto a different mesh.

Checkpoints are *layout-free*: ``save`` gathers every (possibly mesh-
sharded) leaf to its logical host array before writing, and ``meta.json``
records the mesh/layout it was trained on purely as provenance.  Restoring
therefore never depends on the saving mesh — ``restore`` yields logical
arrays, and ``restore_sharded`` immediately re-places them for whatever
mesh the *resuming* job runs on (2x4 -> 1x8 -> single-device all work;
tested in tests/test_sharded_train.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":          # ml_dtypes (bf16, fp8): .npz can't
            arr = arr.astype(np.float32)   # round-trip them; widen losslessly
        flat[key] = arr                    # (restore casts to template dtype)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


# tmp dirs older than this are considered crash leftovers; younger ones may
# belong to a concurrent writer (multi-host savers sharing a dir) mid-save
STALE_TMP_TTL_S = 600.0


def _sweep_stale_tmp(directory: str):
    """Remove orphan ``.tmp_*`` dirs left by a crash between ``mkdtemp`` and
    the atomic rename (the in-save exception handler never runs on a hard
    kill).  Age-guarded so another process's in-flight tmp dir survives."""
    now = time.time()
    for d in os.listdir(directory):
        full = os.path.join(directory, d)
        if not d.startswith(".tmp_"):
            continue
        try:
            # newest of the dir and its entries: the dir mtime alone does
            # not advance while a writer streams into an existing shard file
            mtimes = [os.path.getmtime(full)]
            mtimes += [os.path.getmtime(os.path.join(full, f))
                       for f in os.listdir(full)]
        except OSError:
            continue                      # vanished (e.g. renamed) mid-sweep
        if now - max(mtimes) > STALE_TMP_TTL_S:
            shutil.rmtree(full, ignore_errors=True)


def save(directory: str, step: int, tree: Any, meta: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    _sweep_stale_tmp(directory)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
    try:
        proc = jax.process_index()
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_procs": jax.process_count(),
                       **(meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    # stale-tmp sweep happens at the top of save(); _gc only trims steps
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    return steps[-1] if steps else None


def restore(directory: str, template: Any, step: int | None = None):
    """Restore into the structure of ``template`` (arrays get the stored
    values; shapes must match).  Returns (tree, step, meta)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    blobs: dict[str, np.ndarray] = {}
    for fn in os.listdir(path):
        if fn.startswith("shard_"):
            with np.load(os.path.join(path, fn)) as z:
                blobs.update({k: z[k] for k in z.files})

    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
    flat, treedef = leaves_with_path
    out = []
    for p, leaf in flat:
        key = SEP.join(_path_str(q) for q in p)
        if key not in blobs:
            raise KeyError(f"checkpoint missing leaf {key}")
        val = blobs[key]
        if hasattr(leaf, "shape") and tuple(leaf.shape) != tuple(val.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{val.shape} vs {leaf.shape}")
        out.append(jax.numpy.asarray(val, dtype=getattr(leaf, "dtype", None)))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, meta


def restore_sharded(directory: str, template: Any, spec_tree: Any, mesh,
                    step: int | None = None):
    """``restore`` + re-placement onto ``mesh`` with ``spec_tree``.

    The saving mesh (recorded in meta.json) is irrelevant: leaves come back
    as logical arrays and are device_put with divisibility-checked
    NamedShardings for the *current* mesh, so elastic rescales and layout
    changes between save and resume need no array surgery."""
    from repro.checkpoint.elastic import reshard
    tree, step, meta = restore(directory, template, step)
    return reshard(tree, spec_tree, mesh), step, meta
