"""Backend dispatch for the fused ASI kernels.

One flag — ``ModelConfig.kernel_backend`` / ``LinearCompressionCfg.backend``
(``auto`` | ``pallas`` | ``reference``) — picks the execution mode for every
fused forward/backward sketch contraction:

* ``auto``       — compiled Pallas on TPU, pure-jnp reference elsewhere (XLA
                   fuses the jnp formulation well enough on CPU/GPU, and the
                   interpreter would be orders of magnitude slower).
* ``pallas``     — force the kernel code path: compiled on TPU,
                   ``interpret=True`` elsewhere (bit-for-bit the TPU program,
                   executed by the Pallas interpreter — this is what CI runs).
* ``reference``  — force the pure-jnp oracles from ``ref.py`` everywhere.

The reference backward uses exactly the same contraction XLA derives for the
dense layer's ``jax.grad``, so ``asi_linear`` under ``reference`` produces
bit-identical g_x to an uncompressed layer (tested in
tests/test_fused_asi_kernels.py).

Dispatch is mesh-aware: inside a ``shard_local_kernels()`` scope (kernels
wrapped in shard_map over the TP axis) the backward kernel's VMEM cap
(``GRAD_SKETCH_MAX_N``) is checked against the *per-shard* feature dim of
the axis the active rules shard (see ``local_feature_dim``), so
tensor-parallel layouts keep the fused kernel for globally-wide ffns whose
local blocks fit.  Outside that scope the global width is used — a bare
pallas_call under GSPMD jit receives gathered full-width operands.

Kernel modes cast the small side operands (sketch factor V, subspace P̂) to
the streamed operand's dtype: Mosaic requires matched MXU operand dtypes, and
the fp32 accumulators make the cast harmless at sketch ranks.  Grouped (MoE
per-expert) variants ``vmap`` the same kernels — Pallas lifts the expert dim
into an extra grid dimension.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.asi_sketch import matmul_grad_sketch as _grad_kernel
from repro.kernels.asi_sketch import matmul_sketch as _fwd_kernel
from repro.parallel import sharding as _sharding

Array = jax.Array

BACKENDS = ("auto", "pallas", "reference")

# The backward kernel keeps a grid-persistent (128, N_pad) fp32 R strip in
# VMEM; past this many output features the strip (plus double-buffered input
# blocks) would not fit the ~16 MB budget, so kernel modes fall back to the
# reference contraction for that call.  Shapes are static, so the choice is
# made at trace time, per linear.
GRAD_SKETCH_MAX_N = 16384


# Per-shard VMEM accounting is only sound when the fused kernels execute on
# actual shards — i.e. inside a shard_map over the TP axis.  A bare
# pallas_call in a GSPMD-partitioned jit (our training pipeline) receives
# gathered FULL-WIDTH operands, so relaxing the cap there would admit
# kernels whose R strip overflows VMEM on real TPUs.  Deployments that wrap
# the kernels in shard_map opt in with ``shard_local_kernels()``.
# Thread-local, matching the sibling axis_rules state in parallel/sharding.
_LOCAL_STATE = threading.local()


def _shard_local() -> bool:
    return getattr(_LOCAL_STATE, "shard_local", False)


@contextlib.contextmanager
def shard_local_kernels(enabled: bool = True):
    """Declare that fused kernels run inside shard_map over the TP axis, so
    mesh-aware dispatch may size the VMEM cap against per-shard widths."""
    prev = _shard_local()
    _LOCAL_STATE.shard_local = enabled
    try:
        yield
    finally:
        _LOCAL_STATE.shard_local = prev


def local_feature_dim(n: int, out_axis: str | None = None) -> int:
    """Column count of an ``n``-wide output-feature dim as the kernel will
    actually see it, given the active ``axis_rules`` context (mesh-aware
    dispatch).

    Traced shapes are *global*; inside a ``shard_map`` over the TP axis a
    device only materializes ``n / tp`` columns of a dim the rules actually
    shard, so the VMEM cap may be checked against the local block — a
    TP-sharded 64k-wide ffn then keeps the fused kernel because every
    8k-wide shard fits the R strip.  The TP factor is the mesh-axis size the
    rules map ``out_axis`` to.  Everything else means factor 1 — never
    assume a dim is narrower than the kernel will receive: outside a
    ``shard_local_kernels`` scope (GSPMD jit gathers pallas_call operands to
    full width), with an ``out_axis`` of None (caller doesn't know — e.g.
    o/down projections whose d_model output is replicated under TP), with no
    rules context, an unmapped axis, or a non-divisible dim (safe_spec would
    replicate it).
    """
    ctx = _sharding._current()
    if ctx is None or out_axis is None or not _shard_local():
        return n
    mesh, rules = ctx
    ax = rules.get(out_axis)
    if ax is None:
        return n
    k = _sharding._mesh_axis_size(mesh, ax)
    return n // k if (k > 1 and n % k == 0) else n


def _grad_fits_vmem(n: int, out_axis: str | None = None) -> bool:
    """True when the backward kernel's R strip fits for a per-shard block of
    the ``n``-column global output."""
    return local_feature_dim(n, out_axis) <= GRAD_SKETCH_MAX_N


def resolve(backend: str = "auto") -> str:
    """Map the user flag to an execution mode: pallas | interpret | reference.

    Raises early on unknown flags so a config typo fails at trace time, not
    by silently training on a different code path.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"kernel_backend={backend!r}; expected one of {BACKENDS}")
    on_tpu = jax.default_backend() == "tpu"
    if backend == "reference":
        return "reference"
    if backend == "pallas":
        return "pallas" if on_tpu else "interpret"
    return "pallas" if on_tpu else "reference"


def matmul_sketch(x: Array, w: Array, v: Array, *, backend: str = "auto",
                  **kw):
    """Fused forward:  (Y = X·W in x.dtype, P = X·V in fp32), one pass over X."""
    mode = resolve(backend)
    if mode == "reference":
        # no downcast: x @ v promotes (bf16 x, fp32 v -> fp32 sketch), exactly
        # the pre-dispatch matrix_asi_step numerics.
        return ref.matmul_sketch_ref(x, w, v)
    kw.setdefault("interpret", mode == "interpret")
    return _fwd_kernel(x, w.astype(x.dtype), v.astype(x.dtype), **kw)


def matmul_grad_sketch(g: Array, w: Array, p_hat: Array, *,
                       backend: str = "auto", out_axis: str | None = None,
                       **kw):
    """Fused backward:  (g_x = g·Wᵀ in g.dtype, R = P̂ᵀ·g in fp32), one pass
    over g.  ``w`` is the forward-layout (K, N) weight.  ``out_axis`` is the
    logical name of g's feature dim for the mesh-aware VMEM cap."""
    mode = resolve(backend)
    w = w.astype(g.dtype)
    if mode == "reference" or not _grad_fits_vmem(g.shape[-1], out_axis):
        # Same contraction (and dtype) jax.grad emits for the dense layer:
        # bit-identical g_x, plus the fp32 rank-r reduction.
        g_x = g @ w.T
        r = jnp.dot(p_hat.astype(g.dtype).T, g,
                    preferred_element_type=jnp.float32)
        return g_x, r
    kw.setdefault("interpret", mode == "interpret")
    return _grad_kernel(g, w, p_hat.astype(g.dtype), **kw)


def grouped_matmul_sketch(x: Array, w: Array, v: Array, *,
                          backend: str = "auto", **kw):
    """Per-expert fused forward: x (E, T, K), w (E, K, N), v (E, K, r)."""
    mode = resolve(backend)
    if mode == "reference":
        y = jnp.einsum("etk,ekn->etn", x, w.astype(x.dtype))
        p = jnp.einsum("etk,ekr->etr", x, v,
                       preferred_element_type=jnp.float32)
        return y, p
    kw.setdefault("interpret", mode == "interpret")
    return jax.vmap(lambda xe, we, ve: _fwd_kernel(xe, we, ve, **kw))(
        x, w.astype(x.dtype), v.astype(x.dtype))


def grouped_matmul_grad_sketch(g: Array, w: Array, p_hat: Array, *,
                               backend: str = "auto",
                               out_axis: str | None = None, **kw):
    """Per-expert fused backward: g (E, T, N), w (E, K, N), p_hat (E, T, r)."""
    mode = resolve(backend)
    w = w.astype(g.dtype)
    if mode == "reference":
        g_x = jnp.einsum("etn,ekn->etk", g, w)
        r = jnp.einsum("etr,etn->ern", p_hat.astype(g.dtype), g,
                       preferred_element_type=jnp.float32)
        return g_x, r
    if not _grad_fits_vmem(g.shape[-1], out_axis):
        return grouped_matmul_grad_sketch(g, w, p_hat, backend="reference")
    kw.setdefault("interpret", mode == "interpret")
    return jax.vmap(lambda ge, we, pe: _grad_kernel(ge, we, pe, **kw))(
        g, w, p_hat.astype(g.dtype))
