"""Mamba2 chunked-SSD Pallas TPU kernel.

Grid: (batch·heads, chunks) with the chunk dimension sequential; the running
(P, N) state lives in VMEM scratch across chunk steps.  Within a chunk
everything is (Q, ·) matmuls — the MXU-friendly "state-space duality" form.
B/C projections are shared across heads (single SSM group), read through an
index map that folds head -> batch, so they are fetched once per batch row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, state_ref, *,
            q: int, nc: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0]                           # scalar decay rate (negative)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    da = dt * a                               # (Q,) log-decay per step
    seg = jnp.cumsum(da)                      # within-chunk cumulative decay
    # intra-chunk: y_q = Σ_{j<=q} (c_q·b_j) exp(seg_q - seg_j) dt_j x_j
    att = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, Q)
    decay = seg[:, None] - seg[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l = jnp.where(tri, jnp.exp(decay), 0.0)
    w = att * l * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)
    # inter-chunk: y += exp(seg_q) * (c_q · h_inᵀ)
    h_in = state_ref[...]                     # (P, N)
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        c, h_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # state update: h = exp(Σda) h_in + Σ_j exp(seg_end - seg_j) dt_j x_jᵀ b_j
    dec_end = jnp.exp(seg[q - 1] - seg) * dt  # (Q,)
    contrib = jax.lax.dot_general(x * dec_end[:, None], b,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = h_in * jnp.exp(seg[q - 1]) + contrib
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(cj == nc - 1)
    def _final():
        h_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("n_heads", "chunk", "interpret"))
def ssd_scan(x: Array, dt: Array, a: Array, b: Array, c: Array, *,
             n_heads: int, chunk: int = 256, interpret: bool = False):
    """x (BH, S, P); dt (BH, S); a (BH,); b/c (B, S, N) shared across heads.

    Returns (y (BH, S, P), final_state (BH, P, N)).
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0 and bh % n_heads == 0
    nc = s // chunk

    y, h = pl.pallas_call(
        functools.partial(_kernel, q=chunk, nc=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda z, cj: (z, cj, 0)),
            pl.BlockSpec((1, chunk), lambda z, cj: (z, cj)),
            pl.BlockSpec((1, 1), lambda z, cj: (z, 0)),
            pl.BlockSpec((1, chunk, n), lambda z, cj: (z // n_heads, cj, 0)),
            pl.BlockSpec((1, chunk, n), lambda z, cj: (z // n_heads, cj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda z, cj: (z, cj, 0)),
            pl.BlockSpec((1, p, n), lambda z, cj: (z, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a.reshape(bh, 1), b, c)
    return y, h
