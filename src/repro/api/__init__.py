"""``repro.api`` — the embeddable runtime surface.

One typed object graph unifies what used to be four CLIs' worth of wiring:

    from repro.api import Session, demo_requests

    sess = Session.from_config("tinyllama_1_1b", reduced=True,
                               compress="asi", kernel_backend="reference")
    trainer = sess.trainer(steps=50, ckpt_dir="/tmp/ckpt")
    trainer.fit()
    sess.save()

    server = sess.server(max_batch=4, max_len=64)
    adapter = sess.adapter(mem_budget_mb=0.05)
    done = server.run(demo_requests(4), on_retire=adapter.observe)
    server.swap_params(adapter.step())     # train-while-serve, live weights

``repro.launch.{train,serve,adapt,dryrun}`` are thin argparse shims over
this package; embed the API instead of shelling out to them.  DESIGN.md §9
documents the object graph, state ownership, and the CLI-shim contract.
"""
from repro.api.resolve import (add_arch_argument, add_telemetry_arguments,
                               parse_mesh, resolve_arch, telemetry_recorder,
                               warn_programmatic_use)
from repro.api.session import (Adapter, Server, Session, Trainer,
                               data_source, demo_requests)

__all__ = [
    "Session", "Trainer", "Server", "Adapter",
    "data_source", "demo_requests",
    "resolve_arch", "add_arch_argument", "parse_mesh",
    "add_telemetry_arguments", "telemetry_recorder",
    "warn_programmatic_use",
]
