"""Uniform model API over the families, consumed by the launcher/dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[Array], dict]
    loss: Callable                    # (params, batch, asi_state=None)
    init_asi: Callable                # (key, rank_plan=None) — rank_plan maps
                                      # site paths to per-layer ranks (the
                                      # on-device planner's output)
    trainable_mask: Callable[[dict], Any]
    decode_step: Callable             # (params, cache, token, pos) — pos may
                                      # be scalar or (B,) per-slot positions
    init_cache: Callable[[int, int], dict]
    prefill: Callable                 # (params, tokens, max_len, extra=None)
                                      # -> (last_logits, cache); ``extra`` is
                                      # prefix embeds (vlm) / audio frames
                                      # (encdec), None otherwise
    # --- paged serving hooks (None when a family does not support them) ---
    init_paged_cache: Callable | None = None   # (batch, n_blocks, block_size)
    decode_step_paged: Callable | None = None  # (params, cache, table, token,
                                               #  pos) — table (B, L) int32
    write_paged_slot: Callable | None = None   # (cache, one, table_row, slot)
    # --- chunked-prefill hooks ---
    embed_tokens: Callable | None = None       # (params, token) -> (B, d)
    decode_step_embed: Callable | None = None  # (params, cache, x, pos) with
                                               # pre-embedded x (B, d) — vlm
                                               # prefix chunks
    prime_cross: Callable | None = None        # encdec: (params, frames) ->
                                               # cross K/V for a fresh cache

    def init_struct(self, key: Array | None = None):
        """``eval_shape``-safe init: the parameter pytree as
        ``ShapeDtypeStruct``s with no device allocation.  This is the hook
        the dry-run used to rebuild the whole model to get — use it for
        parameter accounting, sharding-spec construction, and checkpoint
        restore templates."""
        if key is None:
            key = jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            loss=lambda p, b, s=None: encdec.loss_fn(p, b, cfg, s),
            init_asi=lambda key, rank_plan=None: encdec.init_asi_state(
                key, cfg, rank_plan),
            trainable_mask=lambda p: encdec.trainable_mask(p, cfg),
            decode_step=lambda p, c, t, pos: encdec.decode_step(p, c, t, pos, cfg),
            init_cache=lambda b, n: encdec.init_cache(cfg, b, n),
            prefill=lambda p, t, n, extra=None: encdec.prefill(p, extra, t, cfg, n),
            init_paged_cache=lambda b, nb, bs: encdec.init_paged_cache(
                cfg, b, nb, bs),
            decode_step_paged=lambda p, c, tb, t, pos: encdec.decode_step_paged(
                p, c, tb, t, pos, cfg),
            write_paged_slot=lambda c, o, row, slot: encdec.write_paged_slot(
                cfg, c, o, row, slot),
            prime_cross=lambda p, frames: encdec.prime_cross(p, frames, cfg),
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        loss=lambda p, b, s=None: transformer.loss_fn(p, b, cfg, s),
        init_asi=lambda key, rank_plan=None: transformer.init_asi_state(
            key, cfg, rank_plan),
        trainable_mask=lambda p: transformer.trainable_mask(p, cfg),
        decode_step=lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg),
        init_cache=lambda b, n: transformer.init_cache(cfg, b, n),
        prefill=lambda p, t, n, extra=None: transformer.prefill(p, t, cfg, n, extra),
        init_paged_cache=lambda b, nb, bs: transformer.init_paged_cache(
            cfg, b, nb, bs),
        decode_step_paged=lambda p, c, tb, t, pos: transformer.decode_step_paged(
            p, c, tb, t, pos, cfg),
        write_paged_slot=lambda c, o, row, slot: transformer.write_paged_slot(
            cfg, c, o, row, slot),
        embed_tokens=lambda p, t: transformer.embed_tokens(p, t, cfg),
        decode_step_embed=lambda p, c, x, pos: transformer.decode_step_embed(
            p, c, x, pos, cfg),
    )
