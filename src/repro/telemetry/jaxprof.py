"""``jax.profiler`` bridge: device traces, named annotation scopes,
compile-vs-run splits, and memory gauges — all opt-in and all guarded so
the bridge degrades to a no-op on backends (or jax builds) that lack a
profiler.

The bridge never *replaces* the host-side recorder; it decorates it.
Spans opened on a recorder with an attached bridge also enter a
``jax.profiler.TraceAnnotation`` of the same name, so the host timeline
and the XLA device trace line up by name in Perfetto.
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional

import jax

from repro.telemetry.memstats import GAUGE_FIELDS, compiled_memory_stats

#: ``memory_analysis()`` fields exported as gauges when present
#: (kept as an alias — the shared reader in telemetry.memstats owns it)
_MEM_FIELDS = GAUGE_FIELDS


class JaxProfileBridge:
    """Glue between a :class:`~repro.telemetry.record.Recorder` and
    ``jax.profiler``.  Construct via ``Recorder.attach_profiler``."""

    def __init__(self, recorder, trace_dir: Optional[str] = None):
        self.rec = recorder
        self.trace_dir = trace_dir
        self._active = False
        self._split_done: set[str] = set()

    # -- annotation scopes ---------------------------------------------
    def annotation(self, name: str):
        """A named ``TraceAnnotation`` scope (no-op if unavailable)."""
        try:
            return jax.profiler.TraceAnnotation(name)
        except Exception:
            return contextlib.nullcontext()

    # -- whole-run device trace ----------------------------------------
    def start(self) -> None:
        if self.trace_dir and not self._active:
            try:
                jax.profiler.start_trace(self.trace_dir)
                self._active = True
            except Exception:
                self._active = False

    def stop(self) -> None:
        if self._active:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._active = False

    @contextlib.contextmanager
    def trace(self):
        self.start()
        try:
            yield self
        finally:
            self.stop()

    # -- compile-vs-run split ------------------------------------------
    def compile_split(self, name: str, fn, *args, **kwargs) -> None:
        """AOT-lower and compile ``fn`` once, recording the trace/compile
        wall split and ``memory_analysis()`` byte gauges under
        ``<name>.*``.  Memoized per name: only the first invocation pays.

        Note this is a *separate* compilation from the one ``jax.jit``
        caches for the live call, so profiled runs compile the step
        twice — the price of an explicit split, and why this only runs
        behind ``--profile-trace``.
        """
        if name in self._split_done or not hasattr(fn, "lower"):
            return
        self._split_done.add(name)
        rec = self.rec
        try:
            t0 = time.perf_counter()
            lowered = fn.lower(*args, **kwargs)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        except Exception:
            return
        rec.set_gauge(f"{name}.trace_lower_s", t1 - t0)
        rec.set_gauge(f"{name}.compile_s", t2 - t1)
        for field, v in compiled_memory_stats(compiled, _MEM_FIELDS).items():
            if field != "error":
                rec.set_gauge(f"{name}.{field}", v)

    # -- live-buffer gauges --------------------------------------------
    def live_buffer_gauges(self, prefix: str = "jax.live") -> None:
        """Sample the process-wide live jax array population."""
        try:
            arrs = jax.live_arrays()
        except Exception:
            return
        nbytes = 0
        for a in arrs:
            try:
                nbytes += int(a.nbytes)
            except Exception:
                pass
        self.rec.set_gauge(f"{prefix}.arrays", len(arrs))
        self.rec.set_gauge(f"{prefix}.bytes", nbytes)
