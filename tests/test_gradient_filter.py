"""patch_pool correctness: pooled means must be exact even when H/W are not
multiples of r (edge patches renormalized by their true element counts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gradient_filter import patch_pool, pooled_storage_elems


def _oracle(x: np.ndarray, r: int) -> np.ndarray:
    """Unpooled reference: mean over the actual elements of each patch."""
    b, c, h, w = x.shape
    hh, ww = (h + r - 1) // r, (w + r - 1) // r
    out = np.zeros((b, c, hh, ww), x.dtype)
    for i in range(hh):
        for j in range(ww):
            patch = x[:, :, i * r: min((i + 1) * r, h),
                      j * r: min((j + 1) * r, w)]
            out[:, :, i, j] = patch.mean(axis=(2, 3))
    return out


@pytest.mark.parametrize("h,w,r", [
    (8, 8, 4),       # exact multiples
    (7, 9, 4),       # ragged both dims
    (5, 4, 4),       # ragged rows only
    (4, 6, 4),       # ragged cols only
    (3, 3, 4),       # single partial patch
    (10, 7, 3),
])
def test_patch_pool_matches_unpooled_oracle(h, w, r):
    x = jax.random.normal(jax.random.PRNGKey(h * 100 + w), (2, 3, h, w))
    y = patch_pool(x, r)
    assert y.shape[2:] == ((h + r - 1) // r, (w + r - 1) // r)
    assert y.size == pooled_storage_elems((2, 3, h, w), r)
    np.testing.assert_allclose(np.asarray(y), _oracle(np.asarray(x), r),
                               atol=1e-6)


def test_patch_pool_constant_input_is_exact_on_ragged_shapes():
    """The old zero-pad-then-divide-by-r*r version biased edge patches low;
    a constant input must pool to exactly that constant everywhere."""
    x = jnp.full((1, 1, 7, 5), 3.25)
    y = patch_pool(x, 4)
    np.testing.assert_allclose(np.asarray(y), 3.25, atol=1e-7)
